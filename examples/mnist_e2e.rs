//! End-to-end validation driver (EXPERIMENTS.md §E2E): batched CNN
//! inference through the full three-layer stack.
//!
//! The graph is the layer-wise MNIST CNN; conv/FC layers are weight-fixed
//! FPGA roles (the paper's "fix layer weights to have more efficient
//! hardware"), relu/pool stay on the CPU. A synthetic MNIST-like dataset
//! (blob-per-class) is classified; because the network is random-weight,
//! the interesting outputs are latency/throughput, reconfiguration
//! behaviour, and the cross-check that FPGA-path logits equal the
//! CPU-baseline logits and (when artifacts exist) the AOT PJRT module.
//!
//! ```bash
//! make artifacts && cargo run --release --example mnist_e2e
//! ```

use tf_fpga::hsa::agent::DeviceType;
use tf_fpga::tf::dtype::DType;
use tf_fpga::tf::graph::{Graph, NodeId, OpKind};
use tf_fpga::tf::session::{Session, SessionOptions};
use tf_fpga::tf::tensor::Tensor;
use tf_fpga::util::prng::Rng;
use tf_fpga::util::stats::Summary;

const BATCH: usize = 32;
const BATCHES: usize = 32;

/// Layer-wise CNN over one image (the multi-dispatch path the paper's
/// toolflow produces: one registered kernel per layer).
fn cnn_graph() -> anyhow::Result<(Graph, NodeId)> {
    let mut g = Graph::new();
    let x = g.placeholder("x", &[1, 28, 28], DType::F32).map_err(ae)?;
    let c1 = g
        .add(
            "conv1",
            OpKind::ConvFixedF32 {
                weights: "cnn/conv1".into(),
                filters: 2,
                cin: 1,
                kh: 3,
                kw: 3,
            },
            &[x],
        )
        .map_err(ae)?;
    let r1 = g.add("relu1", OpKind::Relu, &[c1]).map_err(ae)?;
    let p1 = g.add("pool1", OpKind::MaxPool2, &[r1]).map_err(ae)?;
    let c2 = g
        .add(
            "conv2",
            OpKind::ConvFixedF32 {
                weights: "cnn/conv2".into(),
                filters: 4,
                cin: 2,
                kh: 5,
                kw: 5,
            },
            &[p1],
        )
        .map_err(ae)?;
    let r2 = g.add("relu2", OpKind::Relu, &[c2]).map_err(ae)?;
    let p2 = g.add("pool2", OpKind::MaxPool2, &[r2]).map_err(ae)?;
    let fl = g
        .add("flat", OpKind::Reshape { shape: vec![1, 64] }, &[p2])
        .map_err(ae)?;
    let f1 = g
        .add(
            "fc1",
            OpKind::FcFixed {
                weights_w: "cnn/fc1_w".into(),
                weights_b: "cnn/fc1_b".into(),
                out_width: 32,
            },
            &[fl],
        )
        .map_err(ae)?;
    let r3 = g.add("relu3", OpKind::Relu, &[f1]).map_err(ae)?;
    let f2 = g
        .add(
            "logits",
            OpKind::FcFixed {
                weights_w: "cnn/fc2_w".into(),
                weights_b: "cnn/fc2_b".into(),
                out_width: 10,
            },
            &[r3],
        )
        .map_err(ae)?;
    Ok((g, f2))
}

/// Synthetic MNIST-like data: class k = a Gaussian blob centred at one of
/// 10 fixed positions plus noise. Real pixels, deterministic labels.
fn synthetic_digit(rng: &mut Rng, class: usize) -> Vec<f32> {
    let centers = [
        (7.0, 7.0), (7.0, 14.0), (7.0, 21.0), (14.0, 7.0), (14.0, 14.0),
        (14.0, 21.0), (21.0, 7.0), (21.0, 14.0), (21.0, 21.0), (10.0, 18.0),
    ];
    let (cy, cx) = centers[class];
    let mut img = vec![0f32; 784];
    for y in 0..28 {
        for x in 0..28 {
            let d2 = (y as f32 - cy).powi(2) + (x as f32 - cx).powi(2);
            img[y * 28 + x] =
                (-d2 / 18.0).exp() * 2.0 + rng.normal() as f32 * 0.05;
        }
    }
    img
}

fn ae(e: tf_fpga::hsa::error::HsaError) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}

fn main() -> anyhow::Result<()> {
    println!("=== MNIST end-to-end driver (full stack) ===\n");

    // --- sessions: FPGA-placed and CPU baseline, identical graphs ---
    // 4 PR regions so the CNN's four weight-fixed roles stay resident (the
    // 2-region default would LRU-thrash: conv1->conv2->fc1->fc2 cycles; we
    // show that contrast at the end).
    let (g, _) = cnn_graph()?;
    let t0 = std::time::Instant::now();
    let fpga_sess = Session::new(
        g.clone(),
        SessionOptions { num_regions: 4, ..SessionOptions::default() },
    )
    .map_err(ae)?;
    println!("FPGA session setup: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    let cpu_sess = Session::new(g.clone(), SessionOptions::cpu_baseline()).map_err(ae)?;

    // Per-layer placement report.
    println!("\nplacement:");
    for node in fpga_sess.graph().nodes() {
        if let Some(dev) = fpga_sess.placement().device_of(node.id) {
            println!("  {:8} -> {dev}", node.name);
        }
    }

    // --- batched inference (layer-wise graph, image at a time) ---
    let mut rng = Rng::new(2026);
    let mut lat_us = Vec::new();
    let mut correct_consistency = 0usize;
    let mut total = 0usize;
    let mut class_hits = vec![0usize; 10];

    let t_run = std::time::Instant::now();
    for _ in 0..BATCHES {
        for _ in 0..BATCH {
            let class = (rng.below(10)) as usize;
            let img = synthetic_digit(&mut rng, class);
            let x = Tensor::from_f32(&[1, 28, 28], img).unwrap();
            let t1 = std::time::Instant::now();
            let out = fpga_sess.run(&[("x", x.clone())], &["logits"]).map_err(ae)?;
            lat_us.push(t1.elapsed().as_secs_f64() * 1e6);
            let cpu_out = cpu_sess.run(&[("x", x)], &["logits"]).map_err(ae)?;
            // FPGA numerics must equal the CPU oracle bit-for-bit (same
            // kernels, different devices).
            let diff = out[0].max_abs_diff(&cpu_out[0]).map_err(|e| anyhow::anyhow!("{e}"))?;
            assert!(diff < 1e-4, "FPGA/CPU divergence {diff}");
            correct_consistency += 1;
            let logits = out[0].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?;
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            class_hits[pred] += 1;
            total += 1;
        }
    }
    let wall = t_run.elapsed().as_secs_f64();

    let s = Summary::from_values(&lat_us);
    println!("\n--- results ({} images) ---", total);
    println!(
        "latency/image: mean {:.2} ms  p50 {:.2}  p99 {:.2}  max {:.2} ms",
        s.mean / 1e3,
        s.p50 / 1e3,
        s.p99 / 1e3,
        s.max / 1e3
    );
    println!("throughput: {:.0} img/s (wall {:.1} s)", total as f64 / wall, wall);
    println!("FPGA==CPU consistency: {}/{}", correct_consistency, total);
    println!("prediction distribution: {class_hits:?}");

    let rs = fpga_sess.reconfig_stats();
    println!(
        "\nreconfiguration: {} dispatches, hit rate {:.2}%, {} reconfigs, {:.1} ms modeled PCAP time",
        rs.dispatches,
        100.0 * rs.hit_rate(),
        rs.misses,
        rs.reconfig_us_total as f64 / 1e3
    );
    println!(
        "fpga virtual time: {:.1} ms; cpu(A53 model) virtual time: {:.1} ms",
        agent_ms(fpga_sess.fpga_agent().as_ref()),
        agent_ms(cpu_sess.cpu_agent().as_ref()),
    );

    // --- the paper's region trade-off: same graph on 2 regions thrashes ---
    let thrash_sess = Session::new(
        g,
        SessionOptions { num_regions: 2, use_pjrt: false, ..SessionOptions::default() },
    )
    .map_err(ae)?;
    let mut v = vec![0f32; 784];
    rng.fill_f32_normal(&mut v, 0.0, 1.0);
    let x = Tensor::from_f32(&[1, 28, 28], v).unwrap();
    for _ in 0..16 {
        thrash_sess.run(&[("x", x.clone())], &["logits"]).map_err(ae)?;
    }
    let ts = thrash_sess.reconfig_stats();
    println!(
        "\n2-region contrast (paper's role-count trade-off): hit rate {:.1}% vs {:.1}% with 4 regions",
        100.0 * ts.hit_rate(),
        100.0 * rs.hit_rate()
    );
    thrash_sess.shutdown();

    // --- whole-model dispatch path (one role per batch, PJRT-backed) ---
    println!("\n--- whole-model role (mnist_cnn, batch {BATCH}) ---");
    let mut g2 = Graph::new();
    let x2 = g2.placeholder("x", &[BATCH, 1, 28, 28], DType::F32).map_err(ae)?;
    g2.add("logits", OpKind::MnistCnn, &[x2]).map_err(ae)?;
    let batch_sess = Session::new(g2, SessionOptions::default()).map_err(ae)?;
    let mut batch_lat = Vec::new();
    for _ in 0..BATCHES {
        let mut imgs = Vec::with_capacity(BATCH * 784);
        for _ in 0..BATCH {
            let class = (rng.below(10)) as usize;
            imgs.extend(synthetic_digit(&mut rng, class));
        }
        let x = Tensor::from_f32(&[BATCH, 1, 28, 28], imgs).unwrap();
        let t1 = std::time::Instant::now();
        let _ = batch_sess.run(&[("x", x)], &["logits"]).map_err(ae)?;
        batch_lat.push(t1.elapsed().as_secs_f64() * 1e6);
    }
    let bs = Summary::from_values(&batch_lat);
    println!(
        "batch latency: mean {:.2} ms  p99 {:.2} ms  throughput {:.0} img/s",
        bs.mean / 1e3,
        bs.p99 / 1e3,
        BATCH as f64 / (bs.mean / 1e6)
    );
    println!(
        "whole-model path used PJRT artifact: {}",
        batch_sess.weights().from_artifacts
    );

    fpga_sess.shutdown();
    cpu_sess.shutdown();
    batch_sess.shutdown();
    println!("\nOK");
    Ok(())
}

fn agent_ms(a: &dyn tf_fpga::hsa::agent::Agent) -> f64 {
    a.virtual_time_ns() as f64 / 1e6
}
