//! Fig. 1 / §III reproduction: the FPGA is *not* monopolized by the neural
//! network. A DL inference client (TF frontend) and an "OpenCL-style"
//! preprocessing client share the same FPGA through the same HSA runtime;
//! the reconfiguration manager LRU-swaps their roles in and out of the PR
//! regions.
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```

use std::sync::Arc;
use tf_fpga::fpga::device::{ComputeBinding, FpgaAgent, FpgaConfig};
use tf_fpga::fpga::roles;
use tf_fpga::hsa::agent::DeviceType;
use tf_fpga::hsa::runtime::HsaRuntime;
use tf_fpga::ops;
use tf_fpga::reconfig::policy::PolicyKind;
use tf_fpga::tf::tensor::Tensor;
use tf_fpga::util::prng::Rng;
use tf_fpga::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    println!("=== multi-tenant FPGA sharing (Fig. 1) ===\n");

    // One FPGA agent with 2 PR regions and LRU eviction (paper default).
    let fpga = FpgaAgent::new(FpgaConfig {
        num_regions: 2,
        policy: PolicyKind::Lru.build(0),
        realtime: false,
        realtime_scale: 1.0,
        trace: None,
    });

    // DL roles (conv layers) + an OpenCL-style preprocessing role.
    let paper = roles::paper_roles();
    let conv5 = paper[2].clone();
    let conv3 = paper[3].clone();
    let mut rng = Rng::new(5);
    let mut w5 = vec![0i16; 25];
    rng.fill_i16(&mut w5, -64, 63);
    let mut w3 = vec![0i16; 18];
    rng.fill_i16(&mut w3, -64, 63);
    let conv5_id = fpga.register_role(
        conv5,
        ComputeBinding::Native(Arc::new({
            let w = w5.clone();
            move |ins: &[Tensor]| Ok(vec![ops::conv2d_fixed_i16(&ins[0], &w, 1, 1, 5, 5, 8)?])
        })),
    );
    let conv3_id = fpga.register_role(
        conv3,
        ComputeBinding::Native(Arc::new({
            let w = w3.clone();
            move |ins: &[Tensor]| Ok(vec![ops::conv2d_fixed_i16(&ins[0], &w, 2, 1, 3, 3, 8)?])
        })),
    );
    // Preprocessing role: scale + clamp (sensor-fusion-style stream op).
    let pre_id = fpga.register_role(
        roles::preprocess_role(),
        ComputeBinding::Native(Arc::new(|ins: &[Tensor]| {
            let d = ins[0].as_i16()?;
            let out: Vec<i16> = d.iter().map(|&v| (v / 2).clamp(-512, 511)).collect();
            Ok(vec![Tensor::from_i16(ins[0].shape(), out)?])
        })),
    );

    let rt = HsaRuntime::builder().with_agent(fpga.clone()).build();
    let agent = rt.agent_by_type(DeviceType::Fpga)?;
    // Each tenant gets its own AQL queue to the same device — the HSA way.
    let q_dl = rt.create_queue(agent.clone(), 64);
    let q_pre = rt.create_queue(agent, 64);

    // --- the two tenants run concurrently ---
    let n_per_tenant = 120usize;
    let rt = Arc::new(rt);

    let dl = {
        let rt = Arc::clone(&rt);
        std::thread::spawn(move || -> Vec<f64> {
            let mut rng = Rng::new(10);
            let mut lat = Vec::new();
            for i in 0..n_per_tenant {
                let mut v = vec![0i16; 784];
                rng.fill_i16(&mut v, -256, 255);
                let x = Tensor::from_i16(&[1, 28, 28], v).unwrap();
                let kernel = if i % 2 == 0 { conv5_id } else { conv3_id };
                let t0 = std::time::Instant::now();
                rt.dispatch_sync(&q_dl, kernel, vec![x]).expect("dl dispatch");
                lat.push(t0.elapsed().as_secs_f64() * 1e6);
            }
            lat
        })
    };

    let pre = {
        let rt = Arc::clone(&rt);
        std::thread::spawn(move || -> Vec<f64> {
            let mut rng = Rng::new(20);
            let mut lat = Vec::new();
            for _ in 0..n_per_tenant {
                let mut v = vec![0i16; 784];
                rng.fill_i16(&mut v, -1024, 1023);
                let x = Tensor::from_i16(&[784], v).unwrap();
                let t0 = std::time::Instant::now();
                rt.dispatch_sync(&q_pre, pre_id, vec![x]).expect("pre dispatch");
                lat.push(t0.elapsed().as_secs_f64() * 1e6);
            }
            lat
        })
    };

    let dl_lat = dl.join().unwrap();
    let pre_lat = pre.join().unwrap();

    let dls = Summary::from_values(&dl_lat);
    let pres = Summary::from_values(&pre_lat);
    println!("DL tenant   : n={} mean {:.1} µs p99 {:.1} µs", dls.n, dls.mean, dls.p99);
    println!("preproc     : n={} mean {:.1} µs p99 {:.1} µs", pres.n, pres.mean, pres.p99);

    let s = fpga.reconfig_stats();
    println!("\nshared-FPGA reconfiguration stats:");
    println!(
        "  dispatches {}  hits {} ({:.1}%)  misses {}  evictions {}  modeled PCAP {:.1} ms",
        s.dispatches,
        s.hits,
        100.0 * s.hit_rate(),
        s.misses,
        s.evictions,
        s.reconfig_us_total as f64 / 1e3
    );
    println!("  per-role dispatches: {:?}", fpga.role_dispatches());
    assert_eq!(s.dispatches as usize, 2 * n_per_tenant);
    assert!(s.evictions > 0, "3 roles over 2 regions must evict");

    // Contrast: 3 regions -> no eviction once warm.
    println!("\nwith 3 regions (working set fits):");
    let fpga3 = FpgaAgent::new(FpgaConfig {
        num_regions: 3,
        policy: PolicyKind::Lru.build(0),
        realtime: false,
        realtime_scale: 1.0,
        trace: None,
    });
    let ids: Vec<u64> = roles::paper_roles()[2..4]
        .iter()
        .cloned()
        .chain([roles::preprocess_role()])
        .map(|b| {
            fpga3.register_role(
                b,
                ComputeBinding::Native(Arc::new(|ins: &[Tensor]| Ok(ins.to_vec()))),
            )
        })
        .collect();
    let rt3 = HsaRuntime::builder().with_agent(fpga3.clone()).build();
    let q3 = rt3.create_queue(rt3.agent_by_type(DeviceType::Fpga)?, 64);
    let x = Tensor::from_i16(&[1, 28, 28], vec![0; 784]).unwrap();
    for i in 0..60 {
        rt3.dispatch_sync(&q3, ids[i % 3], vec![x.clone()])?;
    }
    let s3 = fpga3.reconfig_stats();
    println!(
        "  dispatches {}  hit rate {:.1}%  evictions {}",
        s3.dispatches,
        100.0 * s3.hit_rate(),
        s3.evictions
    );
    assert_eq!(s3.evictions, 0);
    assert_eq!(s3.misses, 3, "only the 3 cold loads");

    rt.shutdown();
    rt3.shutdown();
    println!("\nOK: the FPGA served two independent clients through one runtime.");
    Ok(())
}
