//! Fig. 1 / §III reproduction, grown to the signature-based serving API:
//! the FPGA is *not* monopolized by one network. Two model bundles with
//! **different input shapes** — the MNIST CNN (`[B, 1, 28, 28]`) and a
//! tiny dense model (`[B, 16]`) — are served side by side from one
//! session; each gets its own micro-batch lane, and the reconfiguration
//! manager swaps their roles through the shared PR regions.
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```

use std::sync::Arc;
use std::time::Duration;
use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig, BatchPolicy, ModelSpec};
use tf_fpga::tf::model::ModelBundle;
use tf_fpga::tf::session::SessionOptions;
use tf_fpga::util::prng::Rng;
use tf_fpga::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    println!("=== multi-tenant serving: two bundles, two shapes, one FPGA ===\n");

    let policy = |max_batch, ms| BatchPolicy {
        max_batch,
        max_delay: Duration::from_millis(ms),
    };
    // Two tenants. The bundles could just as well come from disk
    // (`ModelSpec::from_dir`) after `tf-fpga export-demo` or
    // `python -m compile.export`.
    let srv = AsyncInferenceServer::start(AsyncServerConfig {
        models: vec![
            ModelSpec::from_bundle("mnist", ModelBundle::mnist_demo(8), policy(8, 2)),
            ModelSpec::from_bundle("tiny", ModelBundle::tiny_fc_demo(4, 16, 4), policy(4, 1)),
        ],
        session: SessionOptions { dispatch_workers: 4, ..SessionOptions::default() },
        pipeline_depth: 4,
    })
    .map_err(|e| anyhow::anyhow!("{e}"))?;

    for name in ["mnist", "tiny"] {
        let meta = srv.model_meta(name).expect("served model");
        println!(
            "tenant '{name}': {:?} -> {:?} per request",
            meta.sample_in_shape, meta.sample_out_shape
        );
    }

    // --- both tenants submit concurrently ---
    let n_per_tenant = 120usize;
    let srv = Arc::new(srv);
    let client = |model: &'static str, seed: u64| {
        let srv = Arc::clone(&srv);
        std::thread::spawn(move || -> Vec<f64> {
            let meta = srv.model_meta(model).expect("served model").clone();
            let mut rng = Rng::new(seed);
            let mut lat = Vec::new();
            for _ in 0..n_per_tenant {
                let mut sample = vec![0f32; meta.in_elems];
                rng.fill_f32_normal(&mut sample, 0.0, 1.0);
                let t0 = std::time::Instant::now();
                let row = srv.infer(model, sample).expect("infer");
                lat.push(t0.elapsed().as_secs_f64() * 1e6);
                assert_eq!(row.len(), meta.out_elems, "{model} row size");
            }
            lat
        })
    };
    let mnist_thread = client("mnist", 10);
    let tiny_thread = client("tiny", 20);
    let mnist_lat = mnist_thread.join().unwrap();
    let tiny_lat = tiny_thread.join().unwrap();

    let ms = Summary::from_values(&mnist_lat);
    let ts = Summary::from_values(&tiny_lat);
    println!("\nmnist tenant : n={} mean {:.1} µs p99 {:.1} µs", ms.n, ms.mean, ms.p99);
    println!("tiny tenant  : n={} mean {:.1} µs p99 {:.1} µs", ts.n, ts.mean, ts.p99);

    let rep = srv.report();
    println!("\nshared-session serving report:");
    println!(
        "  requests {} (completed {}, failed {})  batches {} (mean fill {:.1}, max in-flight {})",
        rep.requests, rep.completed, rep.failed, rep.batches, rep.mean_batch_fill,
        rep.max_inflight
    );
    println!(
        "  fpga: {} dispatches, hit rate {:.1}%, {} reconfigs ({:.1} ms modeled PCAP)",
        rep.reconfig.dispatches,
        100.0 * rep.reconfig.hit_rate(),
        rep.reconfig.misses,
        rep.reconfig.reconfig_us_total as f64 / 1e3
    );
    assert_eq!(rep.completed, 2 * n_per_tenant as u64);
    assert_eq!(rep.failed, 0);

    if let Ok(mut s) = Arc::try_unwrap(srv) {
        s.stop();
    }
    println!("\nOK: one session served two differently-shaped models through one FPGA.");
    Ok(())
}
