//! §III's motivating use case: "applications … are usually divided into
//! the network inference itself and several external pre- and
//! post-processing steps". One graph mixes all three stages:
//!
//!   sensor f32 frame -> quantize (CPU) -> conv5x5 int16 (FPGA role 3)
//!   -> relu (CPU) -> dequantize (CPU) -> statistics
//!
//! and the same binary also drives the paper's Table II trade-off: the
//! cost of reconfiguring per call vs pinning the role, swept over
//! batch-run lengths (reconfiguration amortization in practice).
//!
//! ```bash
//! cargo run --release --example heterogeneous_pipeline
//! ```

use tf_fpga::hsa::agent::DeviceType;
use tf_fpga::tf::dtype::DType;
use tf_fpga::tf::graph::{Graph, OpKind};
use tf_fpga::tf::session::{Session, SessionOptions};
use tf_fpga::tf::tensor::Tensor;
use tf_fpga::util::prng::Rng;

fn ae(e: tf_fpga::hsa::error::HsaError) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}

fn pipeline_graph() -> anyhow::Result<Graph> {
    let mut g = Graph::new();
    let x = g.placeholder("frame", &[1, 28, 28], DType::F32).map_err(ae)?;
    let q = g.add("quant", OpKind::Quantize { frac_bits: 8 }, &[x]).map_err(ae)?;
    let c = g.add("conv", OpKind::Conv5x5I16, &[q]).map_err(ae)?;
    let r = g.add("relu", OpKind::Relu, &[c]).map_err(ae)?;
    g.add("deq", OpKind::Dequantize { frac_bits: 8 }, &[r]).map_err(ae)?;
    // The conv goes to the FPGA; quant/relu/deq run on the CPU — a genuine
    // heterogeneous dataflow through one runtime.
    g.set_device(c, DeviceType::Fpga);
    Ok(g)
}

fn main() -> anyhow::Result<()> {
    println!("=== heterogeneous pre/post-processing pipeline ===\n");
    let sess = Session::new(pipeline_graph()?, SessionOptions::default()).map_err(ae)?;

    println!("placement:");
    for node in sess.graph().nodes() {
        if let Some(dev) = sess.placement().device_of(node.id) {
            println!("  {:6} -> {dev}", node.name);
        }
    }

    let mut rng = Rng::new(77);
    let frames = 200usize;
    let t0 = std::time::Instant::now();
    let mut checksum = 0f64;
    for _ in 0..frames {
        let mut v = vec![0f32; 784];
        rng.fill_f32_normal(&mut v, 0.0, 1.0);
        let frame = Tensor::from_f32(&[1, 28, 28], v).unwrap();
        let out = sess.run(&[("frame", frame)], &["deq"]).map_err(ae)?;
        checksum += out[0].as_f32().map_err(|e| anyhow::anyhow!("{e}"))?[0] as f64;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nprocessed {frames} frames in {:.2} s ({:.0} frames/s); checksum {:.3}",
        wall,
        frames as f64 / wall,
        checksum
    );

    let s = sess.reconfig_stats();
    println!(
        "fpga: {} conv dispatches, {} reconfig ({} µs modeled), hit rate {:.1}%",
        s.dispatches, s.misses, s.reconfig_us_total, 100.0 * s.hit_rate()
    );

    // --- reconfiguration amortization sweep (virtual time) ---
    println!("\n--- reconfigure-per-burst amortization (virtual device time) ---");
    println!("{:>10} {:>16} {:>16} {:>10}", "burst", "FPGA+reconf [ms]", "A53 [ms]", "win");
    let cpu = tf_fpga::cpu::a53::A53Model::default();
    let spec = tf_fpga::fpga::roles::role3_spec();
    let reconfig_us = tf_fpga::fpga::icap::Icap::default()
        .reconfig_time_us(tf_fpga::fpga::roles::ROLE_BITSTREAM_BYTES);
    for burst in [1usize, 4, 16, 64, 256, 1024, 2048, 4096] {
        let fpga_ms =
            (reconfig_us as f64 + burst as f64 * spec.exec_ns(&spec.op) as f64 / 1e3) / 1e3;
        let cpu_ms = burst as f64 * cpu.exec_ns(&spec.op) as f64 / 1e6;
        println!(
            "{:>10} {:>16.2} {:>16.2} {:>10}",
            burst,
            fpga_ms,
            cpu_ms,
            if fpga_ms < cpu_ms { "FPGA" } else { "CPU" }
        );
    }
    println!(
        "\n(cold-start break-even: the paper's LRU keeps hot roles resident so bursts\n\
         rarely pay the reconfiguration; see `tf-fpga crossover` for all roles)"
    );

    sess.shutdown();
    println!("\nOK");
    Ok(())
}
