//! Quickstart: build a small graph, let the placer put the FC on the FPGA,
//! run it, and inspect the reconfiguration stats.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use tf_fpga::hsa::agent::DeviceType;
use tf_fpga::tf::dtype::DType;
use tf_fpga::tf::graph::{Graph, OpKind};
use tf_fpga::tf::session::{Session, SessionOptions};
use tf_fpga::tf::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    // 1. Build a graph the way a TF user would: x -> FC -> relu.
    let mut g = Graph::new();
    let x = g.placeholder("x", &[2, 4], DType::F32).map_err(err)?;
    let w = g
        .constant(
            "w",
            Tensor::from_f32(&[4, 3], (0..12).map(|i| 0.1 * i as f32).collect())
                .map_err(terr)?,
        )
        .map_err(err)?;
    let b = g
        .constant("b", Tensor::from_f32(&[3], vec![0.5, 0.0, -0.5]).map_err(terr)?)
        .map_err(err)?;
    let y = g.add("y", OpKind::FullyConnected, &[x, w, b]).map_err(err)?;
    g.add("out", OpKind::Relu, &[y]).map_err(err)?;

    // Optional: pin the FC to the FPGA explicitly (the paper's
    // `with tf.device(...)` annotation). Without this the placer would
    // pick the FPGA anyway because an FPGA kernel is registered.
    g.set_device(y, DeviceType::Fpga);

    // 2. One Session bring-up = the paper's "device/kernel setup".
    let sess = Session::new(g, SessionOptions::default()).map_err(err)?;
    println!(
        "session ready in {:.1} ms (PJRT compile {:.1} ms)",
        sess.setup_timing().total_us as f64 / 1000.0,
        sess.setup_timing().pjrt_compile_us as f64 / 1000.0,
    );

    // 3. Run. First dispatch partially reconfigures an FPGA region with the
    //    FC role; later dispatches hit the resident role.
    let input = Tensor::from_f32(&[2, 4], vec![1.0, 2.0, 3.0, 4.0, -1.0, -2.0, -3.0, -4.0])
        .map_err(terr)?;
    for i in 0..3 {
        let out = sess.run(&[("x", input.clone())], &["out"]).map_err(err)?;
        println!("run {i}: out = {:?}", out[0].as_f32().map_err(terr)?);
    }

    let s = sess.reconfig_stats();
    println!(
        "fpga stats: {} dispatches, {} hits, {} misses, {} µs reconfiguration (modeled)",
        s.dispatches, s.hits, s.misses, s.reconfig_us_total
    );
    assert_eq!(s.misses, 1, "role loads once, then stays resident");
    sess.shutdown();
    Ok(())
}

fn err(e: tf_fpga::hsa::error::HsaError) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}

fn terr(e: tf_fpga::tf::tensor::TensorError) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}
