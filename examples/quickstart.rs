//! Quickstart: build a graph the way a TF user would, wrap it in a signed
//! [`ModelBundle`], save/load it as a `model.json` directory, and invoke
//! it by *endpoint name* through the [`Model`] facade — the same bundle
//! format `python -m compile.export` writes and `tf-fpga serve --model`
//! serves.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use tf_fpga::hsa::agent::DeviceType;
use tf_fpga::tf::dtype::DType;
use tf_fpga::tf::graph::{Graph, OpKind};
use tf_fpga::tf::model::{Endpoint, Model, ModelBundle, Signature};
use tf_fpga::tf::session::SessionOptions;
use tf_fpga::tf::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    // 1. Build a graph: x -> FC -> relu, FC pinned to the FPGA (the
    //    paper's `with tf.device(...)` annotation — carried by the bundle).
    let mut g = Graph::new();
    let x = g.placeholder("x", &[2, 4], DType::F32).map_err(err)?;
    let w = g
        .constant(
            "w",
            Tensor::from_f32(&[4, 3], (0..12).map(|i| 0.1 * i as f32).collect())
                .map_err(terr)?,
        )
        .map_err(err)?;
    let b = g
        .constant("b", Tensor::from_f32(&[3], vec![0.5, 0.0, -0.5]).map_err(terr)?)
        .map_err(err)?;
    let y = g.add("y", OpKind::FullyConnected, &[x, w, b]).map_err(err)?;
    g.add("out", OpKind::Relu, &[y]).map_err(err)?;
    g.set_device(y, DeviceType::Fpga);

    // 2. Name the entry point: a signature maps public endpoint names to
    //    graph nodes, with the tensor metas callers must honor.
    let sig = Signature {
        name: "serve".into(),
        inputs: vec![Endpoint::new("features", "x", &[2, 4], DType::F32)],
        outputs: vec![Endpoint::new("scores", "out", &[2, 3], DType::F32)],
    };
    let bundle = ModelBundle::new("quickstart", g, vec![sig]).map_err(err)?;

    // 3. Save and reload: the bundle is a directory holding `model.json`
    //    (GraphDef + signatures) — weights embedded, device pins included.
    let dir = std::env::temp_dir().join("tf_fpga_quickstart_bundle");
    bundle.save(&dir).map_err(err)?;
    println!("saved bundle to {}", dir.join("model.json").display());
    let model = Model::load(&dir, SessionOptions::default()).map_err(err)?;

    // 4. Invoke by endpoint name. First call compiles and caches the
    //    signature's execution plan; later calls replay it.
    let input = Tensor::from_f32(&[2, 4], vec![1.0, 2.0, 3.0, 4.0, -1.0, -2.0, -3.0, -4.0])
        .map_err(terr)?;
    for i in 0..3 {
        let out = model
            .invoke("serve", &[("features", input.clone())])
            .map_err(err)?;
        println!("run {i}: scores = {:?}", out[0].as_f32().map_err(terr)?);
    }

    // 5. Mis-shaped feeds fail by *endpoint*, naming expected vs got —
    //    not a NodeId-level failure deep in the executor.
    let bad = Tensor::zeros(&[5, 4], DType::F32);
    let e = model.invoke("serve", &[("features", bad)]).unwrap_err();
    println!("bad feed rejected: {e}");

    let plans = model.session().plan_cache_stats();
    let s = model.session().reconfig_stats();
    println!(
        "plan cache: {} compile(s), {} replay hit(s); fpga: {} dispatches, {} reconfigs",
        plans.compiles, plans.hits, s.dispatches, s.misses
    );
    assert_eq!(plans.compiles, 1, "one signature = one cached plan");
    model.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

fn err(e: tf_fpga::hsa::error::HsaError) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}

fn terr(e: tf_fpga::tf::tensor::TensorError) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}
