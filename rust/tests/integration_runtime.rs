//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! These tests are the rust-side half of the L1/L2 correctness story: the
//! HLO modules produced by `python/compile/aot.py` (Pallas kernels inside)
//! must agree with the native Rust oracle kernels on the same fixed
//! weights. Tests skip (with a message) when `make artifacts` has not run.

use tf_fpga::ops;
use tf_fpga::runtime::artifact::ArtifactStore;
use tf_fpga::runtime::pjrt::PjrtService;
use tf_fpga::tf::tensor::Tensor;
use tf_fpga::util::prng::Rng;

/// Skip-helper: PJRT needs the `pjrt` cargo feature and a working XLA
/// client; tests skip (like the missing-artifacts case) when absent.
fn pjrt() -> Option<PjrtService> {
    match PjrtService::start() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP (PJRT backend unavailable): {e}");
            None
        }
    }
}

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

fn rand_f32(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut v = vec![0f32; shape.iter().product()];
    rng.fill_f32_normal(&mut v, 0.0, 1.0);
    Tensor::from_f32(shape, v).unwrap()
}

fn rand_i16(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut v = vec![0i16; shape.iter().product()];
    rng.fill_i16(&mut v, -256, 255);
    Tensor::from_i16(shape, v).unwrap()
}

#[test]
fn manifest_lists_all_five_modules() {
    let Some(store) = store() else { return };
    for name in ["role1_fc", "role2_fc_barrier", "role3_conv5x5", "role4_conv3x3", "mnist_cnn"]
    {
        assert!(store.module(name).is_ok(), "missing module {name}");
    }
}

#[test]
fn role1_fc_artifact_matches_native_oracle() {
    let Some(store) = store() else { return };
    let Some(svc) = pjrt() else { return };
    let meta = store.module("role1_fc").unwrap();
    svc.handle().load_module(meta).unwrap();

    let x = rand_f32(&[64, 64], 1);
    let w = rand_f32(&[64, 64], 2);
    let b = rand_f32(&[64], 3);
    let got = svc
        .handle()
        .execute("role1_fc", vec![x.clone(), w.clone(), b.clone()])
        .unwrap();
    let want = ops::fc_f32(&x, &w, &b).unwrap();
    let diff = got[0].max_abs_diff(&want).unwrap();
    assert!(diff < 1e-3, "pallas-FC vs native diff {diff}");
}

#[test]
fn role2_fc_barrier_artifact_matches_role1() {
    let Some(store) = store() else { return };
    let Some(svc) = pjrt() else { return };
    svc.handle().load_module(store.module("role1_fc").unwrap()).unwrap();
    svc.handle()
        .load_module(store.module("role2_fc_barrier").unwrap())
        .unwrap();
    let x = rand_f32(&[64, 64], 5);
    let w = rand_f32(&[64, 64], 6);
    let b = rand_f32(&[64], 7);
    let a = svc
        .handle()
        .execute("role1_fc", vec![x.clone(), w.clone(), b.clone()])
        .unwrap();
    let b2 = svc.handle().execute("role2_fc_barrier", vec![x, w, b]).unwrap();
    let diff = a[0].max_abs_diff(&b2[0]).unwrap();
    assert!(diff < 1e-4, "barrier variant diverged: {diff}");
}

#[test]
fn conv_role_artifacts_match_native_with_manifest_weights() {
    let Some(store) = store() else { return };
    let Some(svc) = pjrt() else { return };
    svc.handle().load_module(store.module("role3_conv5x5").unwrap()).unwrap();
    svc.handle().load_module(store.module("role4_conv3x3").unwrap()).unwrap();
    let (_, w5) = store.load_weight_i16("role3/w").unwrap();
    let (_, w3) = store.load_weight_i16("role4/w").unwrap();
    let shift = store.conv_shift;

    for seed in 0..4 {
        let x = rand_i16(&[1, 28, 28], 40 + seed);
        let got5 = svc.handle().execute("role3_conv5x5", vec![x.clone()]).unwrap();
        let want5 = ops::conv2d_fixed_i16(&x, &w5, 1, 1, 5, 5, shift).unwrap();
        assert_eq!(got5[0], want5, "conv5x5 seed {seed}: int16 must be bit-exact");

        let got3 = svc.handle().execute("role4_conv3x3", vec![x.clone()]).unwrap();
        let want3 = ops::conv2d_fixed_i16(&x, &w3, 2, 1, 3, 3, shift).unwrap();
        assert_eq!(got3[0], want3, "conv3x3 seed {seed}");
    }
}

#[test]
fn mnist_cnn_artifact_matches_native_full_model() {
    let Some(store) = store() else { return };
    let Some(svc) = pjrt() else { return };
    svc.handle().load_module(store.module("mnist_cnn").unwrap()).unwrap();

    // Native full model with the same artifact weights.
    let weights = std::sync::Arc::new(
        tf_fpga::tf::session::WeightBank::load(Some(&store)).unwrap(),
    );
    let native = tf_fpga::tf::session::native_mnist_cnn(&weights);

    let x = rand_f32(&[32, 1, 28, 28], 77);
    let got = svc.handle().execute("mnist_cnn", vec![x.clone()]).unwrap();
    let want = native(&[x]).unwrap();
    assert_eq!(got[0].shape(), &[32, 10]);
    let diff = got[0].max_abs_diff(&want[0]).unwrap();
    assert!(diff < 1e-3, "CNN pallas-vs-native diff {diff}");
}

#[test]
fn shape_validation_rejects_wrong_inputs() {
    let Some(store) = store() else { return };
    let Some(svc) = pjrt() else { return };
    svc.handle().load_module(store.module("role3_conv5x5").unwrap()).unwrap();
    // Wrong shape.
    let bad = Tensor::zeros(&[1, 27, 27], tf_fpga::tf::dtype::DType::I16);
    let err = svc.handle().execute("role3_conv5x5", vec![bad]).unwrap_err();
    assert!(err.to_string().contains("expected"), "{err}");
    // Wrong dtype.
    let bad = Tensor::zeros(&[1, 28, 28], tf_fpga::tf::dtype::DType::F32);
    assert!(svc.handle().execute("role3_conv5x5", vec![bad]).is_err());
    // Wrong arity.
    let x = Tensor::zeros(&[1, 28, 28], tf_fpga::tf::dtype::DType::I16);
    assert!(svc
        .handle()
        .execute("role3_conv5x5", vec![x.clone(), x])
        .is_err());
}

#[test]
fn session_uses_pjrt_for_canonical_role_shapes() {
    // With artifacts present, a (64,64) FC dispatch on the FPGA flows
    // through the PJRT module (hybrid binding); the result must still match
    // the native oracle.
    let Some(_) = store() else { return };
    let mut g = tf_fpga::tf::graph::Graph::new();
    use tf_fpga::tf::dtype::DType;
    use tf_fpga::tf::graph::OpKind;
    let x = g.placeholder("x", &[64, 64], DType::F32).unwrap();
    let w = g.constant("w", rand_f32(&[64, 64], 11)).unwrap();
    let b = g.constant("b", rand_f32(&[64], 12)).unwrap();
    g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
    let sess = tf_fpga::tf::session::Session::new(
        g,
        tf_fpga::tf::session::SessionOptions::default(),
    )
    .unwrap();
    let xv = rand_f32(&[64, 64], 13);
    let out = sess.run(&[("x", xv.clone())], &["y"]).unwrap();
    let want = ops::fc_f32(
        &xv,
        &rand_f32(&[64, 64], 11),
        &rand_f32(&[64], 12),
    )
    .unwrap();
    let diff = out[0].max_abs_diff(&want).unwrap();
    assert!(diff < 1e-3, "hybrid PJRT path diverged: {diff}");
    sess.shutdown();
}
