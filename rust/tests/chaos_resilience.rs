//! Chaos suite: agents stalled, killed and revived **mid-eviction-storm**,
//! with the invariant that the serving pipeline never returns a wrong
//! answer and never hangs a request.
//!
//! The workload is the same layered MNIST storm as
//! `integration_sharding.rs` — four distinct FPGA kernels per request on a
//! pool with one PR region per agent, so every request forces
//! reconfigurations — but here one agent also has deterministic
//! stall/drop faults injected ([`FaultPlan`]) and another is killed and
//! later revived by a choreography thread while requests are in flight.
//! The router's health checks must quarantine the sick agents, the
//! dispatch retry paths must move wedged work onto healthy agents, and a
//! revived agent must be re-admitted — all observable in the
//! `ShardAgentReport` rows.
//!
//! Every completed request must be **bitwise** equal to a fault-free
//! single-agent baseline (identical deterministic weights everywhere), so
//! a retry that double-executes, half-executes, or crosses replies would
//! fail loudly.

use std::sync::Arc;
use std::time::Duration;
use tf_fpga::fpga::device::{FaultPlan, FpgaAgent};
use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig, BatchPolicy, ModelSpec};
use tf_fpga::sharding::{HealthPolicy, ShardStrategy};
use tf_fpga::tf::model::ModelBundle;
use tf_fpga::tf::session::SessionOptions;
use tf_fpga::util::prng::Rng;

const REQUESTS: usize = 12;
const RECV_TIMEOUT: Duration = Duration::from_secs(60);

fn layered_spec() -> ModelSpec {
    // max_batch 1: the layered graph is rank-3 (batch dim must stay 1).
    ModelSpec::from_bundle(
        "layers",
        ModelBundle::mnist_layers_demo(),
        BatchPolicy { max_batch: 1, max_delay: Duration::from_millis(1) },
    )
}

fn images(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            (0..784)
                .map(|p| ((i * 37 + p * 13) % 255) as f32 / 255.0 - 0.5)
                .collect()
        })
        .collect()
}

/// Aggressive health tuning so a test-scale stall (tens of ms) is
/// detected and retried within the test's patience.
fn chaos_health() -> HealthPolicy {
    HealthPolicy {
        stall_threshold: Duration::from_millis(50),
        probe_interval: Duration::from_millis(20),
        // Generous: while one agent is down and another is dropping, a
        // retry can land on the dead agent (an all-quarantined pool voids
        // the eligibility mask) and burn an attempt.
        max_retries: 5,
    }
}

/// Reference logits from a fault-free single-agent server with regions to
/// spare.
fn baseline_logits(images: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let mut baseline = AsyncInferenceServer::start(AsyncServerConfig {
        models: vec![layered_spec()],
        session: SessionOptions {
            num_regions: 4,
            dispatch_workers: 1,
            ..SessionOptions::native_only()
        },
        pipeline_depth: 2,
    })
    .expect("baseline server");
    let want = serve_all(&baseline, images, "baseline");
    baseline.stop();
    want
}

fn chaos_server(pool: usize, strategy: ShardStrategy) -> AsyncInferenceServer {
    AsyncInferenceServer::start(AsyncServerConfig {
        models: vec![layered_spec()],
        session: SessionOptions {
            fpga_pool: pool,
            num_regions: 1, // under-provisioned: the eviction storm
            shard_strategy: strategy,
            dispatch_workers: 1,
            health: chaos_health(),
            ..SessionOptions::native_only()
        },
        pipeline_depth: 4,
    })
    .expect("chaos server")
}

/// Submit everything up front, then harvest with a hard deadline: a hung
/// request fails the test instead of wedging it.
fn serve_all(
    srv: &AsyncInferenceServer,
    images: &[Vec<f32>],
    tag: &str,
) -> Vec<Vec<f32>> {
    let rxs: Vec<_> = images
        .iter()
        .map(|im| srv.infer_async("layers", im.clone()).expect("submit"))
        .collect();
    rxs.into_iter()
        .enumerate()
        .map(|(i, rx)| {
            rx.recv_timeout(RECV_TIMEOUT)
                .unwrap_or_else(|_| panic!("{tag}: request {i} hung past deadline"))
                .unwrap_or_else(|e| panic!("{tag}: request {i} failed: {e}"))
        })
        .collect()
}

/// The headline chaos scenario, fixed seed: pool of three agents, agent 0
/// fault-injected (stalls past the quarantine threshold + hard drops),
/// agent 1 killed ~40 ms into the storm and revived ~250 ms later.
#[test]
fn chaos_kill_stall_revive_keeps_every_answer_bitwise_correct() {
    let images = images(REQUESTS);
    let want = baseline_logits(&images);

    let srv = chaos_server(3, ShardStrategy::KernelAffinity);
    let router = srv.session().router();
    router.agent(0).inject_faults(FaultPlan {
        drop_prob: 0.15,
        stall_prob: 0.35,
        stall: Duration::from_millis(120),
        ..FaultPlan::none(0xC5A0_5EED)
    });
    let victim: Arc<FpgaAgent> = Arc::clone(router.agent(1));

    let got = std::thread::scope(|scope| {
        let choreo = scope.spawn(|| {
            std::thread::sleep(Duration::from_millis(40));
            victim.kill();
            // A health check while the victim is down must quarantine it.
            std::thread::sleep(Duration::from_millis(30));
            let outcome = router.check_health();
            assert!(
                outcome.quarantined.contains(&1) || router.is_quarantined(1),
                "killed agent not quarantined: {outcome:?}"
            );
            std::thread::sleep(Duration::from_millis(180));
            victim.revive();
            router.agent(0).clear_faults();
        });
        let got = serve_all(&srv, &images, "chaos");
        choreo.join().unwrap();
        got
    });

    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert_eq!(a, b, "request {i} logits diverged under chaos");
    }

    // Let any abandoned stall finish, then one more health pass so the
    // revived agent's re-admission is on the books.
    std::thread::sleep(Duration::from_millis(200));
    let outcome = srv.session().router().check_health();
    let rep = srv.report();
    assert_eq!(rep.completed, REQUESTS as u64, "{rep:?}");
    assert_eq!(rep.failed, 0, "{rep:?}");
    assert_eq!(rep.pool.len(), 3);
    let quarantines: u64 = rep.pool.iter().map(|p| p.quarantines).sum();
    let readmissions: u64 = rep.pool.iter().map(|p| p.readmissions).sum();
    assert!(quarantines >= 1, "no quarantine recorded: {:?}", rep.pool);
    assert!(
        readmissions >= 1,
        "no re-admission recorded (outcome {outcome:?}): {:?}",
        rep.pool
    );
    // Every agent healthy again: nothing quarantined, nothing in flight.
    assert!(
        rep.pool.iter().all(|p| p.alive && !p.quarantined),
        "pool did not recover: {:?}",
        rep.pool
    );
    assert_eq!(
        rep.pool.iter().map(|p| p.inflight).sum::<u64>(),
        0,
        "in-flight gauges leaked (zombie not reaped?): {:?}",
        rep.pool
    );
    drop(srv);
}

/// The same choreography across a sweep of seeds, pool sizes and routing
/// strategies: whatever the fault timing lands on, zero wrong answers and
/// zero hung requests.
#[test]
fn chaos_seed_sweep_never_returns_a_wrong_answer() {
    let images = images(6);
    let want = baseline_logits(&images);

    for seed in 1..=8u64 {
        let mut rng = Rng::new(seed);
        let pool = 2 + (rng.below(3) as usize); // 2..=4 agents
        let strategy = *rng.choose(&ShardStrategy::ALL);
        let faulty = rng.below(pool as u64) as usize;
        let victim = (faulty + 1 + rng.below(pool as u64 - 1) as usize) % pool;
        let tag = format!("seed {seed} pool {pool} {strategy:?} f{faulty} v{victim}");

        let srv = chaos_server(pool, strategy);
        let router = srv.session().router();
        router.agent(faulty).inject_faults(FaultPlan {
            drop_prob: 0.05 + 0.1 * rng.f64(),
            stall_prob: 0.2 + 0.2 * rng.f64(),
            stall: Duration::from_millis(60 + rng.below(80)),
            slow_prob: 0.2,
            slow: Duration::from_millis(rng.below(20)),
            ..FaultPlan::none(seed.wrapping_mul(0x9E37_79B9))
        });
        let victim_agent: Arc<FpgaAgent> = Arc::clone(router.agent(victim));
        let kill_at = Duration::from_millis(20 + rng.below(60));
        let down_for = Duration::from_millis(100 + rng.below(150));

        let got = std::thread::scope(|scope| {
            let choreo = scope.spawn(|| {
                std::thread::sleep(kill_at);
                victim_agent.kill();
                std::thread::sleep(down_for);
                victim_agent.revive();
                router.agent(faulty).clear_faults();
            });
            let got = serve_all(&srv, &images, &tag);
            choreo.join().unwrap();
            got
        });

        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a, b, "{tag}: request {i} logits diverged");
        }
        let rep = srv.report();
        assert_eq!(rep.completed, images.len() as u64, "{tag}: {rep:?}");
        assert_eq!(rep.failed, 0, "{tag}: {rep:?}");
        drop(srv);
    }
}

/// Quarantine + retry accounting must close over the storm: retries only
/// happen on quarantined-or-dead agents, and the pooled rollup sums the
/// per-slot counters.
#[test]
fn chaos_report_rollup_sums_resilience_counters() {
    let images = images(REQUESTS);
    let srv = chaos_server(2, ShardStrategy::LeastLoaded);
    let router = srv.session().router();
    // Pure drop faults: every faulted dispatch fails fast with an
    // agent-down error, so the retry path (not the stall path) drives
    // quarantine here.
    router.agent(0).inject_faults(FaultPlan {
        drop_prob: 0.5,
        ..FaultPlan::none(7)
    });
    let got = serve_all(&srv, &images, "drop-faults");
    assert_eq!(got.len(), REQUESTS);
    router.agent(0).clear_faults();

    let rep = srv.report();
    assert_eq!(rep.failed, 0, "drops must be retried, not surfaced: {rep:?}");
    let rollup = router.rollup();
    let per_slot: u64 = rep.pool.iter().map(|p| p.retries).sum();
    assert_eq!(rollup.retries, per_slot, "rollup retries mismatch");
    assert_eq!(
        rollup.quarantines,
        rep.pool.iter().map(|p| p.quarantines).sum::<u64>(),
        "rollup quarantines mismatch"
    );
    // With drop_prob 0.5 over ~48 dispatches, at least one drop is
    // statistically certain (p < 1e-14 otherwise) — and every drop must
    // have been retried.
    assert!(per_slot >= 1, "no retry recorded under 50% drop faults: {rep:?}");
    drop(srv);
}
