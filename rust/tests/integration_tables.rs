//! Integration: the paper's tables regenerate with the published shape
//! (small n so the suite stays fast; the benches run the full n=1000).

use tf_fpga::bench::tables;
use tf_fpga::fpga::resources::ResourceVector;

#[test]
fn table1_reproduces_published_rows() {
    let rows = tables::table1_rows();
    let by_label = |l: &str| rows.iter().find(|(label, _, _)| *label == l).unwrap().1;
    assert_eq!(by_label("Shell"), ResourceVector::new(9915, 8544, 10, 0));
    assert_eq!(by_label("Role 1").luts, 9984);
    assert_eq!(by_label("Role 2"), ResourceVector::new(9501, 7851, 23, 8));
    assert_eq!(by_label("Role 3"), ResourceVector::new(5091, 4935, 21, 6));
    let r4 = by_label("Role 4");
    assert!((r4.luts as i64 - 7881).abs() <= 1);
    assert_eq!((r4.ffs, r4.bram36, r4.dsps), (7926, 21, 12));
}

#[test]
fn table1_shell_plus_two_roles_fit_the_device() {
    // The published design holds a shell + 2 resident roles; the totals
    // must fit the ZU3EG.
    let rows = tables::table1_rows();
    let total = rows[0].1 + rows[2].1 + rows[4].1; // shell + role2 + role4
    assert!(total.fits_in(&tf_fpga::fpga::resources::ZU3EG), "{total}");
}

#[test]
fn table3_ratios_within_three_percent_of_paper() {
    for row in tables::table3_measure(2) {
        let err = (row.increase - row.paper_increase).abs() / row.paper_increase;
        assert!(
            err < 0.03,
            "{}: {:.3}x vs {:.2}x",
            row.role,
            row.increase,
            row.paper_increase
        );
    }
}

#[test]
fn table2_orderings_hold() {
    let m = tables::table2_measure(30, false);
    assert!(m.tf_setup_us > m.hsa_setup_us);
    assert!((m.reconfig_us - 7424.0).abs() < 100.0, "{}", m.reconfig_us);
    // (with PJRT artifact compilation the setup row also dominates the
    // reconfiguration row; that configuration is exercised by the
    // table2_overhead bench, which needs built artifacts)
    assert!(m.reconfig_us > m.tf_dispatch_us * 10.0);
}

#[test]
fn table_rendering_contains_paper_reference_rows() {
    let t1 = tables::table1().to_string();
    assert!(t1.contains("9915 (14.1%)"));
    assert!(t1.contains("5091 (7.2%)"));
    let (t3, _) = tables::table3(2);
    let s3 = t3.to_string();
    assert!(s3.contains("OP/cycle increase"));
    assert!(s3.contains("Role 4"));
}
