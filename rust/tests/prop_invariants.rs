//! Property-based tests over coordinator invariants (reconfiguration
//! manager, queue ordering, signals, JSON, tensors, and plan-vs-interpreter
//! execution equivalence) using the in-tree quickcheck harness
//! (`util::quickcheck`).

use tf_fpga::fpga::bitstream::Bitstream;
use tf_fpga::fpga::icap::Icap;
use tf_fpga::fpga::resources::ResourceVector;
use tf_fpga::fpga::roles::role3_spec;
use tf_fpga::reconfig::manager::ReconfigManager;
use tf_fpga::reconfig::policy::{BeladyOracle, PolicyKind};
use tf_fpga::util::quickcheck::{forall, Gen, U64Range, VecGen};
use tf_fpga::util::prng::Rng;

fn mk_bitstreams(k: usize) -> Vec<Bitstream> {
    (0..k)
        .map(|i| {
            Bitstream::new(
                format!("r{i}"),
                1000,
                ResourceVector::new(10, 10, 1, 1),
                role3_spec(),
            )
        })
        .collect()
}

/// Generator for (num_regions, num_roles, trace).
struct TraceGen;

impl Gen for TraceGen {
    type Value = (usize, usize, Vec<usize>);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let regions = 1 + rng.below(4) as usize;
        let roles = 1 + rng.below(6) as usize;
        let len = 1 + rng.below(300) as usize;
        let trace = (0..len).map(|_| rng.below(roles as u64) as usize).collect();
        (regions, roles, trace)
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let (r, k, t) = v;
        let mut out = Vec::new();
        if t.len() > 1 {
            out.push((*r, *k, t[..t.len() / 2].to_vec()));
            out.push((*r, *k, t[1..].to_vec()));
        }
        out
    }
}

fn run_policy(
    regions: usize,
    bitstreams: &[Bitstream],
    trace: &[usize],
    policy: Box<dyn tf_fpga::reconfig::policy::EvictionPolicy>,
) -> (ReconfigManager, tf_fpga::reconfig::manager::ReconfigStats) {
    let mut mgr = ReconfigManager::with_uniform_regions(
        regions,
        ResourceVector::new(100, 100, 10, 10),
        policy,
        Icap::new(1000.0, 0),
    );
    for &i in trace {
        mgr.ensure_loaded(&bitstreams[i]).unwrap();
    }
    let stats = mgr.stats();
    (mgr, stats)
}

#[test]
fn prop_accounting_always_closes() {
    forall(1, 120, &TraceGen, |(regions, roles, trace)| {
        let bs = mk_bitstreams(*roles);
        for kind in PolicyKind::ALL {
            let (mgr, s) = run_policy(*regions, &bs, trace, kind.build(3));
            if s.hits + s.misses != s.dispatches {
                return Err(format!("{kind:?}: hits+misses != dispatches ({s:?})"));
            }
            if s.dispatches != trace.len() as u64 {
                return Err("dispatch count mismatch".into());
            }
            // Evictions can't exceed misses; misses at least cold set size.
            if s.evictions > s.misses {
                return Err(format!("{kind:?}: evictions > misses"));
            }
            let distinct = {
                let mut t = trace.clone();
                t.sort();
                t.dedup();
                t.len()
            };
            if (s.misses as usize) < distinct.min(*regions).min(trace.len()) {
                return Err("fewer misses than cold loads".into());
            }
            // Residency map bijective with occupied regions.
            let occupied: Vec<_> =
                mgr.regions().iter().filter(|r| r.loaded.is_some()).collect();
            for r in &occupied {
                if mgr.region_of(r.loaded.unwrap()) != Some(r.id) {
                    return Err("residency map out of sync".into());
                }
            }
            if occupied.len() > *regions {
                return Err("more residents than regions".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_working_set_fits_then_no_evictions_after_warmup() {
    forall(2, 100, &TraceGen, |(regions, roles, trace)| {
        if roles > regions {
            return Ok(()); // only the fitting case here
        }
        let bs = mk_bitstreams(*roles);
        let (_, s) = run_policy(*regions, &bs, trace, PolicyKind::Lru.build(0));
        if s.evictions != 0 {
            return Err(format!("evicted although all {roles} roles fit {regions} regions"));
        }
        let distinct = {
            let mut t = trace.clone();
            t.sort();
            t.dedup();
            t.len()
        };
        if s.misses as usize != distinct {
            return Err(format!("misses {} != cold loads {distinct}", s.misses));
        }
        Ok(())
    });
}

#[test]
fn prop_belady_dominates_online_policies() {
    forall(3, 60, &TraceGen, |(regions, roles, trace)| {
        let bs = mk_bitstreams(*roles);
        let oracle = BeladyOracle::new(trace.iter().map(|&i| bs[i].id).collect());
        let (_, belady) = run_policy(*regions, &bs, trace, Box::new(oracle));
        for kind in PolicyKind::ALL {
            let (_, online) = run_policy(*regions, &bs, trace, kind.build(9));
            if online.hits > belady.hits {
                return Err(format!(
                    "{:?} ({} hits) beat Belady ({} hits)",
                    kind, online.hits, belady.hits
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_reconfig_time_equals_miss_count_times_cost() {
    forall(4, 80, &TraceGen, |(regions, roles, trace)| {
        let bs = mk_bitstreams(*roles);
        let (_, s) = run_policy(*regions, &bs, trace, PolicyKind::Lru.build(0));
        // Icap::new(1000.0, 0) and 1000-byte bitstreams: 1 µs per miss.
        if s.reconfig_us_total != s.misses {
            return Err(format!(
                "reconfig time {} != misses {}",
                s.reconfig_us_total, s.misses
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_queue_preserves_fifo_under_random_batch_sizes() {
    use tf_fpga::hsa::packet::AqlPacket;
    use tf_fpga::hsa::queue::Queue;
    use tf_fpga::hsa::signal::Signal;
    let gen = VecGen { inner: U64Range(1, 64), min_len: 1, max_len: 40 };
    forall(5, 60, &gen, |batches| {
        let q = Queue::new(128);
        let mut expected = Vec::new();
        let mut next = 0u64;
        for &batch in batches {
            for _ in 0..batch {
                let (pkt, _) = AqlPacket::dispatch(next, vec![], Signal::new(1));
                q.enqueue(pkt).map_err(|e| e.to_string())?;
                expected.push(next);
                next += 1;
            }
            // Drain the batch.
            for _ in 0..batch {
                match q.dequeue_blocking() {
                    Some(AqlPacket::KernelDispatch(d)) => {
                        let want = expected.remove(0);
                        if d.kernel_object != want {
                            return Err(format!(
                                "out of order: got {} want {want}",
                                d.kernel_object
                            ));
                        }
                    }
                    other => return Err(format!("unexpected {other:?}")),
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_signal_arithmetic_matches_i64() {
    use tf_fpga::hsa::signal::Signal;
    let gen = VecGen { inner: U64Range(0, 200), min_len: 1, max_len: 50 };
    forall(6, 80, &gen, |ops| {
        let s = Signal::new(0);
        let mut model = 0i64;
        for (i, &v) in ops.iter().enumerate() {
            let d = v as i64 - 100;
            if i % 3 == 2 {
                s.store(d);
                model = d;
            } else {
                s.add(d);
                model += d;
            }
            if s.load() != model {
                return Err(format!("signal {} != model {model}", s.load()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_numbers_round_trip() {
    use tf_fpga::util::json::Json;
    let gen = VecGen { inner: U64Range(0, u64::MAX >> 12), min_len: 1, max_len: 20 };
    forall(7, 100, &gen, |nums| {
        let doc = format!(
            "[{}]",
            nums.iter().map(|n| n.to_string()).collect::<Vec<_>>().join(",")
        );
        let parsed = Json::parse(&doc).map_err(|e| e.to_string())?;
        let arr = parsed.as_arr().ok_or("not an array")?;
        for (n, v) in nums.iter().zip(arr) {
            if v.as_usize() != Some(*n as usize) {
                return Err(format!("{n} round-tripped to {v:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tensor_reshape_preserves_data() {
    use tf_fpga::tf::tensor::Tensor;
    let gen = U64Range(1, 256);
    forall(8, 100, &gen, |&n| {
        let n = n as usize;
        let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let t = Tensor::from_f32(&[n], data.clone()).map_err(|e| e.to_string())?;
        // All factorizations n = a*b must reshape losslessly.
        for a in 1..=n {
            if n % a == 0 {
                let b = n / a;
                let r = t.reshape(&[a, b]).map_err(|e| e.to_string())?;
                if r.as_f32().map_err(|e| e.to_string())? != data.as_slice() {
                    return Err(format!("reshape [{a},{b}] lost data"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Plan replay ≡ interpreted executor
// ---------------------------------------------------------------------------

mod plan_equivalence {
    use std::collections::HashMap;
    use std::sync::Arc;
    use tf_fpga::cpu::a53::CpuKernelClass;
    use tf_fpga::cpu::device::{CpuAgent, CpuKernel};
    use tf_fpga::hsa::agent::DeviceType;
    use tf_fpga::hsa::error::Result;
    use tf_fpga::hsa::queue::Queue;
    use tf_fpga::hsa::runtime::HsaRuntime;
    use tf_fpga::tf::dtype::DType;
    use tf_fpga::tf::executor::{self, ExecEnv};
    use tf_fpga::tf::graph::{Graph, OpKind};
    use tf_fpga::tf::kernel::{fused_relu_name, KernelRegistry};
    use tf_fpga::tf::placer::{place, PlacerOptions};
    use tf_fpga::tf::plan::{ExecutionPlan, PlanOptions};
    use tf_fpga::tf::tensor::Tensor;
    use tf_fpga::util::prng::Rng;
    use tf_fpga::util::quickcheck::{forall, Gen};

    fn cpu_env() -> (HsaRuntime, HashMap<DeviceType, Queue>, KernelRegistry) {
        let cpu = CpuAgent::with_defaults();
        let mut reg = KernelRegistry::new();
        let mut add = |name: String,
                       f: Arc<dyn Fn(&[Tensor]) -> Result<Vec<Tensor>> + Send + Sync>| {
            let id = cpu.register_kernel(CpuKernel {
                name: name.clone(),
                func: f,
                class: CpuKernelClass::Memory,
                op_template: None,
            });
            reg.register(name, DeviceType::Cpu, id);
        };
        add(
            "fc".into(),
            Arc::new(|ins| Ok(vec![tf_fpga::ops::fc_f32(&ins[0], &ins[1], &ins[2])?])),
        );
        add(
            fused_relu_name("fc"),
            Arc::new(|ins| Ok(vec![tf_fpga::ops::fc_relu_f32(&ins[0], &ins[1], &ins[2])?])),
        );
        add("relu".into(), Arc::new(|ins| Ok(vec![tf_fpga::ops::relu_f32(&ins[0])?])));
        add(
            "softmax".into(),
            Arc::new(|ins| Ok(vec![tf_fpga::ops::softmax_f32(&ins[0])?])),
        );
        add(
            "add".into(),
            Arc::new(|ins| Ok(vec![tf_fpga::ops::add_f32(&ins[0], &ins[1])?])),
        );
        let rt = HsaRuntime::builder().with_agent(cpu).build();
        let q = rt.create_queue(rt.agent_by_type(DeviceType::Cpu).unwrap(), 128);
        let mut queues = HashMap::new();
        queues.insert(DeviceType::Cpu, q);
        (rt, queues, reg)
    }

    /// Random small rank-2 f32 graphs: chains and diamonds of
    /// Relu/Softmax/FC/Add/Reshape over a placeholder plus random
    /// constants (which make const-only subgraphs for the folding pass).
    /// (`pub`: the sharding properties below reuse the same case space.)
    pub struct GraphCase;

    impl Gen for GraphCase {
        type Value = (u64, Vec<u8>);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            let len = 1 + rng.below(10) as usize;
            (rng.next_u64(), (0..len).map(|_| rng.below(240) as u8).collect())
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            let (seed, ops) = v;
            let mut out = Vec::new();
            if ops.len() > 1 {
                out.push((*seed, ops[..ops.len() / 2].to_vec()));
                out.push((*seed, ops[1..].to_vec()));
                let mut m = ops.clone();
                m.pop();
                out.push((*seed, m));
            }
            out
        }
    }

    /// Build the graph; returns it plus the fetch names (the final node
    /// and one random interior node).
    pub fn build(seed: u64, ops: &[u8]) -> (Graph, Vec<String>) {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new();
        let x = g.placeholder("x", &[2, 3], DType::F32).unwrap();
        let mut nodes = vec![(x, 3usize)];
        for (i, &op) in ops.iter().enumerate() {
            let (src, cols) = nodes[rng.below(nodes.len() as u64) as usize];
            let made = match op % 6 {
                0 => (g.add(format!("relu{i}"), OpKind::Relu, &[src]).unwrap(), cols),
                1 => (g.add(format!("soft{i}"), OpKind::Softmax, &[src]).unwrap(), cols),
                2 => {
                    let n = 1 + rng.below(4) as usize;
                    let mut wv = vec![0f32; cols * n];
                    rng.fill_f32_normal(&mut wv, 0.0, 0.5);
                    let mut bv = vec![0f32; n];
                    rng.fill_f32_normal(&mut bv, 0.0, 0.5);
                    let w = g
                        .constant(format!("w{i}"), Tensor::from_f32(&[cols, n], wv).unwrap())
                        .unwrap();
                    let b = g
                        .constant(format!("b{i}"), Tensor::from_f32(&[n], bv).unwrap())
                        .unwrap();
                    (
                        g.add(format!("fc{i}"), OpKind::FullyConnected, &[src, w, b])
                            .unwrap(),
                        n,
                    )
                }
                3 => (g.add(format!("dbl{i}"), OpKind::Add, &[src, src]).unwrap(), cols),
                4 => (
                    g.add(
                        format!("rs{i}"),
                        OpKind::Reshape { shape: vec![2, cols] },
                        &[src],
                    )
                    .unwrap(),
                    cols,
                ),
                _ => {
                    // Fresh constant source: seeds const-only subgraphs.
                    let mut cv = vec![0f32; 4];
                    rng.fill_f32_normal(&mut cv, 0.0, 1.0);
                    (
                        g.constant(format!("c{i}"), Tensor::from_f32(&[2, 2], cv).unwrap())
                            .unwrap(),
                        2,
                    )
                }
            };
            nodes.push(made);
        }
        let last = g.node(nodes.last().unwrap().0).name.clone();
        let mid = g
            .node(nodes[rng.below(nodes.len() as u64) as usize].0)
            .name
            .clone();
        (g, vec![last, mid])
    }

    #[test]
    fn prop_graphdef_json_round_trip_preserves_graph_and_plan_outputs() {
        use tf_fpga::hsa::agent::DeviceType;
        use tf_fpga::tf::model::{graph_from_json, graph_to_json};
        use tf_fpga::util::json::Json;

        forall(13, 40, &GraphCase, |(seed, ops)| {
            let (mut g, fetches) = build(*seed, ops);
            g.finalize().map_err(|e| e.to_string())?;
            // Random device annotation so the round trip must carry it.
            let mut rng = Rng::new(seed ^ 0xD0D0);
            let annotated = tf_fpga::tf::graph::NodeId(
                rng.below(g.len() as u64) as usize
            );
            g.set_device(annotated, DeviceType::Cpu);

            // Serialize through the *string* form, as a bundle on disk would.
            let doc = graph_to_json(&g).to_string();
            let parsed = Json::parse(&doc).map_err(|e| format!("reparse: {e}"))?;
            let mut g2 = graph_from_json(&parsed).map_err(|e| format!("decode: {e}"))?;
            g2.finalize().map_err(|e| format!("refinalize: {e}"))?;

            // Node count, names, topology and device annotations survive.
            if g.len() != g2.len() {
                return Err(format!("node count {} -> {}", g.len(), g2.len()));
            }
            for (a, b) in g.nodes().iter().zip(g2.nodes()) {
                if a.name != b.name {
                    return Err(format!("name '{}' -> '{}'", a.name, b.name));
                }
                if a.inputs != b.inputs {
                    return Err(format!("inputs of '{}' changed", a.name));
                }
                if a.device != b.device {
                    return Err(format!("device of '{}' changed", a.name));
                }
                if a.out_shape != b.out_shape || a.out_dtype != b.out_dtype {
                    return Err(format!("inferred meta of '{}' changed", a.name));
                }
            }

            // Same registry places both graphs identically...
            let (rt, queues, reg) = cpu_env();
            let p1 = place(&g, &reg, PlacerOptions::default()).map_err(|e| e.to_string())?;
            let p2 = place(&g2, &reg, PlacerOptions::default()).map_err(|e| e.to_string())?;
            if p1.by_node != p2.by_node {
                return Err("placements diverged after round trip".into());
            }

            // ...and the compiled-plan path produces bitwise-identical
            // outputs on both sides of the round trip.
            let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
            let mut xv = vec![0f32; 6];
            Rng::new(seed ^ 0x5A5A).fill_f32_normal(&mut xv, 0.0, 1.0);
            let mut feeds = HashMap::new();
            feeds.insert("x".to_string(), Tensor::from_f32(&[2, 3], xv).unwrap());
            let fetch_refs: Vec<&str> = fetches.iter().map(|s| s.as_str()).collect();
            let opts = PlanOptions::default();
            let plan1 = ExecutionPlan::compile(&g, &p1, &reg, &env, &fetch_refs, opts)
                .map_err(|e| format!("compile original: {e}"))?;
            let plan2 = ExecutionPlan::compile(&g2, &p2, &reg, &env, &fetch_refs, opts)
                .map_err(|e| format!("compile round-tripped: {e}"))?;
            let (want, _) = plan1.replay(&env, &feeds).map_err(|e| e.to_string())?;
            let (got, _) = plan2.replay(&env, &feeds).map_err(|e| e.to_string())?;
            for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                if a != b {
                    return Err(format!(
                        "fetch '{}' diverged after GraphDef round trip",
                        fetch_refs[k]
                    ));
                }
            }
            rt.shutdown();
            Ok(())
        });
    }

    #[test]
    fn prop_plan_replay_bitwise_matches_interpreter_with_and_without_fusion() {
        forall(11, 40, &GraphCase, |(seed, ops)| {
            let (mut g, fetches) = build(*seed, ops);
            g.finalize().map_err(|e| e.to_string())?;
            let (rt, queues, reg) = cpu_env();
            let placement =
                place(&g, &reg, PlacerOptions::default()).map_err(|e| e.to_string())?;
            let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
            let mut xv = vec![0f32; 6];
            Rng::new(seed ^ 0x9E3779B9).fill_f32_normal(&mut xv, 0.0, 1.0);
            let mut feeds = HashMap::new();
            feeds.insert("x".to_string(), Tensor::from_f32(&[2, 3], xv).unwrap());
            let fetch_refs: Vec<&str> = fetches.iter().map(|s| s.as_str()).collect();

            let (want, _) = executor::run(&g, &placement, &env, &feeds, &fetch_refs)
                .map_err(|e| format!("interpreter: {e}"))?;
            for fusion in [false, true] {
                for fold_constants in [false, true] {
                    let opts = PlanOptions { fusion, fold_constants };
                    let plan =
                        ExecutionPlan::compile(&g, &placement, &reg, &env, &fetch_refs, opts)
                            .map_err(|e| format!("compile {opts:?}: {e}"))?;
                    plan.validate().map_err(|e| format!("validate {opts:?}: {e}"))?;
                    let (got, _) = plan
                        .replay(&env, &feeds)
                        .map_err(|e| format!("replay {opts:?}: {e}"))?;
                    for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                        if a != b {
                            return Err(format!(
                                "fetch '{}' diverged under {opts:?}",
                                fetch_refs[k]
                            ));
                        }
                    }
                }
            }
            rt.shutdown();
            Ok(())
        });
    }
}

// ---------------------------------------------------------------------------
// Multi-FPGA sharding: pooled replay ≡ single-agent replay, and
// deterministic kernel-affinity placement
// ---------------------------------------------------------------------------

mod sharding_props {
    use tf_fpga::sharding::ShardStrategy;
    use tf_fpga::tf::session::{Session, SessionOptions};
    use tf_fpga::tf::tensor::Tensor;
    use tf_fpga::util::prng::Rng;
    use tf_fpga::util::quickcheck::forall;

    /// For random graphs, any pool size and every shard strategy, pooled
    /// replay is bitwise identical to single-agent replay: sharding moves
    /// dispatches between agents, never changes what they compute. (All
    /// pool members run the same native numerics, so any divergence means
    /// the router corrupted routing, inputs or result delivery.)
    #[test]
    fn prop_pooled_replay_bitwise_matches_single_agent() {
        forall(17, 12, &super::plan_equivalence::GraphCase, |(seed, ops)| {
            let (g, fetches) = super::plan_equivalence::build(*seed, ops);
            let fetch_refs: Vec<&str> = fetches.iter().map(|s| s.as_str()).collect();
            let mut xv = vec![0f32; 6];
            Rng::new(seed ^ 0x0055AA).fill_f32_normal(&mut xv, 0.0, 1.0);
            let x = Tensor::from_f32(&[2, 3], xv).map_err(|e| e.to_string())?;
            let feeds = [("x", x)];

            let single = Session::new(g.clone(), SessionOptions::native_only())
                .map_err(|e| format!("single session: {e}"))?;
            let want = single
                .run(&feeds, &fetch_refs)
                .map_err(|e| format!("single run: {e}"))?;
            single.shutdown();

            let pool_size = 2 + (seed % 3) as usize; // 2..=4 agents
            for strategy in ShardStrategy::ALL {
                let opts = SessionOptions {
                    fpga_pool: pool_size,
                    shard_strategy: strategy,
                    ..SessionOptions::native_only()
                };
                let pooled = Session::new(g.clone(), opts)
                    .map_err(|e| format!("pool-{pool_size} session: {e}"))?;
                // Two replays per pooled session: the second exercises
                // warm residency / different routing state.
                for round in 0..2 {
                    let got = pooled
                        .run(&feeds, &fetch_refs)
                        .map_err(|e| format!("{strategy:?} run: {e}"))?;
                    for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                        if a != b {
                            return Err(format!(
                                "fetch '{}' diverged on pool {pool_size} \
                                 {strategy:?} round {round}",
                                fetch_refs[k]
                            ));
                        }
                    }
                }
                if pooled.router().rollup().inflight != 0 {
                    return Err(format!("{strategy:?}: in-flight gauge leaked"));
                }
                pooled.shutdown();
            }
            Ok(())
        });
    }

    /// Predictive reconfiguration is invisible to the numerics: for
    /// random graphs, any pool size (including the paper's single
    /// device) and every shard strategy, replay with the prefetch
    /// scheduler enabled is bitwise identical to a plain single-agent
    /// session — prefetching moves ICAP transfers off the critical
    /// path, never changes what a kernel computes — and the
    /// reconfiguration accounting still closes exactly once per
    /// dispatch.
    #[test]
    fn prop_prefetch_preserves_bitwise_outputs() {
        use tf_fpga::reconfig::PrefetchPolicy;
        forall(37, 10, &super::plan_equivalence::GraphCase, |(seed, ops)| {
            let (g, fetches) = super::plan_equivalence::build(*seed, ops);
            let fetch_refs: Vec<&str> = fetches.iter().map(|s| s.as_str()).collect();
            let mut xv = vec![0f32; 6];
            Rng::new(seed ^ 0x9F27).fill_f32_normal(&mut xv, 0.0, 1.0);
            let x = Tensor::from_f32(&[2, 3], xv).map_err(|e| e.to_string())?;
            let feeds = [("x", x)];

            let single = Session::new(g.clone(), SessionOptions::native_only())
                .map_err(|e| format!("single session: {e}"))?;
            let want = single
                .run(&feeds, &fetch_refs)
                .map_err(|e| format!("single run: {e}"))?;
            single.shutdown();

            let pool_size = 1 + (seed % 4) as usize; // 1..=4 agents
            let depth = 1 + (seed >> 4) as usize % 3; // 1..=3 ahead
            for strategy in ShardStrategy::ALL {
                let opts = SessionOptions {
                    fpga_pool: pool_size,
                    shard_strategy: strategy,
                    prefetch: PrefetchPolicy::with_depth(depth),
                    ..SessionOptions::native_only()
                };
                let prefetching = Session::new(g.clone(), opts)
                    .map_err(|e| format!("prefetch session: {e}"))?;
                // Two replays: the second runs against prefetched /
                // mid-transfer residency instead of a cold fabric.
                for round in 0..2 {
                    let got = prefetching
                        .run(&feeds, &fetch_refs)
                        .map_err(|e| format!("{strategy:?} prefetch run: {e}"))?;
                    for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                        if a != b {
                            return Err(format!(
                                "fetch '{}' diverged with prefetch depth {depth} \
                                 (pool {pool_size}, {strategy:?}, round {round})",
                                fetch_refs[k]
                            ));
                        }
                    }
                }
                let rc = prefetching.reconfig_stats();
                if rc.hits + rc.misses != rc.dispatches {
                    return Err(format!(
                        "{strategy:?}: dispatch accounting broke under prefetch: {rc:?}"
                    ));
                }
                if rc.prefetch_hits + rc.prefetch_wasted > rc.prefetches {
                    return Err(format!(
                        "{strategy:?}: more prefetch outcomes than prefetches: {rc:?}"
                    ));
                }
                if prefetching.router().rollup().inflight != 0 {
                    return Err(format!("{strategy:?}: in-flight gauge leaked"));
                }
                prefetching.shutdown();
            }
            Ok(())
        });
    }

    /// The prefetch scheduler is a pure function of the observed call
    /// sequence: twin pools fed the identical interleaving of
    /// dispatch-execute, horizon pumps and demand pumps end with
    /// identical placements, identical prefetch decisions and identical
    /// per-agent reconfiguration accounting. (Single-threaded on
    /// purpose: this pins the decision logic, not thread scheduling.)
    #[test]
    fn prop_prefetch_decisions_are_deterministic() {
        use std::sync::Arc;
        use tf_fpga::fpga::device::{ComputeBinding, FpgaConfig};
        use tf_fpga::fpga::roles::paper_roles;
        use tf_fpga::hsa::agent::Agent;
        use tf_fpga::hsa::packet::AqlPacket;
        use tf_fpga::hsa::queue::Queue;
        use tf_fpga::hsa::signal::Signal;
        use tf_fpga::reconfig::policy::PolicyKind;
        use tf_fpga::reconfig::{KernelHorizon, PrefetchPolicy, PrefetchScheduler};
        use tf_fpga::sharding::{FpgaPool, Router};
        use tf_fpga::util::quickcheck::{U64Range, VecGen};

        struct Harness {
            router: Router,
            scheduler: PrefetchScheduler,
            horizon: KernelHorizon,
            ids: Vec<u64>,
        }

        impl Harness {
            fn new(agents: usize) -> Harness {
                let pool = FpgaPool::new(agents, |i| FpgaConfig {
                    num_regions: 2,
                    policy: PolicyKind::QueueAware.build(i as u64),
                    realtime: false,
                    realtime_scale: 1.0,
                    trace: None,
                });
                let echo = ComputeBinding::Native(Arc::new(
                    |ins: &[tf_fpga::tf::tensor::Tensor]| Ok(ins.to_vec()),
                ));
                let ids: Vec<u64> = paper_roles()
                    .into_iter()
                    .map(|r| pool.register_role(r, echo.clone()))
                    .collect();
                let slots = pool
                    .agents()
                    .iter()
                    .map(|a| (Arc::clone(a), Queue::new(8)))
                    .collect();
                let horizon =
                    KernelHorizon::new(ids.iter().cycle().take(12).copied().collect());
                Harness {
                    router: Router::new(slots, ShardStrategy::KernelAffinity),
                    scheduler: PrefetchScheduler::new(PrefetchPolicy::with_depth(2)),
                    horizon,
                    ids,
                }
            }

            /// Apply one op; `Some(agent)` when the op was a routed
            /// dispatch (executed immediately, so residency and the
            /// virtual ICAP clock advance deterministically).
            fn apply(&mut self, op: u64) -> Option<usize> {
                match op % 4 {
                    0 | 1 => {
                        let ko = self.ids[(op / 4) as usize % self.ids.len()];
                        let (idx, _q, _guard) = self.router.route(ko);
                        let x = tf_fpga::tf::tensor::Tensor::from_f32(
                            &[1],
                            vec![op as f32],
                        )
                        .unwrap();
                        let (pkt, _args) =
                            AqlPacket::dispatch(ko, vec![x], Signal::new(1));
                        if let AqlPacket::KernelDispatch(d) = pkt {
                            self.router.agent(idx).execute(&d).unwrap();
                        }
                        Some(idx)
                    }
                    2 => {
                        let cursor = (op / 4) as usize % (self.horizon.len() + 1);
                        self.scheduler.pump(&self.router, &self.horizon, cursor);
                        None
                    }
                    _ => {
                        let ko = self.ids[(op / 4) as usize % self.ids.len()];
                        self.router.hint_demand(ko, op % 7);
                        self.scheduler.pump_demand(&self.router);
                        None
                    }
                }
            }
        }

        let gen = VecGen { inner: U64Range(0, 1 << 22), min_len: 1, max_len: 100 };
        forall(43, 30, &gen, |ops| {
            let agents = 1 + (ops.len() % 3); // 1..=3
            let mut a = Harness::new(agents);
            let mut b = Harness::new(agents);
            for (step, &op) in ops.iter().enumerate() {
                let pa = a.apply(op);
                let pb = b.apply(op);
                if pa != pb {
                    return Err(format!(
                        "placement diverged at step {step}: {pa:?} vs {pb:?} \
                         ({agents} agents)"
                    ));
                }
            }
            if a.scheduler.issued() != b.scheduler.issued()
                || a.scheduler.declined() != b.scheduler.declined()
            {
                return Err(format!(
                    "prefetch decisions diverged: {}/{} vs {}/{}",
                    a.scheduler.issued(),
                    a.scheduler.declined(),
                    b.scheduler.issued(),
                    b.scheduler.declined()
                ));
            }
            for i in 0..agents {
                let (sa, sb) = (
                    a.router.agent(i).reconfig_stats(),
                    b.router.agent(i).reconfig_stats(),
                );
                if sa != sb {
                    return Err(format!("agent {i} accounting diverged: {sa:?} vs {sb:?}"));
                }
            }
            Ok(())
        });
    }

    /// Kernel-affinity routing is a pure function of the observed call
    /// sequence: two routers fed the identical interleaving of route /
    /// retire / demand-hint calls make identical placements.
    #[test]
    fn prop_kernel_affinity_placement_is_deterministic() {
        use std::collections::VecDeque;
        use std::sync::Arc;
        use tf_fpga::fpga::device::{ComputeBinding, FpgaConfig};
        use tf_fpga::fpga::roles::paper_roles;
        use tf_fpga::hsa::queue::Queue;
        use tf_fpga::reconfig::policy::PolicyKind;
        use tf_fpga::sharding::{FpgaPool, RouteGuard, Router};
        use tf_fpga::util::quickcheck::{U64Range, VecGen};

        struct Harness {
            router: Router,
            ids: Vec<u64>,
            guards: VecDeque<RouteGuard>,
        }

        impl Harness {
            fn new(agents: usize) -> Harness {
                let pool = FpgaPool::new(agents, |i| FpgaConfig {
                    num_regions: 1,
                    policy: PolicyKind::Lru.build(i as u64),
                    realtime: false,
                    realtime_scale: 1.0,
                    trace: None,
                });
                let echo = ComputeBinding::Native(Arc::new(
                    |ins: &[tf_fpga::tf::tensor::Tensor]| Ok(ins.to_vec()),
                ));
                let ids: Vec<u64> = paper_roles()
                    .into_iter()
                    .take(3)
                    .map(|r| pool.register_role(r, echo.clone()))
                    .collect();
                let slots = pool
                    .agents()
                    .iter()
                    .map(|a| (Arc::clone(a), Queue::new(8)))
                    .collect();
                Harness {
                    router: Router::new(slots, ShardStrategy::KernelAffinity),
                    ids,
                    guards: VecDeque::new(),
                }
            }

            /// Apply one op; `Some(agent)` when the op was a route. A
            /// routed dispatch is also *executed* on the chosen agent so
            /// residency evolves exactly as it would in a real session.
            fn apply(&mut self, op: u64) -> Option<usize> {
                use tf_fpga::hsa::agent::Agent;
                use tf_fpga::hsa::packet::AqlPacket;
                use tf_fpga::hsa::signal::Signal;
                match op % 4 {
                    0 | 1 => {
                        let ko = self.ids[(op / 4) as usize % self.ids.len()];
                        let (idx, _q, guard) = self.router.route(ko);
                        let x = tf_fpga::tf::tensor::Tensor::from_f32(
                            &[1],
                            vec![op as f32],
                        )
                        .unwrap();
                        let (pkt, _args) =
                            AqlPacket::dispatch(ko, vec![x], Signal::new(1));
                        if let AqlPacket::KernelDispatch(d) = pkt {
                            self.router.agent(idx).execute(&d).unwrap();
                        }
                        self.guards.push_back(guard);
                        Some(idx)
                    }
                    2 => {
                        self.guards.pop_front(); // retire the oldest
                        None
                    }
                    _ => {
                        let ko = self.ids[(op / 4) as usize % self.ids.len()];
                        self.router.hint_demand(ko, op % 7);
                        None
                    }
                }
            }
        }

        let gen = VecGen { inner: U64Range(0, 1 << 20), min_len: 1, max_len: 120 };
        forall(19, 40, &gen, |ops| {
            let agents = 2 + (ops.len() % 3); // 2..=4
            let mut a = Harness::new(agents);
            let mut b = Harness::new(agents);
            for (step, &op) in ops.iter().enumerate() {
                let pa = a.apply(op);
                let pb = b.apply(op);
                if pa != pb {
                    return Err(format!(
                        "placement diverged at step {step}: {pa:?} vs {pb:?} \
                         (agents {agents})"
                    ));
                }
            }
            Ok(())
        });
    }
}

// ---------------------------------------------------------------------------
// Fleet resilience: fault injection, quarantine, retry-on-alternate
// ---------------------------------------------------------------------------

mod resilience_props {
    use std::time::Duration;
    use tf_fpga::fpga::device::FaultPlan;
    use tf_fpga::sharding::{HealthPolicy, ShardStrategy};
    use tf_fpga::tf::session::{Session, SessionOptions};
    use tf_fpga::tf::tensor::Tensor;
    use tf_fpga::util::prng::Rng;
    use tf_fpga::util::quickcheck::forall;

    /// Test-scale health tuning: stalls of tens of ms get detected,
    /// quarantined and retried within a property iteration.
    fn aggressive() -> HealthPolicy {
        HealthPolicy {
            stall_threshold: Duration::from_millis(20),
            probe_interval: Duration::from_millis(10),
            max_retries: 5,
        }
    }

    /// Drain parked zombies / in-flight gauges after faults are cleared;
    /// errors if the pool never settles.
    fn settle(session: &Session) -> Result<(), String> {
        for _ in 0..200 {
            session.router().check_health();
            if session.router().rollup().inflight == 0 {
                return Ok(());
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        Err(format!(
            "in-flight gauge never drained: {:?}",
            session.router().report()
        ))
    }

    /// Quarantine + retry-on-alternate never changes *what* is computed:
    /// for random graphs on a pool with one agent injected with stall +
    /// drop faults, replay outputs stay bitwise identical to a fault-free
    /// single-agent session.
    #[test]
    fn prop_quarantine_preserves_bitwise_outputs() {
        forall(23, 8, &super::plan_equivalence::GraphCase, |(seed, ops)| {
            let (g, fetches) = super::plan_equivalence::build(*seed, ops);
            let fetch_refs: Vec<&str> = fetches.iter().map(|s| s.as_str()).collect();
            let mut xv = vec![0f32; 6];
            Rng::new(seed ^ 0xFA117).fill_f32_normal(&mut xv, 0.0, 1.0);
            let x = Tensor::from_f32(&[2, 3], xv).map_err(|e| e.to_string())?;
            let feeds = [("x", x)];

            let single = Session::new(g.clone(), SessionOptions::native_only())
                .map_err(|e| format!("single session: {e}"))?;
            let want = single
                .run(&feeds, &fetch_refs)
                .map_err(|e| format!("single run: {e}"))?;
            single.shutdown();

            let pool = 2 + (seed % 3) as usize;
            let strategy = ShardStrategy::ALL[(seed >> 8) as usize % 3];
            let pooled = Session::new(
                g.clone(),
                SessionOptions {
                    fpga_pool: pool,
                    shard_strategy: strategy,
                    health: aggressive(),
                    ..SessionOptions::native_only()
                },
            )
            .map_err(|e| format!("pooled session: {e}"))?;

            // Warm run first: plan *compilation* (constant folding issues
            // real dispatches) has no retry path — only replay does.
            let warm = pooled
                .run(&feeds, &fetch_refs)
                .map_err(|e| format!("warm run: {e}"))?;
            if warm != want {
                return Err("fault-free pooled run diverged".into());
            }

            let faulty = (seed >> 16) as usize % pool;
            pooled.router().agent(faulty).inject_faults(FaultPlan {
                drop_prob: 0.25,
                stall_prob: 0.25,
                stall: Duration::from_millis(30),
                ..FaultPlan::none(*seed)
            });
            for round in 0..2 {
                let got = pooled.run(&feeds, &fetch_refs).map_err(|e| {
                    format!("pool {pool} {strategy:?} faulted round {round}: {e}")
                })?;
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    if a != b {
                        return Err(format!(
                            "fetch '{}' diverged under faults (pool {pool} \
                             {strategy:?} agent {faulty} round {round})",
                            fetch_refs[k]
                        ));
                    }
                }
            }
            pooled.router().agent(faulty).clear_faults();
            settle(&pooled)?;
            pooled.shutdown();
            Ok(())
        });
    }

    /// Exactly-once completion under retry-on-alternate: every submitted
    /// request yields exactly one reply — drops are retried (never
    /// surfaced as failures) and never double-delivered.
    #[test]
    fn prop_retry_never_double_completes() {
        use tf_fpga::serve::{
            AsyncInferenceServer, AsyncServerConfig, BatchPolicy, ModelSpec,
        };
        use tf_fpga::util::quickcheck::U64Range;

        forall(29, 6, &U64Range(1, u64::MAX >> 2), |&seed| {
            let mut rng = Rng::new(seed);
            let pool = 2 + rng.below(2) as usize; // 2..=3 agents
            let mut srv = AsyncInferenceServer::start(AsyncServerConfig {
                models: vec![ModelSpec::new(
                    "mnist",
                    BatchPolicy {
                        max_batch: 1 + rng.below(4) as usize,
                        max_delay: Duration::from_millis(1),
                    },
                )],
                session: SessionOptions {
                    fpga_pool: pool,
                    dispatch_workers: 1,
                    health: aggressive(),
                    ..SessionOptions::native_only()
                },
                pipeline_depth: 2,
            })
            .map_err(|e| e.to_string())?;
            let faulty = rng.below(pool as u64) as usize;
            srv.session().router().agent(faulty).inject_faults(FaultPlan {
                drop_prob: 0.35,
                ..FaultPlan::none(seed)
            });

            let n = 8usize;
            let rxs: Vec<_> = (0..n)
                .map(|i| {
                    let img: Vec<f32> =
                        (0..784).map(|j| ((i * 131 + j) % 255) as f32 / 255.0).collect();
                    srv.infer_async("mnist", img)
                })
                .collect::<Result<_, _>>()
                .map_err(|e| e.to_string())?;
            for (i, rx) in rxs.iter().enumerate() {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(Ok(_)) => {}
                    Ok(Err(e)) => return Err(format!("request {i} failed: {e}")),
                    Err(_) => return Err(format!("request {i} hung")),
                }
                // A second value on the same channel = double completion.
                if let Ok(extra) = rx.recv_timeout(Duration::from_millis(50)) {
                    return Err(format!("request {i} completed twice: {extra:?}"));
                }
            }

            srv.session().router().agent(faulty).clear_faults();
            let rep = srv.report();
            if rep.completed != n as u64 || rep.failed != 0 {
                return Err(format!(
                    "counters don't close: completed {} failed {} (want {n}/0)",
                    rep.completed, rep.failed
                ));
            }
            settle(srv.session())?;
            srv.stop();
            Ok(())
        });
    }

    /// Routing stays a pure function of the observed call sequence when
    /// quarantine/readmit events are part of it — and the eligibility
    /// mask is honored: a route never picks a quarantined slot while an
    /// eligible one exists (an all-quarantined pool voids the mask).
    #[test]
    fn prop_routing_deterministic_under_quarantine() {
        use std::collections::VecDeque;
        use std::sync::Arc;
        use tf_fpga::fpga::device::{ComputeBinding, FpgaConfig};
        use tf_fpga::fpga::roles::paper_roles;
        use tf_fpga::hsa::agent::Agent;
        use tf_fpga::hsa::packet::AqlPacket;
        use tf_fpga::hsa::queue::Queue;
        use tf_fpga::hsa::signal::Signal;
        use tf_fpga::reconfig::policy::PolicyKind;
        use tf_fpga::sharding::{FpgaPool, RouteGuard, Router};
        use tf_fpga::util::quickcheck::{U64Range, VecGen};

        struct Harness {
            router: Router,
            agents: usize,
            ids: Vec<u64>,
            guards: VecDeque<RouteGuard>,
        }

        impl Harness {
            fn new(agents: usize, strategy: ShardStrategy) -> Harness {
                let pool = FpgaPool::new(agents, |i| FpgaConfig {
                    num_regions: 1,
                    policy: PolicyKind::Lru.build(i as u64),
                    realtime: false,
                    realtime_scale: 1.0,
                    trace: None,
                });
                let echo = ComputeBinding::Native(Arc::new(
                    |ins: &[tf_fpga::tf::tensor::Tensor]| Ok(ins.to_vec()),
                ));
                let ids: Vec<u64> = paper_roles()
                    .into_iter()
                    .take(3)
                    .map(|r| pool.register_role(r, echo.clone()))
                    .collect();
                let slots = pool
                    .agents()
                    .iter()
                    .map(|a| (Arc::clone(a), Queue::new(8)))
                    .collect();
                Harness {
                    router: Router::new(slots, strategy),
                    agents,
                    ids,
                    guards: VecDeque::new(),
                }
            }

            /// Apply one op; `Some(agent)` when the op was a route.
            /// Quarantine/readmit come from explicit calls (never the
            /// wall-clock prober), so twins stay in lockstep.
            fn apply(&mut self, op: u64) -> Option<usize> {
                match op % 8 {
                    0..=2 => {
                        let ko = self.ids[(op / 8) as usize % self.ids.len()];
                        let (idx, _q, guard) = self.router.route(ko);
                        let x = tf_fpga::tf::tensor::Tensor::from_f32(
                            &[1],
                            vec![op as f32],
                        )
                        .unwrap();
                        let (pkt, _args) =
                            AqlPacket::dispatch(ko, vec![x], Signal::new(1));
                        if let AqlPacket::KernelDispatch(d) = pkt {
                            self.router.agent(idx).execute(&d).unwrap();
                        }
                        self.guards.push_back(guard);
                        Some(idx)
                    }
                    3 => {
                        self.guards.pop_front(); // retire the oldest
                        None
                    }
                    4 => {
                        let ko = self.ids[(op / 8) as usize % self.ids.len()];
                        self.router.hint_demand(ko, op % 7);
                        None
                    }
                    5 => {
                        self.router.quarantine((op / 8) as usize % self.agents);
                        None
                    }
                    6 => {
                        self.router.readmit((op / 8) as usize % self.agents);
                        None
                    }
                    _ => None,
                }
            }
        }

        let gen = VecGen { inner: U64Range(0, 1 << 24), min_len: 1, max_len: 120 };
        forall(31, 40, &gen, |ops| {
            let agents = 2 + (ops.len() % 3); // 2..=4
            let strategy = ShardStrategy::ALL[ops.iter().sum::<u64>() as usize % 3];
            let mut a = Harness::new(agents, strategy);
            let mut b = Harness::new(agents, strategy);
            for (step, &op) in ops.iter().enumerate() {
                let pa = a.apply(op);
                let pb = b.apply(op);
                if pa != pb {
                    return Err(format!(
                        "placement diverged at step {step}: {pa:?} vs {pb:?} \
                         ({strategy:?}, {agents} agents)"
                    ));
                }
                if let Some(idx) = pa {
                    let eligible_exists =
                        (0..agents).any(|i| !a.router.is_quarantined(i));
                    if eligible_exists && a.router.is_quarantined(idx) {
                        return Err(format!(
                            "step {step}: routed to quarantined agent {idx} \
                             while eligible agents existed ({strategy:?})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}

mod wire_props {
    use std::cell::RefCell;
    use std::time::Duration;
    use tf_fpga::net::{
        decode_predictions, decode_predictions_bin, HttpServer, HttpServerConfig, NetClient,
    };
    use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig, BatchPolicy, ModelSpec};
    use tf_fpga::tf::model::{Model, ModelBundle};
    use tf_fpga::tf::session::SessionOptions;
    use tf_fpga::tf::tensor::Tensor;
    use tf_fpga::util::prng::Rng;
    use tf_fpga::util::quickcheck::{forall, Gen};

    /// One 16-element sample skewed toward f32 edge cases: negative zero,
    /// denormals, and random bit patterns coerced finite.
    struct SampleGen;
    impl Gen for SampleGen {
        type Value = Vec<f32>;
        fn generate(&self, rng: &mut Rng) -> Vec<f32> {
            (0..16)
                .map(|_| match rng.below(5) {
                    0 => -0.0,
                    1 => f32::from_bits(rng.below(0x0080_0000) as u32),
                    2 => -f32::from_bits(1 + rng.below(0x007F_FFFF) as u32),
                    _ => {
                        let v = f32::from_bits(rng.next_u64() as u32);
                        if v.is_finite() { v } else { rng.below(1000) as f32 - 500.0 }
                    }
                })
                .collect()
        }
    }

    /// Binary wire path ≡ JSON path ≡ `Model::invoke`, bitwise, for
    /// adversarial f32 inputs. Non-finite values are out of scope by
    /// construction: the JSON number grammar cannot carry NaN/Inf, so the
    /// generator only emits finite bit patterns (the binary tier would
    /// pass them through untouched).
    #[test]
    fn prop_binary_and_json_paths_are_bitwise_identical() {
        let srv = AsyncInferenceServer::start(AsyncServerConfig {
            models: vec![ModelSpec::from_bundle(
                "tiny",
                ModelBundle::tiny_fc_demo(2, 16, 4),
                BatchPolicy { max_batch: 2, max_delay: Duration::from_millis(1) },
            )],
            session: SessionOptions { dispatch_workers: 2, ..SessionOptions::native_only() },
            pipeline_depth: 2,
        })
        .expect("inference server");
        let mut server = HttpServer::start(srv, HttpServerConfig::default()).expect("http server");
        let reference = Model::from_bundle(
            ModelBundle::tiny_fc_demo(1, 16, 4),
            SessionOptions::native_only(),
        )
        .expect("reference model");
        let client = RefCell::new(NetClient::connect(server.local_addr()).unwrap());

        forall(41, 24, &SampleGen, |sample| {
            let mut client = client.borrow_mut();
            // Reference bits straight through the Model facade.
            let x = Tensor::from_f32(&[1, 16], sample.clone()).map_err(|e| e.to_string())?;
            let out = reference.invoke("serve", &[("x", x)]).map_err(|e| e.to_string())?;
            let want: Vec<f32> = out[0].as_f32().map_err(|e| e.to_string())?.to_vec();

            let resp = client
                .predict("tiny", &[sample.as_slice()], &[])
                .map_err(|e| e.to_string())?;
            if resp.status != 200 {
                return Err(format!("json status {}: {}", resp.status, resp.body));
            }
            let json_rows = decode_predictions(&resp)?;

            let resp = client
                .predict_bin("tiny", &[16], &[sample.as_slice()], &[])
                .map_err(|e| e.to_string())?;
            if resp.status != 200 {
                return Err(format!("binary status {}", resp.status));
            }
            let bin_rows = decode_predictions_bin(&resp)?;

            for (name, row) in [("json", &json_rows[0]), ("binary", &bin_rows[0])] {
                if row.len() != want.len() {
                    return Err(format!("{name}: row length {} vs {}", row.len(), want.len()));
                }
                for (i, (g, w)) in row.iter().zip(&want).enumerate() {
                    if g.to_bits() != w.to_bits() {
                        return Err(format!(
                            "{name} element {i}: {g:?} ({:#010x}) vs {w:?} ({:#010x}) \
                             for sample {sample:?}",
                            g.to_bits(),
                            w.to_bits()
                        ));
                    }
                }
            }
            Ok(())
        });

        reference.shutdown();
        server.shutdown();
    }
}

#[test]
fn prop_native_conv_matches_brute_force() {
    // Independent re-derivation of conv semantics: brute-force i64
    // accumulation, then shift/saturate — must equal ops::conv2d_fixed_i16.
    struct ConvCase;
    impl Gen for ConvCase {
        type Value = (usize, usize, usize, usize, usize, u32, u64);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            let c = 1 + rng.below(3) as usize;
            let f = 1 + rng.below(3) as usize;
            let k = *rng.choose(&[1usize, 3, 5]);
            let h = k + rng.below(12) as usize;
            let w = k + rng.below(12) as usize;
            let shift = rng.below(10) as u32;
            (c, f, k, h, w, shift, rng.next_u64())
        }
    }
    forall(9, 60, &ConvCase, |&(c, f, k, h, w, shift, seed)| {
        let mut rng = Rng::new(seed);
        let mut x = vec![0i16; c * h * w];
        rng.fill_i16(&mut x, -300, 300);
        let mut wts = vec![0i16; f * c * k * k];
        rng.fill_i16(&mut wts, -128, 127);
        let xt = tf_fpga::tf::tensor::Tensor::from_i16(&[c, h, w], x.clone())
            .map_err(|e| e.to_string())?;
        let got = tf_fpga::ops::conv2d_fixed_i16(&xt, &wts, f, c, k, k, shift)
            .map_err(|e| e.to_string())?;
        let (oh, ow) = (h - k + 1, w - k + 1);
        for fi in 0..f {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    for ci in 0..c {
                        for a in 0..k {
                            for b in 0..k {
                                let xv = x[ci * h * w + (oy + a) * w + ox + b] as i64;
                                let wv = wts[((fi * c + ci) * k + a) * k + b] as i64;
                                acc += xv * wv;
                            }
                        }
                    }
                    let want = (acc >> shift).clamp(-32768, 32767) as i16;
                    let gv = got.as_i16().map_err(|e| e.to_string())?
                        [fi * oh * ow + oy * ow + ox];
                    if gv != want {
                        return Err(format!(
                            "({fi},{oy},{ox}): {gv} != {want} (c={c} f={f} k={k} shift={shift})"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}
