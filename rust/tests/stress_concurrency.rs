//! Concurrency stress tests: the MPMC `hsa::queue` and the multi-agent
//! shard router under real thread contention.
//!
//! These are the torture variants of the unit tests in `hsa::queue` /
//! `sharding::router` — thousands of packets, many producers *and* many
//! consumers at once, exercising the CAS-claimed read index, the Vyukov
//! slot sequencing (full-lap producers on a small ring) and the router's
//! in-flight accounting. The invariants: no packet is lost, none is
//! delivered twice, no dispatch completes twice, and every gauge returns
//! to zero once the storm has passed.
//!
//! CI runs this file twice: with `--test-threads=1` (each storm gets the
//! whole machine) and at the default parallelism (storms compete with
//! each other — more preemption, different interleavings).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;
use tf_fpga::fpga::device::{ComputeBinding, FpgaConfig};
use tf_fpga::fpga::roles::paper_roles;
use tf_fpga::hsa::packet::AqlPacket;
use tf_fpga::hsa::queue::Queue;
use tf_fpga::hsa::runtime::HsaRuntime;
use tf_fpga::hsa::signal::Signal;
use tf_fpga::reconfig::policy::PolicyKind;
use tf_fpga::sharding::{FpgaPool, Router, ShardStrategy};
use tf_fpga::tf::tensor::Tensor;

const PRODUCERS: u64 = 4;
const CONSUMERS: usize = 4;
const PER_PRODUCER: u64 = 2000;

/// N producer threads × M consumer threads on one small ring: every packet
/// is delivered exactly once, in spite of full-lap producers and racing
/// read-index claims.
#[test]
fn mpmc_queue_no_loss_no_duplication_under_contention() {
    // Ring much smaller than the packet count: producers lap the ring
    // constantly, consumers fight over the read index.
    let q = Queue::new(32);
    let seen = Arc::new(Mutex::new(Vec::<u64>::new()));

    let consumers: Vec<_> = (0..CONSUMERS)
        .map(|_| {
            let q = q.clone();
            let seen = Arc::clone(&seen);
            thread::spawn(move || {
                let mut local = Vec::new();
                while let Some(pkt) = q.dequeue_blocking() {
                    if let AqlPacket::KernelDispatch(d) = pkt {
                        local.push(d.kernel_object);
                        d.completion_signal.subtract(1);
                    }
                }
                seen.lock().unwrap().extend(local);
            })
        })
        .collect();

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let q = q.clone();
            thread::spawn(move || {
                for i in 0..PER_PRODUCER {
                    let (pkt, _args) =
                        AqlPacket::dispatch(p * 1_000_000 + i, vec![], Signal::new(1));
                    q.enqueue(pkt).expect("enqueue during storm");
                }
            })
        })
        .collect();
    for p in producers {
        p.join().unwrap();
    }
    // Producers done: drain, then release the consumers.
    while q.depth() > 0 {
        thread::yield_now();
    }
    q.shutdown();
    for c in consumers {
        c.join().unwrap();
    }

    let mut got = seen.lock().unwrap().clone();
    let mut want: Vec<u64> = (0..PRODUCERS)
        .flat_map(|p| (0..PER_PRODUCER).map(move |i| p * 1_000_000 + i))
        .collect();
    got.sort_unstable();
    want.sort_unstable();
    assert_eq!(
        got.len(),
        want.len(),
        "lost or duplicated packets: got {}, want {}",
        got.len(),
        want.len()
    );
    assert_eq!(got, want, "packet id multiset changed in transit");
}

/// Each packet's completion signal fires exactly once even when a pool of
/// processors drains the queue: a double-completion would drive the signal
/// negative, a dropped one would leave it at 1.
#[test]
fn completion_signals_fire_exactly_once_across_processor_pool() {
    let q = Queue::new(64);
    let consumers: Vec<_> = (0..CONSUMERS)
        .map(|_| {
            let q = q.clone();
            thread::spawn(move || {
                while let Some(pkt) = q.dequeue_blocking() {
                    if let AqlPacket::KernelDispatch(d) = pkt {
                        d.completion_signal.subtract(1);
                    }
                }
            })
        })
        .collect();
    let signals: Vec<Signal> = (0..1000)
        .map(|i| {
            let sig = Signal::new(1);
            let (pkt, _args) = AqlPacket::dispatch(i, vec![], sig.clone());
            q.enqueue(pkt).unwrap();
            sig
        })
        .collect();
    for sig in &signals {
        sig.wait_eq(0, Some(Duration::from_secs(30))).expect("signal reached 0");
    }
    q.shutdown();
    for c in consumers {
        c.join().unwrap();
    }
    for (i, sig) in signals.iter().enumerate() {
        assert_eq!(sig.load(), 0, "signal {i} fired a wrong number of times");
    }
}

fn echo_binding() -> ComputeBinding {
    ComputeBinding::Native(Arc::new(|ins: &[Tensor]| Ok(ins.to_vec())))
}

fn stress_pool(n: usize) -> (FpgaPool, Vec<u64>) {
    let pool = FpgaPool::new(n, |i| FpgaConfig {
        num_regions: 1,
        policy: PolicyKind::Lru.build(i as u64),
        realtime: false,
        realtime_scale: 1.0,
        trace: None,
    });
    let ids: Vec<u64> = paper_roles()
        .into_iter()
        .take(2)
        .map(|r| pool.register_role(r, echo_binding()))
        .collect();
    (pool, ids)
}

/// Hammer a 3-agent router from 8 threads: every dispatch must complete
/// exactly once on exactly one agent, the per-agent dispatch counts must
/// sum to the total, and the in-flight gauges must all return to zero.
#[test]
fn router_stress_no_lost_or_double_completions() {
    for strategy in ShardStrategy::ALL {
        let (pool, ids) = stress_pool(3);
        let rt = HsaRuntime::builder().with_fpga_pool(&pool).build();
        let slots = pool
            .agents()
            .iter()
            .map(|a| {
                let q = rt.create_queue_with_processors(
                    Arc::clone(a) as Arc<dyn tf_fpga::hsa::agent::Agent>,
                    64,
                    1,
                );
                (Arc::clone(a), q)
            })
            .collect();
        let router = Arc::new(Router::new(slots, strategy));
        let rt = Arc::new(rt);

        const THREADS: usize = 8;
        const PER_THREAD: usize = 250;
        let completed = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let router = Arc::clone(&router);
                let rt = Arc::clone(&rt);
                let completed = Arc::clone(&completed);
                let ids = ids.clone();
                thread::spawn(move || {
                    for i in 0..PER_THREAD {
                        let ko = ids[(t + i) % ids.len()];
                        let payload = (t * PER_THREAD + i) as f32;
                        let x = Tensor::from_f32(&[1, 2], vec![payload, -payload])
                            .unwrap();
                        let (_, queue, _guard) = router.route(ko);
                        let out = rt
                            .dispatch_sync(&queue, ko, vec![x.clone()])
                            .expect("dispatch during storm");
                        assert_eq!(out, vec![x], "echo payload corrupted in flight");
                        completed.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }

        let total = (THREADS * PER_THREAD) as u64;
        assert_eq!(completed.load(Ordering::Relaxed), total);
        let rollup = router.rollup();
        assert_eq!(
            rollup.dispatches, total,
            "{strategy:?}: routed dispatches != issued dispatches"
        );
        assert_eq!(rollup.inflight, 0, "{strategy:?}: in-flight gauge leaked");
        // Every routed dispatch executed on exactly one agent: the agents'
        // own reconfig accounting (bumped once per executed packet) must
        // sum to the total — a lost packet undercounts, a duplicated
        // delivery overcounts.
        assert_eq!(
            rollup.reconfig.dispatches, total,
            "{strategy:?}: executed packets != routed packets"
        );
        rt.shutdown();
    }
}

/// Concurrent pooled sessions: many client threads through one pooled
/// session; every result must be the caller's own (no cross-request
/// bleed), and the pool accounting must close.
#[test]
fn pooled_session_parallel_clients_get_their_own_results() {
    use tf_fpga::tf::dtype::DType;
    use tf_fpga::tf::graph::{Graph, OpKind};
    use tf_fpga::tf::session::{Session, SessionOptions};

    let mut g = Graph::new();
    let x = g.placeholder("x", &[2, 4], DType::F32).unwrap();
    let w = g
        .constant("w", Tensor::from_f32(&[4, 2], vec![0.5; 8]).unwrap())
        .unwrap();
    let b = g
        .constant("b", Tensor::from_f32(&[2], vec![1.0, -1.0]).unwrap())
        .unwrap();
    g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();

    let sess = Arc::new(
        Session::new(
            g,
            SessionOptions {
                fpga_pool: 2,
                shard_strategy: ShardStrategy::LeastLoaded,
                dispatch_workers: 2,
                ..SessionOptions::native_only()
            },
        )
        .unwrap(),
    );
    let handles: Vec<_> = (0..6)
        .map(|t| {
            let sess = Arc::clone(&sess);
            thread::spawn(move || {
                for i in 0..50 {
                    let v = (t * 100 + i) as f32;
                    let x = Tensor::from_f32(&[2, 4], vec![v; 8]).unwrap();
                    let out = sess.run(&[("x", x)], &["y"]).unwrap();
                    let want = [2.0 * v + 1.0, 2.0 * v - 1.0];
                    for row in out[0].as_f32().unwrap().chunks(2) {
                        assert_eq!(row, &want, "thread {t} iter {i} got foreign batch");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(sess.router().rollup().inflight, 0, "in-flight gauge leaked");
    let stats = sess.reconfig_stats();
    assert_eq!(stats.dispatches, 6 * 50, "each run is exactly one dispatch");
    sess.shutdown();
}

/// Sanity companion for the storm: the per-agent dispatch split is
/// complete (sums to the rollup) and reported in stable pool order.
#[test]
fn router_reports_are_complete_and_ordered() {
    let (pool, ids) = stress_pool(2);
    let rt = HsaRuntime::builder().with_fpga_pool(&pool).build();
    let slots = pool
        .agents()
        .iter()
        .map(|a| {
            let q = rt.create_queue(
                Arc::clone(a) as Arc<dyn tf_fpga::hsa::agent::Agent>,
                32,
            );
            (Arc::clone(a), q)
        })
        .collect();
    let router = Router::new(slots, ShardStrategy::RoundRobin);
    let mut by_agent: HashMap<usize, u64> = HashMap::new();
    for i in 0..10 {
        let x = Tensor::from_f32(&[1], vec![i as f32]).unwrap();
        let ko = ids[i % 2];
        let (idx, queue, _guard) = router.route(ko);
        rt.dispatch_sync(&queue, ko, vec![x]).unwrap();
        *by_agent.entry(idx).or_insert(0) += 1;
    }
    let report = router.report();
    assert_eq!(report.len(), 2);
    assert_eq!(report[0].agent, "ultra96-pl-0");
    assert_eq!(report[1].agent, "ultra96-pl-1");
    for (idx, count) in by_agent {
        assert_eq!(report[idx].dispatches, count);
    }
    assert_eq!(router.rollup().dispatches, 10);
    rt.shutdown();
}
