//! Integration: full graphs through the Session, FPGA placement vs the CPU
//! baseline, soft placement, quantization pipelines, reconfiguration
//! behaviour at the session level.

use tf_fpga::hsa::agent::DeviceType;
use tf_fpga::tf::dtype::DType;
use tf_fpga::tf::graph::{Graph, OpKind};
use tf_fpga::tf::session::{Session, SessionOptions};
use tf_fpga::tf::tensor::Tensor;
use tf_fpga::util::prng::Rng;

fn rand_f32(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut v = vec![0f32; shape.iter().product()];
    rng.fill_f32_normal(&mut v, 0.0, 1.0);
    Tensor::from_f32(shape, v).unwrap()
}

fn rand_i16(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut v = vec![0i16; shape.iter().product()];
    rng.fill_i16(&mut v, -256, 255);
    Tensor::from_i16(shape, v).unwrap()
}

/// FC chain: x -> fc -> relu -> fc_barrier.
fn fc_chain() -> Graph {
    let mut g = Graph::new();
    let x = g.placeholder("x", &[8, 16], DType::F32).unwrap();
    let w1 = g.constant("w1", rand_f32(&[16, 12], 1)).unwrap();
    let b1 = g.constant("b1", rand_f32(&[12], 2)).unwrap();
    let y1 = g.add("y1", OpKind::FullyConnected, &[x, w1, b1]).unwrap();
    let r = g.add("r", OpKind::Relu, &[y1]).unwrap();
    let w2 = g.constant("w2", rand_f32(&[12, 4], 3)).unwrap();
    let b2 = g.constant("b2", rand_f32(&[4], 4)).unwrap();
    g.add("y2", OpKind::FcBarrier, &[r, w2, b2]).unwrap();
    g
}

#[test]
fn fc_chain_fpga_equals_cpu_baseline() {
    let fpga = Session::new(fc_chain(), SessionOptions::native_only()).unwrap();
    let cpu = Session::new(fc_chain(), SessionOptions::cpu_baseline()).unwrap();
    for seed in 0..5 {
        let x = rand_f32(&[8, 16], 100 + seed);
        let a = fpga.run(&[("x", x.clone())], &["y2"]).unwrap();
        let b = cpu.run(&[("x", x)], &["y2"]).unwrap();
        let diff = a[0].max_abs_diff(&b[0]).unwrap();
        assert!(diff < 1e-5, "seed {seed}: diff {diff}");
    }
    // FC ops went to the FPGA in one session and not the other.
    assert!(fpga.reconfig_stats().dispatches >= 10);
    assert_eq!(cpu.reconfig_stats().dispatches, 0);
    fpga.shutdown();
    cpu.shutdown();
}

#[test]
fn quantized_conv_pipeline_round_trip() {
    // f32 -> quantize -> conv5x5(i16) -> relu(i16) -> dequantize -> f32.
    let mut g = Graph::new();
    let x = g.placeholder("x", &[1, 28, 28], DType::F32).unwrap();
    let q = g.add("q", OpKind::Quantize { frac_bits: 8 }, &[x]).unwrap();
    let c = g.add("c", OpKind::Conv5x5I16, &[q]).unwrap();
    let r = g.add("r", OpKind::Relu, &[c]).unwrap();
    g.add("out", OpKind::Dequantize { frac_bits: 8 }, &[r]).unwrap();

    let sess = Session::new(g, SessionOptions::native_only()).unwrap();
    let x = rand_f32(&[1, 28, 28], 9);
    let out = sess.run(&[("x", x)], &["out"]).unwrap();
    assert_eq!(out[0].shape(), &[1, 24, 24]);
    assert_eq!(out[0].dtype(), DType::F32);
    // Relu'd and dequantized: all outputs are >= 0.
    assert!(out[0].as_f32().unwrap().iter().all(|&v| v >= 0.0));
    sess.shutdown();
}

#[test]
fn conv_roles_on_fpga_match_cpu_for_many_inputs() {
    let mut g = Graph::new();
    let x = g.placeholder("x", &[1, 28, 28], DType::I16).unwrap();
    g.add("c5", OpKind::Conv5x5I16, &[x]).unwrap();
    g.add("c3", OpKind::Conv3x3I16, &[x]).unwrap();
    let fpga = Session::new(g.clone(), SessionOptions::native_only()).unwrap();
    let cpu = Session::new(g, SessionOptions::cpu_baseline()).unwrap();
    for seed in 0..8 {
        let x = rand_i16(&[1, 28, 28], 50 + seed);
        let a = fpga.run(&[("x", x.clone())], &["c5", "c3"]).unwrap();
        let b = cpu.run(&[("x", x)], &["c5", "c3"]).unwrap();
        assert_eq!(a[0], b[0], "conv5 seed {seed}");
        assert_eq!(a[1], b[1], "conv3 seed {seed}");
    }
    fpga.shutdown();
    cpu.shutdown();
}

#[test]
fn soft_placement_falls_back_for_fpga_annotated_relu() {
    let mut g = Graph::new();
    let x = g.placeholder("x", &[4], DType::F32).unwrap();
    let r = g.add("r", OpKind::Relu, &[x]).unwrap();
    g.set_device(r, DeviceType::Fpga); // no FPGA relu registered
    let sess = Session::new(g, SessionOptions::native_only()).unwrap();
    assert_eq!(sess.placement().device_of(r), Some(DeviceType::Cpu));
    assert_eq!(sess.placement().soft_placed, vec![r]);
    let out = sess
        .run(&[("x", Tensor::from_f32(&[4], vec![-1.0, 2.0, -3.0, 4.0]).unwrap())], &["r"])
        .unwrap();
    assert_eq!(out[0].as_f32().unwrap(), &[0.0, 2.0, 0.0, 4.0]);
    sess.shutdown();
}

#[test]
fn hard_placement_error_is_loud() {
    let mut g = Graph::new();
    let x = g.placeholder("x", &[4], DType::F32).unwrap();
    let r = g.add("r", OpKind::Relu, &[x]).unwrap();
    g.set_device(r, DeviceType::Fpga);
    let err = Session::new(
        g,
        SessionOptions { allow_soft_placement: false, ..SessionOptions::native_only() },
    )
    .err()
    .expect("must fail");
    assert!(err.to_string().contains("relu"), "{err}");
}

#[test]
fn session_reconfig_stats_reflect_role_thrash() {
    // Alternate two conv roles + fc on a 1-region FPGA: every dispatch is
    // a miss (paper: "if not configured" cost on every role switch).
    let mut g = Graph::new();
    let x = g.placeholder("x", &[1, 28, 28], DType::I16).unwrap();
    g.add("c5", OpKind::Conv5x5I16, &[x]).unwrap();
    g.add("c3", OpKind::Conv3x3I16, &[x]).unwrap();
    let sess = Session::new(
        g,
        SessionOptions { num_regions: 1, ..SessionOptions::native_only() },
    )
    .unwrap();
    for seed in 0..5 {
        let x = rand_i16(&[1, 28, 28], seed);
        sess.run(&[("x", x)], &["c5", "c3"]).unwrap();
    }
    let s = sess.reconfig_stats();
    assert_eq!(s.dispatches, 10);
    assert_eq!(s.misses, 10, "1 region + 2 alternating roles never hits");
    assert_eq!(s.reconfig_us_total, 10 * 7425);
    sess.shutdown();
}

#[test]
fn run_with_stats_counts_dispatches_per_device() {
    let sess = Session::new(fc_chain(), SessionOptions::native_only()).unwrap();
    let x = rand_f32(&[8, 16], 1);

    // Interpreted walk: 2 FC on FPGA + relu on CPU, one dispatch per node.
    let (interp_out, stats) = sess.run_interpreted(&[("x", x.clone())], &["y2"]).unwrap();
    assert_eq!(stats.dispatches, 3);
    assert_eq!(stats.dispatches_by_device[&DeviceType::Fpga], 2);
    assert_eq!(stats.dispatches_by_device[&DeviceType::Cpu], 1);
    assert!(stats.wall_us > 0);

    // Plan replay: fc+relu fuses into one FPGA dispatch, so the relu's
    // CPU hop disappears — 2 FPGA dispatches total, identical output.
    let (plan_out, stats) = sess.run_with_stats(&[("x", x)], &["y2"]).unwrap();
    assert_eq!(stats.dispatches, 2);
    assert_eq!(stats.fused_dispatches, 1);
    assert_eq!(stats.dispatches_by_device[&DeviceType::Fpga], 2);
    assert!(!stats.dispatches_by_device.contains_key(&DeviceType::Cpu));
    assert_eq!(plan_out[0], interp_out[0]);
    sess.shutdown();
}

#[test]
fn whole_cnn_native_kernel_shapes_and_consistency() {
    let mut g = Graph::new();
    let x = g.placeholder("x", &[4, 1, 28, 28], DType::F32).unwrap();
    g.add("logits", OpKind::MnistCnn, &[x]).unwrap();
    let fpga = Session::new(g.clone(), SessionOptions::native_only()).unwrap();
    let cpu = Session::new(g, SessionOptions::cpu_baseline()).unwrap();
    let x = rand_f32(&[4, 1, 28, 28], 33);
    let a = fpga.run(&[("x", x.clone())], &["logits"]).unwrap();
    let b = cpu.run(&[("x", x)], &["logits"]).unwrap();
    assert_eq!(a[0].shape(), &[4, 10]);
    let diff = a[0].max_abs_diff(&b[0]).unwrap();
    assert!(diff < 1e-5, "diff {diff}");
    fpga.shutdown();
    cpu.shutdown();
}

#[test]
fn softmax_head_produces_distribution() {
    let mut g = Graph::new();
    let x = g.placeholder("x", &[4, 1, 28, 28], DType::F32).unwrap();
    let l = g.add("logits", OpKind::MnistCnn, &[x]).unwrap();
    g.add("probs", OpKind::Softmax, &[l]).unwrap();
    let sess = Session::new(g, SessionOptions::native_only()).unwrap();
    let x = rand_f32(&[4, 1, 28, 28], 71);
    let out = sess.run(&[("x", x)], &["probs"]).unwrap();
    for row in out[0].as_f32().unwrap().chunks(10) {
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5, "{row:?}");
        assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
    sess.shutdown();
}

#[test]
fn session_trace_records_reconfig_and_exec_events() {
    use tf_fpga::trace::recorder::TraceRecorder;
    let tr = TraceRecorder::new();
    let mut g = Graph::new();
    let x = g.placeholder("x", &[1, 28, 28], DType::I16).unwrap();
    g.add("c5", OpKind::Conv5x5I16, &[x]).unwrap();
    let sess = Session::new(
        g,
        SessionOptions { trace: Some(tr.clone()), ..SessionOptions::native_only() },
    )
    .unwrap();
    let x = rand_i16(&[1, 28, 28], 3);
    sess.run(&[("x", x.clone())], &["c5"]).unwrap();
    sess.run(&[("x", x)], &["c5"]).unwrap();
    // 1 reconfig + 2 kernel executions.
    assert_eq!(tr.len(), 3, "{}", tr.to_chrome_trace());
    let json = tf_fpga::util::json::Json::parse(&tr.to_chrome_trace()).unwrap();
    let cats: Vec<String> = json
        .get("traceEvents")
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|e| e.get("cat").as_str().map(String::from))
        .collect();
    assert_eq!(cats.iter().filter(|c| *c == "reconfig").count(), 1);
    assert_eq!(cats.iter().filter(|c| *c == "kernel").count(), 2);
    sess.shutdown();
}

#[test]
fn eviction_policy_option_respected_by_session() {
    use tf_fpga::reconfig::policy::PolicyKind;
    // FIFO vs LRU distinguishable: load c5, c3; touch c5; load third role
    // (cnn conv1 via graph) — FIFO evicts c5, LRU evicts c3.
    let build = |policy| {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 28, 28], DType::I16).unwrap();
        g.add("c5", OpKind::Conv5x5I16, &[x]).unwrap();
        g.add("c3", OpKind::Conv3x3I16, &[x]).unwrap();
        Session::new(
            g,
            SessionOptions { policy, num_regions: 2, ..SessionOptions::native_only() },
        )
        .unwrap()
    };
    for kind in [PolicyKind::Lru, PolicyKind::Fifo, PolicyKind::Random] {
        let sess = build(kind);
        let x = rand_i16(&[1, 28, 28], 1);
        for _ in 0..4 {
            sess.run(&[("x", x.clone())], &["c5", "c3"]).unwrap();
        }
        let s = sess.reconfig_stats();
        assert_eq!(s.misses, 2, "{kind:?}: both roles stay resident");
        assert_eq!(s.hits, 6, "{kind:?}");
        sess.shutdown();
    }
}

#[test]
fn batch_size_flexibility_via_native_fallback() {
    // The generic FC datapath accepts any M (PJRT module is shape-locked to
    // 64; the hybrid binding falls back to the native path for others).
    let mut g = Graph::new();
    let x = g.placeholder("x", &[3, 16], DType::F32).unwrap();
    let w = g.constant("w", rand_f32(&[16, 5], 7)).unwrap();
    let b = g.constant("b", rand_f32(&[5], 8)).unwrap();
    g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
    let sess = Session::new(g, SessionOptions::default()).unwrap();
    let out = sess.run(&[("x", rand_f32(&[3, 16], 21))], &["y"]).unwrap();
    assert_eq!(out[0].shape(), &[3, 5]);
    sess.shutdown();
}
