//! Failover / eviction-storm integration: serve a bundle whose FPGA
//! working set is larger than the pool's total PR regions, under every
//! eviction policy × shard strategy combination that matters, and assert
//! the pipeline keeps making progress with correct outputs and bounded
//! reconfiguration thrash.
//!
//! The layered MNIST bundle dispatches four distinct FPGA kernels per
//! request (conv1+relu, conv2+relu, fc1+relu, fc2); a pool of two agents
//! with one PR region each can hold only two at a time, so *every*
//! request forces reconfigurations somewhere — the storm. The invariants:
//!
//! * forward progress — every request completes within the timeout (no
//!   deadlock between routing, reconfiguration and completion);
//! * correctness — pooled logits are bitwise identical to a single-agent
//!   baseline (identical deterministic weights everywhere);
//! * bounded thrash — the reconfiguration accounting closes: at most one
//!   reconfig per dispatch, at least one cold load per kernel, and the
//!   in-flight gauges all return to zero.

use std::time::Duration;
use tf_fpga::reconfig::policy::PolicyKind;
use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig, BatchPolicy, ModelSpec};
use tf_fpga::sharding::ShardStrategy;
use tf_fpga::tf::model::ModelBundle;
use tf_fpga::tf::session::SessionOptions;

const REQUESTS: usize = 12;
const RECV_TIMEOUT: Duration = Duration::from_secs(120);

fn layered_spec() -> ModelSpec {
    // max_batch 1: the layered graph is rank-3 (batch dim must stay 1).
    ModelSpec::from_bundle(
        "layers",
        ModelBundle::mnist_layers_demo(),
        BatchPolicy { max_batch: 1, max_delay: Duration::from_millis(1) },
    )
}

fn images() -> Vec<Vec<f32>> {
    (0..REQUESTS)
        .map(|i| {
            (0..784)
                .map(|p| ((i * 37 + p * 13) % 255) as f32 / 255.0 - 0.5)
                .collect()
        })
        .collect()
}

fn serve_all(
    srv: &AsyncInferenceServer,
    images: &[Vec<f32>],
    tag: &str,
) -> Vec<Vec<f32>> {
    // Submit everything up front (the storm: all lanes demand regions at
    // once), then harvest with a deadline so a routing/reconfig deadlock
    // fails the test instead of hanging it.
    let rxs: Vec<_> = images
        .iter()
        .map(|im| srv.infer_async("layers", im.clone()).expect("submit"))
        .collect();
    rxs.into_iter()
        .enumerate()
        .map(|(i, rx)| {
            rx.recv_timeout(RECV_TIMEOUT)
                .unwrap_or_else(|_| panic!("{tag}: request {i} stalled (deadlock?)"))
                .unwrap_or_else(|e| panic!("{tag}: request {i} failed: {e}"))
        })
        .collect()
}

#[test]
fn eviction_storm_on_undersized_pool_stays_correct_and_live() {
    let images = images();

    // Single-agent baseline with ample regions: the reference logits.
    let mut baseline = AsyncInferenceServer::start(AsyncServerConfig {
        models: vec![layered_spec()],
        session: SessionOptions {
            num_regions: 4,
            dispatch_workers: 1,
            ..SessionOptions::native_only()
        },
        pipeline_depth: 2,
    })
    .expect("baseline server");
    let want = serve_all(&baseline, &images, "baseline");
    baseline.stop();

    for policy in [PolicyKind::Lru, PolicyKind::QueueAware] {
        for strategy in ShardStrategy::ALL {
            let tag = format!("{policy:?}/{strategy:?}");
            let mut srv = AsyncInferenceServer::start(AsyncServerConfig {
                models: vec![layered_spec()],
                session: SessionOptions {
                    fpga_pool: 2,
                    num_regions: 1, // 2 regions total < 4-kernel working set
                    policy,
                    shard_strategy: strategy,
                    dispatch_workers: 1,
                    ..SessionOptions::native_only()
                },
                pipeline_depth: 4,
            })
            .unwrap_or_else(|e| panic!("{tag}: server start: {e}"));

            let got = serve_all(&srv, &images, &tag);
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a, b, "{tag}: request {i} logits diverged under the storm");
            }

            let rep = srv.report();
            assert_eq!(rep.completed, REQUESTS as u64, "{tag}: {rep:?}");
            assert_eq!(rep.failed, 0, "{tag}: {rep:?}");
            let rc = &rep.reconfig;
            assert!(rc.dispatches > 0, "{tag}: nothing reached the FPGA pool");
            // Bounded thrash: a dispatch triggers at most one reconfig,
            // and the four-kernel working set must have cold-loaded at
            // least once each (somewhere in the pool).
            assert!(
                rc.misses <= rc.dispatches,
                "{tag}: more reconfigs than dispatches: {rc:?}"
            );
            assert!(rc.misses >= 4, "{tag}: working set never loaded: {rc:?}");
            assert_eq!(rc.hits + rc.misses, rc.dispatches, "{tag}: {rc:?}");
            // Both report rows exist and the gauges closed.
            assert_eq!(rep.pool.len(), 2, "{tag}");
            assert_eq!(
                rep.pool.iter().map(|p| p.inflight).sum::<u64>(),
                0,
                "{tag}: in-flight leaked: {:?}",
                rep.pool
            );
            srv.stop();
        }
    }
}

/// Predictive reconfiguration under the same storm: a single agent whose
/// two PR regions are half the four-kernel working set, with the prefetch
/// scheduler walking the plan horizon. Prefetching reorders *when* ICAP
/// transfers happen, never *what* the kernels compute — logits must stay
/// bitwise identical to the reactive baseline — and the new accounting
/// must close: every dispatch is still a hit or a miss, and prefetch
/// outcomes (hit / wasted) never exceed prefetches issued.
#[test]
fn prefetch_keeps_outputs_bitwise_identical_under_region_pressure() {
    use tf_fpga::reconfig::PrefetchPolicy;
    let images = images();

    let mut baseline = AsyncInferenceServer::start(AsyncServerConfig {
        models: vec![layered_spec()],
        session: SessionOptions {
            num_regions: 2, // half the 4-kernel working set
            dispatch_workers: 1,
            ..SessionOptions::native_only()
        },
        pipeline_depth: 2,
    })
    .expect("reactive baseline server");
    let want = serve_all(&baseline, &images, "prefetch-baseline");
    baseline.stop();

    let mut srv = AsyncInferenceServer::start(AsyncServerConfig {
        models: vec![layered_spec()],
        session: SessionOptions {
            num_regions: 2,
            dispatch_workers: 1,
            prefetch: PrefetchPolicy::with_depth(2),
            ..SessionOptions::native_only()
        },
        pipeline_depth: 2,
    })
    .expect("prefetching server");
    let got = serve_all(&srv, &images, "prefetch");
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert_eq!(a, b, "prefetch: request {i} logits diverged from reactive run");
    }

    let rep = srv.report();
    assert_eq!(rep.completed, REQUESTS as u64, "{rep:?}");
    assert_eq!(rep.failed, 0, "{rep:?}");
    let rc = &rep.reconfig;
    assert!(rc.dispatches > 0, "nothing reached the FPGA: {rc:?}");
    assert_eq!(rc.hits + rc.misses, rc.dispatches, "accounting broke: {rc:?}");
    assert!(
        rc.prefetches > 0,
        "scheduler never issued a prefetch under region pressure: {rc:?}"
    );
    assert!(
        rc.prefetch_hits + rc.prefetch_wasted <= rc.prefetches,
        "more prefetch outcomes than prefetches issued: {rc:?}"
    );
    assert_eq!(
        rep.pool.iter().map(|p| p.inflight).sum::<u64>(),
        0,
        "in-flight leaked: {:?}",
        rep.pool
    );
    srv.stop();
}

/// The same storm at pool sizes 1..=3 under kernel-affinity routing:
/// adding agents must never *increase* total reconfiguration misses for
/// the same request trace (more total regions → the affinity router can
/// pin kernels to agents instead of cycling one undersized device).
#[test]
fn kernel_affinity_reconfig_thrash_shrinks_as_the_pool_grows() {
    let images = images();
    let mut misses_by_pool = Vec::new();
    for pool in 1..=3usize {
        let mut srv = AsyncInferenceServer::start(AsyncServerConfig {
            models: vec![layered_spec()],
            session: SessionOptions {
                fpga_pool: pool,
                num_regions: 1,
                shard_strategy: ShardStrategy::KernelAffinity,
                dispatch_workers: 1,
                ..SessionOptions::native_only()
            },
            pipeline_depth: 1, // serialized: routing sees settled residency
        })
        .unwrap_or_else(|e| panic!("pool {pool}: {e}"));
        let _ = serve_all(&srv, &images, &format!("pool-{pool}"));
        let rep = srv.report();
        assert_eq!(rep.completed, REQUESTS as u64);
        misses_by_pool.push(rep.reconfig.misses);
        srv.stop();
    }
    assert!(
        misses_by_pool.windows(2).all(|w| w[1] <= w[0]),
        "reconfig misses should not grow with pool size: {misses_by_pool:?}"
    );
}
