//! Loopback integration tests for the HTTP serving frontend: concurrent
//! tenants against two hosted bundles on a 2-agent FPGA pool with
//! bitwise-correct logits, load shedding under overload (429, never a
//! hang, never a dropped in-flight request), per-tenant quotas, deadline
//! cancellation, graceful drain, structured error bodies, and Prometheus
//! metrics.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};
use tf_fpga::fpga::device::FaultPlan;
use tf_fpga::net::{
    decode_predictions, decode_predictions_bin, one_shot, HttpServer, HttpServerConfig, NetClient,
    TENSOR_CONTENT_TYPE,
};
use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig, BatchPolicy, ModelSpec};
use tf_fpga::sharding::ShardStrategy;
use tf_fpga::tf::model::{Model, ModelBundle};
use tf_fpga::tf::session::SessionOptions;
use tf_fpga::tf::tensor::Tensor;

fn policy(max_batch: usize, delay_ms: u64) -> BatchPolicy {
    BatchPolicy { max_batch, max_delay: Duration::from_millis(delay_ms) }
}

fn start_http(
    models: Vec<ModelSpec>,
    session: SessionOptions,
    pipeline_depth: usize,
    http: HttpServerConfig,
) -> HttpServer {
    let srv = AsyncInferenceServer::start(AsyncServerConfig { models, session, pipeline_depth })
        .expect("inference server");
    HttpServer::start(srv, http).expect("http server")
}

/// Reference logits straight through the Model facade: `samples` rows in
/// one batch-`samples.len()` invocation of the same (deterministic)
/// bundle.
fn mnist_reference(samples: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = samples.len();
    let model = Model::from_bundle(ModelBundle::mnist_demo(n), SessionOptions::native_only())
        .expect("reference model");
    let mut data = Vec::with_capacity(n * 784);
    for s in samples {
        data.extend_from_slice(s);
    }
    let x = Tensor::from_f32(&[n, 1, 28, 28], data).unwrap();
    let out = model.invoke("serve", &[("x", x)]).unwrap();
    let rows = out[0].as_f32().unwrap().chunks(10).map(|r| r.to_vec()).collect();
    model.shutdown();
    rows
}

fn tiny_reference(samples: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = samples.len();
    let model = Model::from_bundle(
        ModelBundle::tiny_fc_demo(n, 16, 4),
        SessionOptions::native_only(),
    )
    .expect("reference model");
    let mut data = Vec::with_capacity(n * 16);
    for s in samples {
        data.extend_from_slice(s);
    }
    let x = Tensor::from_f32(&[n, 16], data).unwrap();
    let out = model.invoke("serve", &[("x", x)]).unwrap();
    let rows = out[0].as_f32().unwrap().chunks(4).map(|r| r.to_vec()).collect();
    model.shutdown();
    rows
}

fn assert_bitwise(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: row length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{ctx}: element {i} diverged ({g} vs {w})"
        );
    }
}

/// Pull one `name{label...} value` sample out of a Prometheus document.
fn metric_value(text: &str, prefix: &str) -> Option<u64> {
    text.lines()
        .find(|l| l.starts_with(prefix))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

// ---------------------------------------------------------------------------
// The tentpole acceptance test: concurrent tenants x two bundles x a
// 2-agent pool, bitwise-correct logits over the wire, metrics exposed.
// ---------------------------------------------------------------------------

#[test]
fn concurrent_tenants_two_bundles_pool2_bitwise_logits_and_metrics() {
    let mut server = start_http(
        vec![
            ModelSpec::new("mnist", policy(4, 2)),
            ModelSpec::from_bundle("tiny", ModelBundle::tiny_fc_demo(4, 16, 4), policy(2, 2)),
        ],
        SessionOptions {
            fpga_pool: 2,
            shard_strategy: ShardStrategy::RoundRobin,
            dispatch_workers: 2,
            ..SessionOptions::native_only()
        },
        4,
        HttpServerConfig { workers: 8, max_pending: 256, ..HttpServerConfig::default() },
    );
    let addr = server.local_addr();

    const PER_CLIENT: usize = 6;
    let mnist_samples: Vec<Vec<f32>> = (0..4 * PER_CLIENT)
        .map(|i| (0..784).map(|j| ((i * 797 + j) % 251) as f32 / 251.0).collect())
        .collect();
    let tiny_samples: Vec<Vec<f32>> = (0..4 * PER_CLIENT)
        .map(|i| (0..16).map(|j| (i + j) as f32 * 0.07 - 0.5).collect())
        .collect();
    let mnist_want = Arc::new(mnist_reference(&mnist_samples));
    let tiny_want = Arc::new(tiny_reference(&tiny_samples));
    let mnist_samples = Arc::new(mnist_samples);
    let tiny_samples = Arc::new(tiny_samples);

    let handles: Vec<_> = (0..8)
        .map(|c| {
            let (mnist_samples, tiny_samples) = (Arc::clone(&mnist_samples), Arc::clone(&tiny_samples));
            let (mnist_want, tiny_want) = (Arc::clone(&mnist_want), Arc::clone(&tiny_want));
            std::thread::spawn(move || {
                let mut client = NetClient::connect(addr).expect("connect");
                let tenant = format!("tenant-{c}");
                for k in 0..PER_CLIENT {
                    // Clients 0-3 hit mnist, 4-7 hit tiny.
                    let (model, sample, want) = if c < 4 {
                        let i = c * PER_CLIENT + k;
                        ("mnist", &mnist_samples[i], &mnist_want[i])
                    } else {
                        let i = (c - 4) * PER_CLIENT + k;
                        ("tiny", &tiny_samples[i], &tiny_want[i])
                    };
                    let resp = client
                        .predict(model, &[sample.as_slice()], &[("X-Tenant", &tenant)])
                        .expect("predict io");
                    assert_eq!(resp.status, 200, "{}", resp.body);
                    let rows = decode_predictions(&resp).expect("decode");
                    assert_bitwise(&rows[0], want, &format!("{model} client {c} req {k}"));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // Metrics expose request, shed and per-agent counters.
    let mut client = NetClient::connect(addr).unwrap();
    let metrics = client.get("/metrics").unwrap();
    assert_eq!(metrics.status, 200);
    let text = &metrics.body;
    let ok = metric_value(text, "tf_fpga_http_responses_total{code=\"200\"}").unwrap();
    assert_eq!(ok, 48, "every request answered 200:\n{text}");
    let submitted = metric_value(text, "tf_fpga_serve_requests_total").unwrap();
    assert_eq!(submitted, 48);
    let a0 = metric_value(text, "tf_fpga_agent_dispatches_total{agent=\"ultra96-pl-0\"}").unwrap();
    let a1 = metric_value(text, "tf_fpga_agent_dispatches_total{agent=\"ultra96-pl-1\"}").unwrap();
    assert!(a0 >= 1 && a1 >= 1, "both pool agents served traffic: {a0}/{a1}");
    assert_eq!(metric_value(text, "tf_fpga_http_shed_total{reason=\"pending\"}"), Some(0));

    // The HTTP layer introduced no numeric drift anywhere: every serving
    // counter agrees.
    let rep = server.report();
    assert_eq!(rep.completed, 48);
    assert_eq!(rep.failed, 0);
    drop(client);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Load shedding: past --max-pending the server answers 429 + Retry-After
// immediately; admitted requests all complete correctly.
// ---------------------------------------------------------------------------

#[test]
fn overload_sheds_429_and_admitted_requests_complete() {
    // A 64-wide lane with an 800 ms flush deadline: admitted requests sit
    // in the batcher, holding their pending permits, while the rest of
    // the storm arrives and must shed.
    let mut server = start_http(
        vec![ModelSpec::new("mnist", policy(64, 800))],
        SessionOptions { dispatch_workers: 1, ..SessionOptions::native_only() },
        2,
        HttpServerConfig { workers: 12, max_pending: 3, ..HttpServerConfig::default() },
    );
    let addr = server.local_addr();

    const CLIENTS: usize = 10;
    let samples: Vec<Vec<f32>> = (0..CLIENTS)
        .map(|i| (0..784).map(|j| ((i * 31 + j) % 97) as f32 / 97.0).collect())
        .collect();
    let want = Arc::new(mnist_reference(&samples));
    let samples = Arc::new(samples);
    let barrier = Arc::new(Barrier::new(CLIENTS));

    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let samples = Arc::clone(&samples);
            let want = Arc::clone(&want);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut client = NetClient::connect(addr).expect("connect");
                barrier.wait();
                let resp = client
                    .predict("mnist", &[samples[c].as_slice()], &[])
                    .expect("predict io");
                match resp.status {
                    200 => {
                        let rows = decode_predictions(&resp).expect("decode");
                        assert_bitwise(&rows[0], &want[c], &format!("admitted client {c}"));
                        true
                    }
                    429 => {
                        assert!(
                            resp.header("retry-after").is_some(),
                            "429 must carry Retry-After: {:?}",
                            resp.headers
                        );
                        assert!(resp.body.contains("overloaded"), "{}", resp.body);
                        false
                    }
                    other => panic!("unexpected status {other}: {}", resp.body),
                }
            })
        })
        .collect();
    let outcomes: Vec<bool> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let ok = outcomes.iter().filter(|&&b| b).count();
    let shed = outcomes.len() - ok;
    assert_eq!(ok + shed, CLIENTS, "no request hung or vanished");
    // Exactly max-pending admitted in the common case; a client thread
    // descheduled past the 800 ms batch flush can be admitted on a freed
    // permit, so allow one straggler rather than flake under CI load.
    assert!(
        (3..=4).contains(&ok),
        "~max-pending admitted (got {ok} ok / {shed} shed)"
    );
    assert!(shed >= 6, "overload must shed: {shed}");

    let mut client = NetClient::connect(addr).unwrap();
    let text = client.get("/metrics").unwrap().body;
    assert_eq!(
        metric_value(&text, "tf_fpga_http_shed_total{reason=\"pending\"}"),
        Some(shed as u64),
        "{text}"
    );
    assert_eq!(
        metric_value(&text, "tf_fpga_http_responses_total{code=\"429\"}"),
        Some(shed as u64)
    );
    drop(client);
    server.shutdown();
    let rep = server.report();
    assert_eq!(rep.completed, ok as u64, "admitted requests all completed");
    assert_eq!(rep.failed, 0, "no in-flight request was dropped");
}

// ---------------------------------------------------------------------------
// Per-tenant token buckets: independent quotas, fair under overload.
// ---------------------------------------------------------------------------

#[test]
fn per_tenant_quota_sheds_fairly() {
    let mut server = start_http(
        vec![ModelSpec::from_bundle(
            "tiny",
            ModelBundle::tiny_fc_demo(2, 16, 4),
            policy(1, 1),
        )],
        SessionOptions { dispatch_workers: 2, ..SessionOptions::native_only() },
        4,
        HttpServerConfig {
            workers: 8,
            max_pending: 256,
            tenant_rps: 3,
            tenant_burst: 3,
            ..HttpServerConfig::default()
        },
    );
    let addr = server.local_addr();

    const PER_TENANT: usize = 20;
    let t0 = Instant::now();
    let handles: Vec<_> = ["alice", "bob"]
        .into_iter()
        .map(|tenant| {
            std::thread::spawn(move || {
                let mut client = NetClient::connect(addr).expect("connect");
                let sample = vec![0.25f32; 16];
                let mut ok = 0u64;
                let mut shed = 0u64;
                for _ in 0..PER_TENANT {
                    let resp = client
                        .predict("tiny", &[sample.as_slice()], &[("X-Tenant", tenant)])
                        .expect("predict io");
                    match resp.status {
                        200 => ok += 1,
                        429 => {
                            assert!(resp.header("retry-after").is_some());
                            assert!(resp.body.contains(tenant), "{}", resp.body);
                            shed += 1;
                        }
                        other => panic!("unexpected status {other}: {}", resp.body),
                    }
                }
                (ok, shed)
            })
        })
        .collect();
    let results: Vec<(u64, u64)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let elapsed_secs = t0.elapsed().as_secs_f64();

    // Each tenant gets its burst, plus at most rps·elapsed refills — and
    // a flood is definitely shed. Buckets are per tenant, so both see the
    // same quota regardless of who floods harder.
    let cap = 3 + (3.0 * elapsed_secs).ceil() as u64 + 1;
    for (who, (ok, shed)) in ["alice", "bob"].iter().zip(&results) {
        assert!(*ok >= 3, "{who} must get at least the burst, got {ok}");
        assert!(*ok <= cap, "{who} exceeded quota: {ok} > {cap} ({elapsed_secs:.2}s)");
        assert!(*shed >= 1, "{who} flooded and must see 429s");
        assert_eq!(ok + shed, PER_TENANT as u64);
    }
    let (a, b) = (results[0].0, results[1].0);
    let diff = a.abs_diff(b);
    // Scale the fairness bound with real elapsed time: a descheduled
    // thread legitimately accrues extra refills while the other waits.
    let fair_slack = 3 + (3.0 * elapsed_secs).ceil() as u64;
    assert!(
        diff <= fair_slack,
        "equal offered load should get near-equal quota: alice {a} vs bob {b} \
         (slack {fair_slack}, {elapsed_secs:.2}s)"
    );

    let mut client = NetClient::connect(addr).unwrap();
    let text = client.get("/metrics").unwrap().body;
    let tenant_shed = metric_value(&text, "tf_fpga_http_shed_total{reason=\"tenant\"}").unwrap();
    assert_eq!(tenant_shed, results[0].1 + results[1].1);
    drop(client);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Deadlines: an already-expired budget cancels before dispatch.
// ---------------------------------------------------------------------------

#[test]
fn expired_deadline_cancels_before_dispatch() {
    let mut server = start_http(
        vec![ModelSpec::from_bundle(
            "tiny",
            ModelBundle::tiny_fc_demo(2, 16, 4),
            policy(2, 1),
        )],
        SessionOptions { dispatch_workers: 2, ..SessionOptions::native_only() },
        2,
        HttpServerConfig::default(),
    );
    let addr = server.local_addr();
    let mut client = NetClient::connect(addr).unwrap();
    let sample = vec![0.5f32; 16];

    let resp = client
        .predict("tiny", &[sample.as_slice()], &[("X-Deadline-Ms", "0")])
        .unwrap();
    assert_eq!(resp.status, 504, "{}", resp.body);
    let doc = resp.json().unwrap();
    assert_eq!(doc.get("error").get("kind").as_str(), Some("deadline_exceeded"));

    let text = client.get("/metrics").unwrap().body;
    assert_eq!(
        metric_value(&text, "tf_fpga_serve_requests_total"),
        Some(0),
        "cancelled request never reached the pipeline:\n{text}"
    );
    assert_eq!(metric_value(&text, "tf_fpga_http_deadline_expired_total"), Some(1));

    // A generous deadline sails through.
    let resp = client
        .predict("tiny", &[sample.as_slice()], &[("X-Deadline-Ms", "30000")])
        .unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    // A malformed one is a client error.
    let resp = client
        .predict("tiny", &[sample.as_slice()], &[("X-Deadline-Ms", "soon")])
        .unwrap();
    assert_eq!(resp.status, 400, "{}", resp.body);
    drop(client);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Graceful drain: in-flight requests complete with correct results while
// new connections are refused.
// ---------------------------------------------------------------------------

#[test]
fn graceful_drain_completes_inflight_and_refuses_new_connections() {
    // 500 ms flush deadline keeps the in-flight request in the pipeline
    // while the drain begins around it.
    let mut server = start_http(
        vec![ModelSpec::new("mnist", policy(64, 500))],
        SessionOptions { dispatch_workers: 1, ..SessionOptions::native_only() },
        2,
        HttpServerConfig { workers: 4, ..HttpServerConfig::default() },
    );
    let addr = server.local_addr();

    let sample: Vec<f32> = (0..784).map(|j| (j % 89) as f32 / 89.0).collect();
    let want = mnist_reference(&[sample.clone()]);

    let inflight = {
        let sample = sample.clone();
        std::thread::spawn(move || {
            let mut client = NetClient::connect(addr).expect("connect");
            client.predict("mnist", &[sample.as_slice()], &[]).expect("predict io")
        })
    };
    // Let the in-flight request get admitted, then start the drain.
    std::thread::sleep(Duration::from_millis(150));
    let drainer = std::thread::spawn(move || {
        server.shutdown();
        server
    });
    // While the drain waits on the in-flight batch, new connections are
    // refused: either the accept loop answers 503, or the closed listener
    // resets the connection.
    std::thread::sleep(Duration::from_millis(100));
    // A connection-level error (refused/reset) is equally correct here.
    if let Ok(resp) = one_shot(addr, "GET", "/healthz", &[], None) {
        assert_eq!(resp.status, 503, "drain must refuse: {}", resp.body);
    }

    let resp = inflight.join().unwrap();
    assert_eq!(resp.status, 200, "in-flight request survived the drain: {}", resp.body);
    let rows = decode_predictions(&resp).expect("decode");
    assert_bitwise(&rows[0], &want[0], "drained in-flight request");

    let server = drainer.join().unwrap();
    let rep = server.report();
    assert_eq!(rep.completed, 1, "the in-flight request completed");
    assert_eq!(rep.failed, 0, "nothing was dropped by the drain");
}

// ---------------------------------------------------------------------------
// Continuous batching (tentpole): a request arriving while its bucket's
// batch is mid-flush — sealed but blocked acquiring a pipeline slot —
// rides that in-flight batch instead of waiting out a full flush cycle.
// ---------------------------------------------------------------------------

#[test]
fn late_arrival_rides_the_mid_flush_batch() {
    // One pipeline slot, and an agent whose every dispatch stalls 700 ms:
    // a plug request dispatches and holds the slot, the next flush seals
    // its batch and blocks on the slot, and a request arriving in that
    // window must late-join the sealed batch.
    let srv = AsyncInferenceServer::start(AsyncServerConfig {
        models: vec![ModelSpec::from_bundle(
            "tiny",
            ModelBundle::tiny_fc_demo(8, 16, 4),
            policy(8, 15),
        )],
        session: SessionOptions { dispatch_workers: 1, ..SessionOptions::native_only() },
        pipeline_depth: 1,
    })
    .expect("inference server");
    srv.session().router().agent(0).inject_faults(FaultPlan {
        stall_prob: 1.0,
        stall: Duration::from_millis(700),
        ..FaultPlan::none(0x1A7E_301B)
    });
    let mut server = HttpServer::start(srv, HttpServerConfig::default()).expect("http server");
    let addr = server.local_addr();

    let samples: Vec<Vec<f32>> = (0..3)
        .map(|i| (0..16).map(|j| (i * 5 + j) as f32 * 0.11 - 0.9).collect())
        .collect();
    let want = tiny_reference(&samples);

    let handles: Vec<_> = samples
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let s = s.clone();
            std::thread::spawn(move || {
                // 0 = the plug (flushes alone at 15 ms and stalls on the
                // agent), 1 = seals the next batch at ~215 ms and blocks
                // mid-flush, 2 = arrives inside that window.
                std::thread::sleep(Duration::from_millis([0, 200, 400][i]));
                let mut client = NetClient::connect(addr).expect("connect");
                client.predict("tiny", &[s.as_slice()], &[]).expect("predict io")
            })
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.join().unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let rows = decode_predictions(&resp).expect("decode");
        assert_bitwise(&rows[0], &want[i], &format!("request {i}"));
    }

    let mut client = NetClient::connect(addr).unwrap();
    let text = client.get("/metrics").unwrap().body;
    assert_eq!(
        metric_value(&text, "tf_fpga_serve_late_joins_total"),
        Some(1),
        "request 2 must join request 1's sealed batch:\n{text}"
    );
    assert_eq!(
        metric_value(&text, "tf_fpga_serve_batches_total"),
        Some(2),
        "three requests, two batches:\n{text}"
    );
    drop(client);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Binary wire path (tentpole): `:predict-bin` answers the exact bits the
// JSON tier and the Model facade produce, and no request bytes are ever
// copied between the socket and the batch tensor.
// ---------------------------------------------------------------------------

#[test]
fn binary_wire_path_is_bitwise_equal_and_copy_free() {
    let mut server = start_http(
        vec![ModelSpec::from_bundle(
            "tiny",
            ModelBundle::tiny_fc_demo(4, 16, 4),
            policy(4, 2),
        )],
        SessionOptions { dispatch_workers: 2, ..SessionOptions::native_only() },
        2,
        HttpServerConfig::default(),
    );
    let addr = server.local_addr();
    let mut client = NetClient::connect(addr).unwrap();

    let samples: Vec<Vec<f32>> = (0..4)
        .map(|i| (0..16).map(|j| ((i * 7 + j) as f32).sin()).collect())
        .collect();
    let want = tiny_reference(&samples);
    let refs: Vec<&[f32]> = samples.iter().map(|s| s.as_slice()).collect();

    // One 4-row binary request; the reply mirrors the binary encoding.
    let resp = client.predict_bin("tiny", &[16], &refs, &[]).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("content-type"), Some(TENSOR_CONTENT_TYPE));
    let bin_rows = decode_predictions_bin(&resp).unwrap();
    assert_eq!(bin_rows.len(), 4);
    for (i, row) in bin_rows.iter().enumerate() {
        assert_bitwise(row, &want[i], &format!("binary row {i}"));
    }

    // The JSON tier answers the same bits.
    let resp = client.predict("tiny", &refs, &[]).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let json_rows = decode_predictions(&resp).unwrap();
    assert_eq!(json_rows.len(), 4);
    for (i, row) in json_rows.iter().enumerate() {
        assert_bitwise(row, &want[i], &format!("json row {i}"));
    }

    // Every HTTP tier decodes rows straight into the lane's staging
    // buffer — the serving pipeline never copied request bytes.
    let text = client.get("/metrics").unwrap().body;
    assert_eq!(
        metric_value(&text, "tf_fpga_serve_bytes_copied_total"),
        Some(0),
        "zero-copy ingestion:\n{text}"
    );
    assert_eq!(metric_value(&text, "tf_fpga_serve_requests_total"), Some(8));
    drop(client);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Structured error surfaces (satellite): every client mistake maps to a
// JSON body naming the endpoint and expected-vs-got meta.
// ---------------------------------------------------------------------------

#[test]
fn structured_error_bodies_name_endpoint_and_meta() {
    let mut server = start_http(
        vec![ModelSpec::from_bundle(
            "tiny",
            ModelBundle::tiny_fc_demo(2, 16, 4),
            policy(2, 1),
        )],
        SessionOptions { dispatch_workers: 2, ..SessionOptions::native_only() },
        2,
        HttpServerConfig { max_body_bytes: 4096, ..HttpServerConfig::default() },
    );
    let addr = server.local_addr();
    let mut client = NetClient::connect(addr).unwrap();

    // Unknown model: 404 naming the model and listing what is served.
    let resp = client.predict("nope", &[[0.0f32; 16].as_slice()], &[]).unwrap();
    assert_eq!(resp.status, 404);
    let err = resp.json().unwrap();
    assert_eq!(err.get("error").get("kind").as_str(), Some("unknown_model"));
    assert!(err.get("error").get("message").as_str().unwrap().contains("nope"));
    assert_eq!(err.get("error").get("models").idx(0).as_str(), Some("tiny"));

    // Shape mismatch: 400 with endpoint plus expected-vs-got meta.
    let resp = client.predict("tiny", &[[0.0f32; 3].as_slice()], &[]).unwrap();
    assert_eq!(resp.status, 400);
    let err = resp.json().unwrap();
    let e = err.get("error");
    assert_eq!(e.get("kind").as_str(), Some("shape_mismatch"));
    assert_eq!(e.get("endpoint").as_str(), Some("x"));
    assert_eq!(e.get("expected_elems").as_usize(), Some(16));
    assert_eq!(e.get("got_elems").as_usize(), Some(3));
    assert_eq!(e.get("expected_shape").idx(0).as_usize(), Some(16));
    let msg = e.get("message").as_str().unwrap();
    assert!(
        msg.contains("tiny") && msg.contains("16") && msg.contains("3"),
        "message mirrors the Model facade's wording: {msg}"
    );

    // Unknown endpoint in a named feed: 400 naming expected vs got.
    let body = r#"{"inputs": {"y": [1,2,3]}}"#;
    let resp = client
        .request("POST", "/v1/models/tiny:predict", &[], Some(body))
        .unwrap();
    assert_eq!(resp.status, 400);
    let err = resp.json().unwrap();
    let e = err.get("error");
    assert_eq!(e.get("kind").as_str(), Some("unknown_endpoint"));
    assert_eq!(e.get("endpoint").as_str(), Some("y"));
    assert_eq!(e.get("expected_endpoint").as_str(), Some("x"));

    // Malformed JSON.
    let resp = client
        .request("POST", "/v1/models/tiny:predict", &[], Some("{not json"))
        .unwrap();
    assert_eq!(resp.status, 400);
    assert_eq!(
        resp.json().unwrap().get("error").get("kind").as_str(),
        Some("bad_request")
    );

    // Adversarial nesting: named kind from the hardened JSON parser.
    let bomb = "[".repeat(2048);
    let resp = client
        .request("POST", "/v1/models/tiny:predict", &[], Some(&bomb))
        .unwrap();
    assert_eq!(resp.status, 400);
    assert_eq!(resp.json().unwrap().get("error").get("kind").as_str(), Some("too_deep"));

    // Oversized body: refused from Content-Length alone (413), with the
    // same named kind the body-level check would use.
    let huge = format!("{{\"instances\": [[{}]]}}", vec!["0.1"; 4096].join(","));
    let resp = client
        .request("POST", "/v1/models/tiny:predict", &[], Some(&huge))
        .unwrap();
    assert_eq!(resp.status, 413, "{}", resp.body);
    assert_eq!(
        resp.json().unwrap().get("error").get("kind").as_str(),
        Some("payload_too_large")
    );

    // Empty instances.
    let resp = client
        .request("POST", "/v1/models/tiny:predict", &[], Some("{\"instances\": []}"))
        .unwrap();
    assert_eq!(resp.status, 400, "{}", resp.body);

    // Too many instances for one request's admission charge.
    let many = format!("{{\"instances\": [{}]}}", vec!["[0.5]"; 65].join(","));
    let resp = client
        .request("POST", "/v1/models/tiny:predict", &[], Some(&many))
        .unwrap();
    assert_eq!(resp.status, 400, "{}", resp.body);
    assert!(resp.body.contains("64"), "names the limit: {}", resp.body);

    // After all that abuse, a good request still works on the same client.
    let resp = client.predict("tiny", &[[0.5f32; 16].as_slice()], &[]).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    drop(client);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Request-scoped tracing acceptance: one traced predict yields an echoed
// request id, a flight-recorder window whose stage spans account for the
// measured end-to-end latency, and non-zero per-stage Prometheus
// histograms on /metrics.
// ---------------------------------------------------------------------------

#[test]
fn traced_request_spans_cover_e2e_latency_and_feed_histograms() {
    use tf_fpga::util::json::Json;

    // A batch window wide enough that batch_wait dominates the request:
    // a lone request sits out the full max_delay in its lane, so most of
    // the end-to-end latency is time the span breakdown must account for.
    let mut server = start_http(
        vec![ModelSpec::from_bundle("tiny", ModelBundle::tiny_fc_demo(4, 16, 4), policy(4, 25))],
        SessionOptions { dispatch_workers: 2, ..SessionOptions::native_only() },
        2,
        HttpServerConfig {
            // Exercise the slow-request log path too: every request over
            // 1ms logs its breakdown to stderr.
            slow_request: Duration::from_millis(1),
            ..HttpServerConfig::default()
        },
    );
    let addr = server.local_addr();
    let mut client = NetClient::connect(addr).unwrap();

    let sample: Vec<f32> = (0..16).map(|i| i as f32 * 0.11 - 0.9).collect();
    let started = Instant::now();
    let resp = client
        .predict(
            "tiny",
            &[sample.as_slice()],
            &[("X-Request-Id", "trace-me-1"), ("X-Debug-Timing", "1")],
        )
        .unwrap();
    let e2e_us = started.elapsed().as_micros() as u64;
    assert_eq!(resp.status, 200, "{}", resp.body);

    // (a) The inbound id is echoed, and the opt-in X-Timing header
    // carries a per-stage breakdown ending in the total.
    assert_eq!(resp.request_id(), Some("trace-me-1"));
    let timing = resp.timing().expect("X-Timing header");
    let total = timing.iter().find(|(k, _)| k == "total").expect("total entry").1;
    assert!(total <= e2e_us, "server total {total}us inside client e2e {e2e_us}us");
    for stage in ["admission_wait", "batch_wait", "kernel_exec", "reply_serialize"] {
        assert!(timing.iter().any(|(k, _)| k == stage), "missing {stage} in {timing:?}");
    }

    // (b) The flight recorder holds the request's track with every
    // pipeline stage; the disjoint stages sum to within 20% of the
    // measured end-to-end latency.
    let trace = client.get("/v1/debug/trace").unwrap();
    assert_eq!(trace.status, 200);
    let doc = Json::parse(&trace.body).expect("chrome-trace JSON parses");
    let events = doc.get("traceEvents").as_arr().expect("traceEvents array");
    let req_pid = events
        .iter()
        .find(|e| {
            e.get("ph").as_str() == Some("M")
                && e.get("args").get("name").as_str() == Some("req:trace-me-1")
        })
        .and_then(|e| e.get("pid").as_usize())
        .expect("request track registered");
    let spans: Vec<(&str, u64)> = events
        .iter()
        .filter(|e| {
            e.get("ph").as_str() == Some("X") && e.get("pid").as_usize() == Some(req_pid)
        })
        .filter_map(|e| Some((e.get("name").as_str()?, e.get("dur").as_usize()? as u64)))
        .collect();
    let disjoint = [
        "admission_wait",
        "batch_wait",
        "batch_assembly",
        "route",
        "kernel_exec",
        "reply_serialize",
    ];
    for stage in disjoint.iter().chain(&["reconfig_stall"]) {
        assert!(spans.iter().any(|(n, _)| n == stage), "missing {stage} span in {spans:?}");
    }
    let span_sum: u64 = spans
        .iter()
        .filter(|(n, _)| disjoint.contains(n))
        .map(|&(_, dur)| dur)
        .sum();
    let (lo, hi) = ((e2e_us as f64 * 0.8) as u64, (e2e_us as f64 * 1.2) as u64);
    assert!(
        (lo..=hi).contains(&span_sum),
        "disjoint span sum {span_sum}us outside 20% of e2e {e2e_us}us ({spans:?})"
    );

    // (c) The per-stage Prometheus histograms saw the request.
    let metrics = client.get("/metrics").unwrap();
    assert_eq!(metrics.status, 200);
    for stage in ["admission_wait", "batch_wait", "kernel_exec", "reply_serialize"] {
        let prefix = format!("tf_fpga_stage_latency_us_count{{stage=\"{stage}\"}}");
        let count = metric_value(&metrics.body, &prefix).unwrap_or(0);
        assert!(count >= 1, "{prefix} is zero:\n{}", metrics.body);
    }
    assert!(
        metrics.body.contains("tf_fpga_stage_latency_us_bucket{stage=\"batch_wait\",le=\"+Inf\"}"),
        "{}",
        metrics.body
    );

    // A zero-width window (`last_ms=0`) still parses; completed spans
    // fall outside it.
    let windowed = client.get("/v1/debug/trace?last_ms=0").unwrap();
    assert_eq!(windowed.status, 200);
    Json::parse(&windowed.body).expect("windowed export parses");

    drop(client);
    server.shutdown();
}
