//! Integration: the HSA runtime under realistic multi-agent, multi-queue,
//! multi-client load.

use std::sync::Arc;
use std::time::Duration;
use tf_fpga::cpu::a53::CpuKernelClass;
use tf_fpga::cpu::device::{CpuAgent, CpuKernel};
use tf_fpga::fpga::device::{ComputeBinding, FpgaAgent, FpgaConfig};
use tf_fpga::fpga::roles;
use tf_fpga::hsa::agent::DeviceType;
use tf_fpga::hsa::runtime::HsaRuntime;
use tf_fpga::hsa::signal::Signal;
use tf_fpga::reconfig::policy::PolicyKind;
use tf_fpga::tf::tensor::Tensor;

fn echo_binding() -> ComputeBinding {
    ComputeBinding::Native(Arc::new(|ins: &[Tensor]| Ok(ins.to_vec())))
}

fn full_runtime() -> (HsaRuntime, u64, u64) {
    let cpu = CpuAgent::with_defaults();
    let cpu_kernel = cpu.register_kernel(CpuKernel {
        name: "relu".into(),
        func: Arc::new(|ins| Ok(vec![tf_fpga::ops::relu_f32(&ins[0])?])),
        class: CpuKernelClass::Memory,
        op_template: None,
    });
    let fpga = FpgaAgent::new(FpgaConfig {
        num_regions: 2,
        policy: PolicyKind::Lru.build(0),
        realtime: false,
        realtime_scale: 1.0,
        trace: None,
    });
    let fpga_kernel = fpga.register_role(roles::paper_roles().remove(0), echo_binding());
    let rt = HsaRuntime::builder().with_agent(cpu).with_agent(fpga).build();
    (rt, cpu_kernel, fpga_kernel)
}

#[test]
fn cpu_and_fpga_agents_coexist() {
    let (rt, cpu_k, fpga_k) = full_runtime();
    let qc = rt.create_queue(rt.agent_by_type(DeviceType::Cpu).unwrap(), 32);
    let qf = rt.create_queue(rt.agent_by_type(DeviceType::Fpga).unwrap(), 32);
    let t = Tensor::from_f32(&[2], vec![-1.0, 1.0]).unwrap();
    let out_c = rt.dispatch_sync(&qc, cpu_k, vec![t.clone()]).unwrap();
    assert_eq!(out_c[0].as_f32().unwrap(), &[0.0, 1.0]);
    let out_f = rt.dispatch_sync(&qf, fpga_k, vec![t.clone()]).unwrap();
    assert_eq!(out_f[0], t);
    rt.shutdown();
}

#[test]
fn many_concurrent_clients_one_device() {
    let (rt, _cpu_k, fpga_k) = full_runtime();
    let rt = Arc::new(rt);
    let q = rt.create_queue(rt.agent_by_type(DeviceType::Fpga).unwrap(), 64);
    let handles: Vec<_> = (0..8)
        .map(|c| {
            let rt = Arc::clone(&rt);
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..40 {
                    let t = Tensor::from_f32(&[2], vec![c as f32, i as f32]).unwrap();
                    let out = rt.dispatch_sync(&q, fpga_k, vec![t.clone()]).unwrap();
                    assert_eq!(out[0], t, "client {c} iteration {i}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    rt.shutdown();
}

#[test]
fn barrier_chains_across_queues() {
    let (rt, cpu_k, fpga_k) = full_runtime();
    let qc = rt.create_queue(rt.agent_by_type(DeviceType::Cpu).unwrap(), 32);
    let qf = rt.create_queue(rt.agent_by_type(DeviceType::Fpga).unwrap(), 32);
    let t = Tensor::from_f32(&[1], vec![1.0]).unwrap();

    let (fpga_done, _args) = rt.dispatch_async(&qf, fpga_k, vec![t.clone()]).unwrap();
    let barrier_done = rt.barrier(&qc, vec![fpga_done.clone()]).unwrap();
    let (cpu_done, _args2) = rt.dispatch_async(&qc, cpu_k, vec![t]).unwrap();
    cpu_done.wait_eq(0, Some(Duration::from_secs(10))).unwrap();
    barrier_done.wait_eq(0, Some(Duration::from_secs(10))).unwrap();
    assert_eq!(fpga_done.load(), 0);
    rt.shutdown();
}

#[test]
fn deep_pipeline_async_dispatches_all_retire() {
    let (rt, _cpu_k, fpga_k) = full_runtime();
    let q = rt.create_queue(rt.agent_by_type(DeviceType::Fpga).unwrap(), 16);
    let mut signals: Vec<Signal> = Vec::new();
    for i in 0..64 {
        let t = Tensor::from_f32(&[1], vec![i as f32]).unwrap();
        let (sig, _args) = rt.dispatch_async(&q, fpga_k, vec![t]).unwrap();
        signals.push(sig);
    }
    for (i, s) in signals.iter().enumerate() {
        assert_eq!(
            s.wait_eq(0, Some(Duration::from_secs(10))).unwrap(),
            0,
            "dispatch {i}"
        );
    }
    rt.shutdown();
}

#[test]
fn memory_pools_track_usage_across_threads() {
    let pools = tf_fpga::hsa::memory::ultra96_regions();
    let global = pools
        .iter()
        .find(|p| p.info().name == "lpddr4-global")
        .unwrap()
        .clone();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let pool = global.clone();
            std::thread::spawn(move || {
                let mut ids = Vec::new();
                for _ in 0..50 {
                    ids.push(pool.alloc(4096).unwrap());
                }
                for id in ids {
                    pool.free(id).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(global.used_bytes(), 0, "all freed");
    assert_eq!(global.live_allocations(), 0);
    assert!(global.peak_bytes() >= 4096 * 50, "peak witnessed some load");
}

#[test]
fn dispatch_after_shutdown_errors() {
    let (rt, _cpu_k, fpga_k) = full_runtime();
    let q = rt.create_queue(rt.agent_by_type(DeviceType::Fpga).unwrap(), 8);
    rt.shutdown();
    let t = Tensor::from_f32(&[1], vec![0.0]).unwrap();
    assert!(rt.dispatch_sync(&q, fpga_k, vec![t]).is_err());
}

#[test]
fn failed_fpga_kernel_reports_error_not_hang() {
    let fpga = FpgaAgent::with_defaults();
    let failing = fpga.register_role(
        roles::paper_roles().remove(0),
        ComputeBinding::Native(Arc::new(|_ins: &[Tensor]| {
            Err(tf_fpga::hsa::error::HsaError::KernelFailed("boom".into()))
        })),
    );
    let rt = HsaRuntime::builder().with_agent(fpga).build();
    let q = rt.create_queue(rt.agent_by_type(DeviceType::Fpga).unwrap(), 8);
    let err = rt
        .dispatch_sync(&q, failing, vec![Tensor::from_f32(&[1], vec![0.0]).unwrap()])
        .unwrap_err();
    assert!(err.to_string().contains("boom"), "{err}");
    rt.shutdown();
}

#[test]
fn shared_fpga_two_tenants_interleave_correctly() {
    // Condensed multi_tenant example as a regression test.
    let fpga = FpgaAgent::new(FpgaConfig {
        num_regions: 2,
        policy: PolicyKind::Lru.build(0),
        realtime: false,
        realtime_scale: 1.0,
        trace: None,
    });
    let paper = roles::paper_roles();
    let a = fpga.register_role(paper[2].clone(), echo_binding());
    let b = fpga.register_role(paper[3].clone(), echo_binding());
    let c = fpga.register_role(roles::preprocess_role(), echo_binding());
    let rt = Arc::new(HsaRuntime::builder().with_agent(fpga.clone()).build());
    let q1 = rt.create_queue(rt.agent_by_type(DeviceType::Fpga).unwrap(), 32);
    let q2 = rt.create_queue(rt.agent_by_type(DeviceType::Fpga).unwrap(), 32);

    let t1 = {
        let rt = Arc::clone(&rt);
        std::thread::spawn(move || {
            let x = Tensor::from_i16(&[1, 28, 28], vec![0; 784]).unwrap();
            for i in 0..60 {
                let k = if i % 2 == 0 { a } else { b };
                rt.dispatch_sync(&q1, k, vec![x.clone()]).unwrap();
            }
        })
    };
    let t2 = {
        let rt = Arc::clone(&rt);
        std::thread::spawn(move || {
            let x = Tensor::from_i16(&[784], vec![0; 784]).unwrap();
            for _ in 0..60 {
                rt.dispatch_sync(&q2, c, vec![x.clone()]).unwrap();
            }
        })
    };
    t1.join().unwrap();
    t2.join().unwrap();
    let s = fpga.reconfig_stats();
    assert_eq!(s.dispatches, 120);
    assert_eq!(s.hits + s.misses, s.dispatches, "accounting closes");
    assert!(s.evictions > 0, "3 roles over 2 regions must evict");
    rt.shutdown();
}
