//! Model-bundle integration: save/load round trips, end-to-end serving of
//! loaded bundles with non-MNIST shapes, and (when `TF_FPGA_BUNDLE_DIR`
//! points at a directory of bundles exported by the Python frontend via
//! `python -m compile.export`) the cross-language Python → Rust loop.

use std::path::PathBuf;
use std::time::Duration;
use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig, BatchPolicy, ModelSpec};
use tf_fpga::tf::model::{Model, ModelBundle};
use tf_fpga::tf::session::SessionOptions;
use tf_fpga::tf::tensor::Tensor;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("tf_fpga_bundle_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn policy(max_batch: usize, delay_ms: u64) -> BatchPolicy {
    BatchPolicy { max_batch, max_delay: Duration::from_millis(delay_ms) }
}

#[test]
fn saved_bundles_reload_and_serve_end_to_end() {
    let dir = tmpdir("serve");
    ModelBundle::mnist_demo(32).save(dir.join("mnist")).unwrap();
    ModelBundle::tiny_fc_demo(8, 16, 4).save(dir.join("tiny_fc")).unwrap();

    // Load from disk — not the in-memory originals — and serve both from
    // one async server; each lane picks its own (overriding) batch dim.
    let mnist = ModelSpec::from_dir(dir.join("mnist"), policy(4, 2)).unwrap();
    let tiny = ModelSpec::from_dir(dir.join("tiny_fc"), policy(2, 2)).unwrap();
    assert_eq!(mnist.name, "mnist");
    assert_eq!(tiny.name, "tiny_fc");
    let mut srv = AsyncInferenceServer::start(AsyncServerConfig {
        models: vec![mnist, tiny],
        session: SessionOptions { dispatch_workers: 2, ..SessionOptions::native_only() },
        pipeline_depth: 2,
    })
    .unwrap();

    let logits = srv.infer("mnist", vec![0.25; 784]).unwrap();
    assert_eq!(logits.len(), 10);
    let row = srv.infer("tiny_fc", vec![0.5; 16]).unwrap();
    assert_eq!(row.len(), 4);
    let rep = srv.report();
    assert_eq!(rep.completed, 2);
    assert_eq!(rep.failed, 0);
    srv.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn loaded_bundle_invokes_identically_to_the_original() {
    let dir = tmpdir("invoke");
    let original = ModelBundle::tiny_fc_demo(4, 16, 4);
    original.save(&dir).unwrap();
    let loaded = ModelBundle::load(&dir).unwrap();

    let m1 = Model::from_bundle(original, SessionOptions::native_only()).unwrap();
    let m2 = Model::from_bundle(loaded, SessionOptions::native_only()).unwrap();
    let x = Tensor::from_f32(&[4, 16], (0..64).map(|i| (i as f32) * 0.03 - 1.0).collect())
        .unwrap();
    let a = m1.invoke("serve", &[("x", x.clone())]).unwrap();
    let b = m2.invoke("serve", &[("x", x)]).unwrap();
    assert_eq!(a[0], b[0], "embedded weights must survive the JSON round trip bitwise");
    m1.shutdown();
    m2.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn layered_bundle_resolves_artifact_refs_after_reload() {
    let dir = tmpdir("layers");
    let bundle = ModelBundle::mnist_layers_demo();
    assert!(!bundle.artifact_refs().is_empty());
    bundle.save(&dir).unwrap();
    let model = Model::load(&dir, SessionOptions::native_only()).unwrap();
    let out = model
        .invoke("serve", &[("x", Tensor::zeros(&[1, 28, 28], tf_fpga::tf::DType::F32))])
        .unwrap();
    assert_eq!(out[0].shape(), &[1, 10]);
    model.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Python → Rust interop: CI exports bundles with the Python frontend and
/// points `TF_FPGA_BUNDLE_DIR` here. Every bundle in the directory must
/// load, bring up a session, and produce outputs matching its declared
/// signature metas. Skipped (with a note) when the env var is unset.
#[test]
fn python_exported_bundles_load_and_invoke() {
    let Ok(dir) = std::env::var("TF_FPGA_BUNDLE_DIR") else {
        eprintln!("skipped: TF_FPGA_BUNDLE_DIR not set (CI exports bundles from Python)");
        return;
    };
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("bundle dir readable") {
        let path = entry.expect("dir entry").path();
        if !path.join("model.json").is_file() {
            continue;
        }
        seen += 1;
        let bundle = ModelBundle::load(&path)
            .unwrap_or_else(|e| panic!("load {}: {e}", path.display()));
        let model = Model::from_bundle(bundle.clone(), SessionOptions::native_only())
            .unwrap_or_else(|e| panic!("session for {}: {e}", bundle.name));
        for sig in &bundle.signatures {
            let feeds_owned: Vec<(String, Tensor)> = sig
                .inputs
                .iter()
                .map(|e| (e.name.clone(), Tensor::zeros(&e.shape, e.dtype)))
                .collect();
            let feeds: Vec<(&str, Tensor)> =
                feeds_owned.iter().map(|(n, t)| (n.as_str(), t.clone())).collect();
            let outs = model
                .invoke(&sig.name, &feeds)
                .unwrap_or_else(|e| panic!("invoke {}:{}: {e}", bundle.name, sig.name));
            for (out, ep) in outs.iter().zip(&sig.outputs) {
                assert_eq!(
                    out.shape(),
                    ep.shape.as_slice(),
                    "{}:{} output '{}' shape",
                    bundle.name,
                    sig.name,
                    ep.name
                );
                assert_eq!(out.dtype(), ep.dtype);
            }
        }
        model.shutdown();
        println!("ok: python bundle '{}' invoked through the Rust stack", bundle.name);
    }
    assert!(seen > 0, "TF_FPGA_BUNDLE_DIR={dir} holds no bundles");
}
