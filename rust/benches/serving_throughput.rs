//! Serving throughput: synchronous lock-step pipeline vs the async
//! batched pipeline at compiled batch sizes 1, 8 and 32, then the async
//! pipeline scaled across FPGA pool sizes 1, 2 and 4.
//! `cargo bench --bench serving_throughput`.
//!
//! Both servers run the same `mnist_cnn` kernel with the same weights and
//! the same client drive (a pool of blocking clients issuing single-image
//! requests). The only variable is the pipeline: the sync server forms,
//! executes and delivers one batch at a time; the async server overlaps
//! all three stages and keeps several batches in flight across queue
//! processors. The pool series pins one packet processor per agent queue,
//! so the only parallelism left is the pool itself — N agents execute N
//! batches concurrently. Environment knobs: `SERVE_N` total requests per
//! configuration (default 256), `SERVE_CLIENTS` concurrent clients
//! (default 8).

use std::sync::Arc;
use std::time::{Duration, Instant};
use tf_fpga::bench::{write_and_check, BenchArtifact};
use tf_fpga::serve::{
    AsyncInferenceServer, AsyncServerConfig, BatchPolicy, InferenceServer, ModelSpec,
    ServerConfig,
};
use tf_fpga::tf::session::SessionOptions;

/// Committed floor values for `--check` (absolute throughput is nulled
/// out there — machine-dependent — only scaling ratios gate).
const BASELINE: &str = include_str!("baselines/BENCH_serving.json");

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn policy(max_batch: usize) -> BatchPolicy {
    BatchPolicy { max_batch, max_delay: Duration::from_millis(2) }
}

/// Drive `total` blocking requests from `clients` threads; return elapsed.
fn drive(clients: usize, total: usize, infer: impl Fn(Vec<f32>) -> bool + Send + Sync + 'static) -> Duration {
    let infer = Arc::new(infer);
    let t0 = Instant::now();
    let per_client = total / clients;
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let infer = Arc::clone(&infer);
            std::thread::spawn(move || {
                for i in 0..per_client {
                    let image = vec![((c * per_client + i) % 255) as f32 / 255.0; 784];
                    assert!(infer(image), "request failed");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed()
}

fn main() {
    let total = env_usize("SERVE_N", 256);
    let clients = env_usize("SERVE_CLIENTS", 8);
    let total = (total / clients).max(1) * clients; // divisible by clients

    println!(
        "serving_throughput: {total} requests, {clients} clients, per batch size:\n"
    );
    println!(
        "{:<12} {:>12} {:>12} {:>9}   (req/s, higher is better)",
        "batch size", "sync", "async", "speedup"
    );

    let mut artifact = BenchArtifact::new("serving");
    artifact.set_u64("requests", total as u64);
    artifact.set_u64("clients", clients as u64);

    let mut all_faster = true;
    for max_batch in [1usize, 8, 32] {
        // --- synchronous lock-step baseline ---
        let sync_rps = {
            let srv = Arc::new(
                InferenceServer::start(ServerConfig {
                    batch: policy(max_batch),
                    session: SessionOptions::native_only(),
                    ..ServerConfig::default()
                })
                .expect("sync server"),
            );
            let s2 = Arc::clone(&srv);
            let elapsed =
                drive(clients, total, move |img| s2.infer(img).is_ok());
            let rps = total as f64 / elapsed.as_secs_f64();
            // All client clones are gone after drive(); unwrap and stop.
            if let Ok(mut s) = Arc::try_unwrap(srv) {
                s.stop();
            }
            rps
        };

        // --- async batched pipeline ---
        let async_rps = {
            let srv = Arc::new(
                AsyncInferenceServer::start(AsyncServerConfig {
                    models: vec![ModelSpec::new("mnist", policy(max_batch))],
                    session: SessionOptions {
                        dispatch_workers: 4,
                        ..SessionOptions::native_only()
                    },
                    pipeline_depth: 4,
                })
                .expect("async server"),
            );
            let s2 = Arc::clone(&srv);
            let elapsed =
                drive(clients, total, move |img| s2.infer("mnist", img).is_ok());
            let rps = total as f64 / elapsed.as_secs_f64();
            let rep = srv.report();
            println!(
                "  [async b{max_batch}: fill {:.1}, max in-flight {}, p99 {} µs]",
                rep.mean_batch_fill, rep.max_inflight, rep.latency_us_p99
            );
            let prefix = format!("async.batch_{max_batch}");
            artifact.set_f64(&format!("{prefix}.req_s"), rps);
            artifact.set_u64(&format!("{prefix}.p50_us"), rep.latency_us_p50);
            artifact.set_u64(&format!("{prefix}.p99_us"), rep.latency_us_p99);
            artifact.set_f64(&format!("{prefix}.batch_fill"), rep.mean_batch_fill);
            artifact.set_f64(&format!("{prefix}.fill_ratio"), rep.batch_fill_ratio);
            artifact.set_u64(&format!("{prefix}.reconfigs"), rep.reconfig.misses);
            if let Ok(mut s) = Arc::try_unwrap(srv) {
                s.stop();
            }
            rps
        };

        let speedup = async_rps / sync_rps;
        all_faster &= speedup > 1.0;
        artifact.set_f64(&format!("sync.batch_{max_batch}.req_s"), sync_rps);
        artifact.set_f64(&format!("speedup.batch_{max_batch}"), speedup);
        println!(
            "{:<12} {:>12.1} {:>12.1} {:>8.2}x",
            max_batch, sync_rps, async_rps, speedup
        );
    }

    // --- multi-FPGA scaling series: async pipeline, pool 1 vs 2 vs 4 ---
    //
    // dispatch_workers = 1: each agent queue has exactly one packet
    // processor, so per-agent kernel execution is serialized and the pool
    // size is the concurrency. Least-loaded routing spreads the batches.
    println!(
        "\n{:<12} {:>12} {:>9}   (req/s, batch 8, least-loaded routing)",
        "fpga pool", "async", "scaling"
    );
    let mut base_rps = 0.0;
    let mut pool2_scaling = 0.0;
    for pool in [1usize, 2, 4] {
        let srv = Arc::new(
            AsyncInferenceServer::start(AsyncServerConfig {
                models: vec![ModelSpec::new("mnist", policy(8))],
                session: SessionOptions {
                    dispatch_workers: 1,
                    fpga_pool: pool,
                    shard_strategy: tf_fpga::sharding::ShardStrategy::LeastLoaded,
                    ..SessionOptions::native_only()
                },
                pipeline_depth: 8,
            })
            .expect("pooled async server"),
        );
        let s2 = Arc::clone(&srv);
        let elapsed = drive(clients, total, move |img| s2.infer("mnist", img).is_ok());
        let rps = total as f64 / elapsed.as_secs_f64();
        let rep = srv.report();
        let shards: Vec<String> = rep
            .pool
            .iter()
            .map(|s| format!("{}:{}", s.agent, s.dispatches))
            .collect();
        println!("  [pool {pool}: dispatches {}]", shards.join(" "));
        if pool == 1 {
            base_rps = rps;
        }
        let scaling = if base_rps > 0.0 { rps / base_rps } else { 1.0 };
        if pool == 2 {
            pool2_scaling = scaling;
        }
        artifact.set_f64(&format!("pool_scaling.pool_{pool}.req_s"), rps);
        artifact.set_f64(&format!("pool_scaling.pool_{pool}.scaling"), scaling);
        println!("{:<12} {:>12.1} {:>8.2}x", pool, rps, scaling);
        if let Ok(mut s) = Arc::try_unwrap(srv) {
            s.stop();
        }
    }

    // Artifact + optional baseline gate before the existing pass/fail
    // logic, so CI always gets the JSON even on a failing run.
    match write_and_check(&artifact, BASELINE) {
        Ok(regs) if regs.is_empty() => {}
        Ok(regs) => {
            for r in &regs {
                println!("REGRESSION: {r}");
            }
            std::process::exit(1);
        }
        Err(e) => {
            println!("bench artifact error: {e}");
            std::process::exit(1);
        }
    }

    if all_faster && pool2_scaling >= 1.5 {
        println!(
            "\nserving_throughput: OK (async > sync at every batch size; \
             pool 2 scaled {pool2_scaling:.2}x >= 1.5x)"
        );
    } else if all_faster {
        println!(
            "\nserving_throughput: WARNING — pool 2 scaled only \
             {pool2_scaling:.2}x (< 1.5x target; single-core host?)"
        );
        std::process::exit(1);
    } else {
        println!("\nserving_throughput: WARNING — async did not beat sync everywhere");
        std::process::exit(1);
    }
}
