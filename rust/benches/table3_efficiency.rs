//! Bench: regenerate Table III (OP/cycle increase over the A53) from real
//! dispatches, n=1000. `cargo bench --bench table3_efficiency`.

use tf_fpga::bench::tables::table3;

fn main() {
    let n = std::env::var("TABLE3_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let (t, rows) = table3(n);
    println!("{t}");
    for r in &rows {
        let err = (r.increase - r.paper_increase).abs() / r.paper_increase;
        println!(
            "{}: {:.2}x vs paper {:.2}x ({:+.2}%)",
            r.role,
            r.increase,
            r.paper_increase,
            100.0 * (r.increase - r.paper_increase) / r.paper_increase
        );
        assert!(err < 0.03, "{} off by {:.1}%", r.role, err * 100.0);
    }
    println!("\ntable3_efficiency: OK");
}
