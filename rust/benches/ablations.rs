//! Ablation benches (DESIGN.md §5): eviction policies × workload traces,
//! PR-region-count sweep, and the reconfiguration amortization crossover.
//! `cargo bench --bench ablations`.

use tf_fpga::cpu::a53::A53Model;
use tf_fpga::fpga::bitstream::Bitstream;
use tf_fpga::fpga::icap::Icap;
use tf_fpga::fpga::resources::ResourceVector;
use tf_fpga::fpga::roles;
use tf_fpga::metrics::report::Table;
use tf_fpga::reconfig::manager::ReconfigManager;
use tf_fpga::reconfig::policy::{BeladyOracle, PolicyKind};
use tf_fpga::util::prng::Rng;

fn mk_roles(k: usize) -> Vec<Bitstream> {
    (0..k)
        .map(|i| {
            Bitstream::new(
                format!("role{i}"),
                roles::ROLE_BITSTREAM_BYTES,
                ResourceVector::new(100, 100, 10, 10),
                roles::role3_spec(),
            )
        })
        .collect()
}

fn run_trace(
    regions: usize,
    bitstreams: &[Bitstream],
    trace: &[usize],
    policy: Box<dyn tf_fpga::reconfig::policy::EvictionPolicy>,
) -> tf_fpga::reconfig::manager::ReconfigStats {
    let mut mgr = ReconfigManager::with_uniform_regions(
        regions,
        ResourceVector::new(1000, 1000, 100, 100),
        policy,
        Icap::default(),
    );
    for &i in trace {
        mgr.ensure_loaded(&bitstreams[i]).unwrap();
    }
    mgr.stats()
}

fn eviction_ablation(n: usize) {
    let roles_k = 4;
    let regions = 2;
    let bitstreams = mk_roles(roles_k);
    let mut rng = Rng::new(7);
    let traces: Vec<(&str, Vec<usize>)> = vec![
        ("cyclic", (0..n).map(|i| i % roles_k).collect()),
        ("zipf(1.2)", (0..n).map(|_| rng.zipf(roles_k, 1.2)).collect()),
        ("uniform", (0..n).map(|_| rng.below(roles_k as u64) as usize).collect()),
        // Bursty: long runs on one role (inference bursts), occasional swap.
        ("bursty(16)", (0..n).map(|i| (i / 16) % roles_k).collect()),
    ];

    let mut table = Table::new(
        format!("Ablation: eviction policy ({roles_k} roles, {regions} regions, n={n})"),
        &["Trace", "LRU", "MRU", "FIFO", "Random", "Belady (oracle)"],
    );
    for (name, trace) in &traces {
        let mut cells = vec![name.to_string()];
        for kind in PolicyKind::ALL {
            let s = run_trace(regions, &bitstreams, trace, kind.build(1));
            cells.push(format!("{:.1}%", 100.0 * s.hit_rate()));
        }
        let oracle = Box::new(BeladyOracle::new(
            trace.iter().map(|&i| bitstreams[i].id).collect(),
        ));
        let s = run_trace(regions, &bitstreams, trace, oracle);
        cells.push(format!("{:.1}%", 100.0 * s.hit_rate()));
        table.row(&cells);

        // Sanity: the oracle is at least as good as every online policy.
        let belady_hits = s.hits;
        for kind in PolicyKind::ALL {
            let online = run_trace(regions, &bitstreams, trace, kind.build(1));
            assert!(
                online.hits <= belady_hits,
                "{name}: {} beat Belady ({} > {belady_hits})",
                kind.build(1).name(),
                online.hits
            );
        }
    }
    table.footnote("hit rate; higher is better. LRU is the paper's shipped policy.");
    println!("{table}");
}

fn region_sweep(n: usize) {
    let roles_k = 4;
    let bitstreams = mk_roles(roles_k);
    let mut table = Table::new(
        format!("Ablation: PR region count (LRU, {roles_k} roles, n={n})"),
        &["Regions", "cyclic", "zipf(1.2)", "uniform", "reconfig time zipf [ms]"],
    );
    for regions in 1..=roles_k {
        let mut rng = Rng::new(11);
        let cyclic: Vec<usize> = (0..n).map(|i| i % roles_k).collect();
        let zipf: Vec<usize> = (0..n).map(|_| rng.zipf(roles_k, 1.2)).collect();
        let uniform: Vec<usize> = (0..n).map(|_| rng.below(roles_k as u64) as usize).collect();
        let sc = run_trace(regions, &bitstreams, &cyclic, PolicyKind::Lru.build(0));
        let sz = run_trace(regions, &bitstreams, &zipf, PolicyKind::Lru.build(0));
        let su = run_trace(regions, &bitstreams, &uniform, PolicyKind::Lru.build(0));
        table.row(&[
            regions.to_string(),
            format!("{:.1}%", 100.0 * sc.hit_rate()),
            format!("{:.1}%", 100.0 * sz.hit_rate()),
            format!("{:.1}%", 100.0 * su.hit_rate()),
            format!("{:.1}", sz.reconfig_us_total as f64 / 1000.0),
        ]);
        if regions == roles_k {
            assert_eq!(sc.misses as usize, roles_k, "full residency: only cold loads");
        }
    }
    println!("{table}");
}

fn crossover_table() {
    let cpu = A53Model::default();
    let icap = Icap::default();
    let reconfig_us = icap.reconfig_time_us(roles::ROLE_BITSTREAM_BYTES) as f64;
    let mut table = Table::new(
        "Ablation: reconfiguration amortization (break-even dispatches per role)",
        &["Role", "FPGA [µs/disp]", "A53 [µs/disp]", "OP/cycle win", "Latency break-even"],
    );
    let mut any_latency_win = false;
    for spec in [
        roles::role1_spec(),
        roles::role2_spec(),
        roles::role3_spec(),
        roles::role4_spec(),
    ] {
        let f = spec.exec_ns(&spec.op) as f64 / 1000.0;
        let c = cpu.exec_ns(&spec.op) as f64 / 1000.0;
        let opc_win = spec.ops_per_cycle(&spec.op) / cpu.achieved_ops_per_cycle(&spec.op);
        let be = if c > f {
            any_latency_win = true;
            format!("{:.0}", (reconfig_us / (c - f)).ceil())
        } else {
            "never (A53 clock 8x)".to_string()
        };
        table.row(&[
            spec.name.to_string(),
            format!("{f:.1}"),
            format!("{c:.1}"),
            format!("{opc_win:.2}x"),
            be,
        ]);
    }
    table.footnote(
        "The paper's claim is OP/cycle (energy) efficiency, not latency: at 150 MHz PL vs \
         1200 MHz A53 the FC roles lose on wall-clock while winning 6.5x/3.0x per cycle. \
         The conv roles win both.",
    );
    assert!(any_latency_win, "conv roles should beat the A53 on latency too");
    println!("{table}");
}

fn hls_flow_table() {
    use tf_fpga::fpga::hls::HlsFlow;
    use tf_fpga::fpga::synthesis::estimate;
    let flow = HlsFlow::default();
    let icap = Icap::default();
    let reconfig_us = icap.reconfig_time_us(roles::ROLE_BITSTREAM_BYTES);
    let mut table = Table::new(
        "Ablation: pre-synthesized vs online OpenCL synthesis (1000 dispatches, 20 reconfigs)",
        &["Role", "Synthesis [s]", "Time x", "Energy x"],
    );
    for (name, comps) in [
        ("role1_fc", roles::role1_components()),
        ("role3_conv5x5", roles::role3_components()),
    ] {
        let res = estimate(&comps);
        let cmp = flow.compare(&res, reconfig_us, 1000, 20);
        assert!(cmp.overhead_factor() > 100.0, "{name}: online flow must dominate");
        table.row(&[
            name.to_string(),
            format!("{:.0}", flow.synthesis_seconds(&res)),
            format!("{:.0}x", cmp.overhead_factor()),
            format!("{:.0}x", cmp.energy_factor()),
        ]);
    }
    println!("{table}");
}

fn main() {
    let n = std::env::var("ABLATION_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4000);
    eviction_ablation(n);
    region_sweep(n);
    crossover_table();
    hls_flow_table();
    println!("ablations: OK");
}
