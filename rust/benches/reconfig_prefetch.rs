//! Reconfiguration-storm bench: reactive (dispatch-time) reconfiguration
//! vs the predictive prefetch path, on the deterministic virtual clock.
//! `cargo bench --bench reconfig_prefetch [-- --check]`.
//!
//! The storm is the worst case for an LRU fabric: a cyclic working set
//! one-plus-larger than the two PR regions, so the reactive path misses
//! on *every* dispatch and pays the full ~7.4 ms ICAP transfer on the
//! critical path each time. The prefetch run replays the same dispatch
//! trace but mirrors the scheduler's pump between dispatches: while one
//! region computes, the ICAP streams the next role into the other region
//! (eviction-safety mask protecting the in-flight kernel), so in steady
//! state every dispatch lands on an already-resident role.
//!
//! Everything runs on the manager's virtual clock — no wall-clock noise —
//! so the gated ratios (stall reduction, prefetch hit rate, overlap
//! ratio) are bit-stable across machines; absolute `_us` numbers are
//! nulled in the committed baseline. `RECONFIG_N` overrides the dispatch
//! count per series (default 64).

use tf_fpga::bench::{write_and_check, BenchArtifact};
use tf_fpga::fpga::roles::{fused_paper_roles, paper_roles};
use tf_fpga::fpga::{Bitstream, Shell};
use tf_fpga::reconfig::policy::Lru;
use tf_fpga::reconfig::{ReconfigManager, ReconfigStats};

const BASELINE: &str = include_str!("baselines/BENCH_reconfig.json");

/// Regions on the bench fabric (half the largest working set).
const REGIONS: usize = 2;
/// Scheduler lookahead mirrored by the pump below.
const DEPTH: usize = 2;
/// Modeled compute time per dispatch, µs — longer than one ~950 KB role
/// transfer (~7.4 ms), so a prefetch issued at dispatch N is resident by
/// dispatch N+1. That is the paper's overlap budget: conv layers run for
/// milliseconds while the ICAP streams the next role.
const EXEC_US: u64 = 8_000;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn mk_manager() -> ReconfigManager {
    let shell = Shell::ultra96(REGIONS);
    ReconfigManager::new(shell.regions, Box::new(Lru), shell.icap)
}

/// The eight distinct roles the series draw from: the four paper roles
/// plus their ReLU-fused variants (fresh ids, same footprint).
fn role_set() -> Vec<Bitstream> {
    let mut roles = paper_roles();
    roles.extend(fused_paper_roles());
    roles
}

/// Reactive baseline: every reconfiguration happens at dispatch time, on
/// the critical path.
fn run_reactive(roles: &[Bitstream], n: usize) -> ReconfigStats {
    let mut m = mk_manager();
    for i in 0..n {
        m.ensure_loaded(&roles[i % roles.len()]).expect("reactive load");
        m.advance_clock(EXEC_US);
    }
    m.stats()
}

/// Predictive run: the same dispatch trace, with the scheduler's pump
/// mirrored between dispatches — walk the cyclic horizon up to `DEPTH`
/// ahead, protect the in-flight role and everything needed sooner, and
/// let the transfer stream while the current kernel computes.
fn run_prefetched(roles: &[Bitstream], n: usize) -> ReconfigStats {
    let mut m = mk_manager();
    for i in 0..n {
        let current = &roles[i % roles.len()];
        m.ensure_loaded(current).expect("prefetched load");
        let mut protected = vec![current.id];
        for d in 1..=DEPTH {
            let next = &roles[(i + d) % roles.len()];
            if !protected.contains(&next.id) {
                m.try_prefetch(next, &protected, 0, d as u64);
                protected.push(next.id);
            }
        }
        m.advance_clock(EXEC_US);
    }
    m.stats()
}

fn main() {
    let n = env_usize("RECONFIG_N", 64).max(8);

    println!("reconfig_prefetch: {n} dispatches, {REGIONS} PR regions, depth {DEPTH}\n");
    println!(
        "{:<5} {:>14} {:>14} {:>10} {:>9} {:>9}   (virtual µs)",
        "ws", "reactive stall", "prefetch stall", "reduction", "hit rate", "overlap"
    );

    let roles = role_set();
    let mut artifact = BenchArtifact::new("reconfig");
    artifact.set_u64("dispatches", n as u64);
    artifact.set_u64("regions", REGIONS as u64);

    let mut worst_reduction = f64::INFINITY;
    for ws in [3usize, 4, 6] {
        let reactive = run_reactive(&roles[..ws], n);
        let prefetched = run_prefetched(&roles[..ws], n);

        let reduction =
            reactive.stall_us as f64 / prefetched.stall_us.max(1) as f64;
        let hit_rate = prefetched.prefetch_hit_rate();
        let overlap = if prefetched.reconfig_us_total == 0 {
            0.0
        } else {
            prefetched.overlapped_us as f64 / prefetched.reconfig_us_total as f64
        };
        worst_reduction = worst_reduction.min(reduction);

        let prefix = format!("ws_{ws}");
        artifact.set_u64(&format!("{prefix}.reactive.stall_us"), reactive.stall_us);
        artifact.set_u64(&format!("{prefix}.reactive.misses"), reactive.misses);
        artifact.set_u64(&format!("{prefix}.prefetch.stall_us"), prefetched.stall_us);
        artifact
            .set_u64(&format!("{prefix}.prefetch.overlapped_us"), prefetched.overlapped_us);
        artifact.set_f64(&format!("{prefix}.prefetch.hit_rate"), hit_rate);
        artifact.set_f64(&format!("{prefix}.prefetch.overlap_ratio"), overlap);
        artifact.set_f64(&format!("{prefix}.stall_reduction"), reduction);

        println!(
            "{:<5} {:>14} {:>14} {:>9.1}x {:>8.0}% {:>8.0}%",
            ws,
            reactive.stall_us,
            prefetched.stall_us,
            reduction,
            hit_rate * 100.0,
            overlap * 100.0
        );

        // The storm preconditions must hold or the ratios are vacuous.
        assert_eq!(
            reactive.misses as usize, n,
            "ws {ws}: reactive run should miss on every dispatch"
        );
        assert_eq!(
            prefetched.hits + prefetched.misses,
            prefetched.dispatches,
            "ws {ws}: accounting broke: {prefetched:?}"
        );
        assert!(
            prefetched.prefetch_hits + prefetched.prefetch_wasted
                <= prefetched.prefetches,
            "ws {ws}: more prefetch outcomes than prefetches: {prefetched:?}"
        );
    }

    match write_and_check(&artifact, BASELINE) {
        Ok(regs) if regs.is_empty() => {}
        Ok(regs) => {
            for r in &regs {
                println!("REGRESSION: {r}");
            }
            std::process::exit(1);
        }
        Err(e) => {
            println!("bench artifact error: {e}");
            std::process::exit(1);
        }
    }

    if worst_reduction >= 2.0 {
        println!(
            "\nreconfig_prefetch: OK (worst stall reduction {worst_reduction:.1}x >= 2x)"
        );
    } else {
        println!(
            "\nreconfig_prefetch: WARNING — stall reduction {worst_reduction:.1}x < 2x"
        );
        std::process::exit(1);
    }
}
