//! Closed-loop loopback benchmark of the HTTP serving frontend:
//! in-process `AsyncInferenceServer::infer` vs the same pipeline behind
//! `net::HttpServer` + `NetClient` keep-alive connections — over both the
//! JSON `:predict` route and the binary `:predict-bin` tensor route — at
//! batch sizes 1 and 8. `cargo bench --bench http_serving`.
//!
//! Three headline ratios:
//!
//! * *overhead factor* — how much of the pipeline's throughput survives
//!   the JSON + TCP round trip;
//! * *json_vs_binary_overhead_factor* — binary-route req/s over JSON
//!   req/s at batch 8. The binary wire path skips JSON number
//!   formatting/tokenising on both ends and decodes rows straight into
//!   the batch lane's staging buffer, so the factor must stay above 1.0
//!   (gated by `--check` via the committed baseline);
//! * *tracing_overhead_factor* — untraced over traced JSON req/s at
//!   batch 8 (best of 3 each): what the always-on request spans cost.
//!   `--check` gates it at 1.05x.
//!
//! A closed loop (every client blocks on its reply) keeps the comparison
//! honest: all sides see identical offered concurrency. Environment
//! knobs: `HTTP_N` total requests per configuration (default 256),
//! `HTTP_CLIENTS` concurrent clients (default 8).

use std::sync::Arc;
use std::time::{Duration, Instant};
use tf_fpga::bench::{write_and_check, BenchArtifact};
use tf_fpga::net::{HttpServer, HttpServerConfig, NetClient};
use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig, BatchPolicy, ModelSpec};
use tf_fpga::tf::session::SessionOptions;

/// Committed floor values for `--check` (absolute throughput nulled —
/// machine-dependent — only the scaling ratios gate).
const BASELINE: &str = include_str!("baselines/BENCH_http.json");

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn config(max_batch: usize) -> AsyncServerConfig {
    AsyncServerConfig {
        models: vec![ModelSpec::new(
            "mnist",
            BatchPolicy { max_batch, max_delay: Duration::from_millis(2) },
        )],
        session: SessionOptions { dispatch_workers: 4, ..SessionOptions::native_only() },
        pipeline_depth: 4,
    }
}

fn sample(seed: usize) -> Vec<f32> {
    (0..784).map(|j| ((seed * 131 + j) % 255) as f32 / 255.0).collect()
}

/// Drive `total` closed-loop requests from `clients` threads.
fn drive(clients: usize, total: usize, infer: impl Fn(usize, Vec<f32>) + Send + Sync + 'static) -> Duration {
    let infer = Arc::new(infer);
    let per_client = total / clients;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let infer = Arc::clone(&infer);
            std::thread::spawn(move || {
                for i in 0..per_client {
                    infer(c, sample(c * per_client + i));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed()
}

/// One closed-loop run over a fresh HTTP server — JSON `:predict` or the
/// binary `:predict-bin` route — recording latency/fill metrics under
/// `http.batch_N` / `http_bin.batch_N`. Returns req/s.
fn run_http(
    max_batch: usize,
    clients: usize,
    total: usize,
    binary: bool,
    trace_requests: bool,
    artifact: &mut BenchArtifact,
    sane: &mut bool,
) -> f64 {
    let srv = AsyncInferenceServer::start(config(max_batch)).expect("server");
    let server = HttpServer::start(
        srv,
        HttpServerConfig {
            workers: clients,
            max_pending: total.max(64),
            trace_requests,
            ..HttpServerConfig::default()
        },
    )
    .expect("http server");
    let addr = server.local_addr();
    let per_client = total / clients;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = NetClient::connect(addr).expect("connect");
                for i in 0..per_client {
                    let s = sample(c * per_client + i);
                    if binary {
                        let resp = client
                            .predict_bin("mnist", &[1, 28, 28], &[s.as_slice()], &[])
                            .expect("predict-bin io");
                        assert_eq!(resp.status, 200);
                    } else {
                        let resp = client
                            .predict("mnist", &[s.as_slice()], &[])
                            .expect("predict io");
                        assert_eq!(resp.status, 200, "{}", resp.body);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let elapsed = t0.elapsed();
    let rep = server.report();
    let net = server.net_snapshot();
    let label = if binary {
        "bin"
    } else if trace_requests {
        "json"
    } else {
        "json-untraced"
    };
    println!(
        "  [{label} b{max_batch}: fill {:.2}, late joins {}, bytes copied {}, \
         p99 {} µs, shed {}, {} connections]",
        rep.batch_fill_ratio,
        rep.late_joins,
        rep.bytes_copied,
        rep.latency_us_p99,
        net.shed_pending + net.shed_tenant,
        net.connections
    );
    *sane &= rep.failed == 0 && net.responses_with(200) as usize == total;
    let prefix = match (binary, trace_requests) {
        (true, _) => format!("http_bin.batch_{max_batch}"),
        (false, true) => format!("http.batch_{max_batch}"),
        (false, false) => format!("http_untraced.batch_{max_batch}"),
    };
    artifact.set_u64(&format!("{prefix}.p50_us"), rep.latency_us_p50);
    artifact.set_u64(&format!("{prefix}.p99_us"), rep.latency_us_p99);
    artifact.set_f64(&format!("{prefix}.batch_fill"), rep.mean_batch_fill);
    artifact.set_f64(&format!("{prefix}.fill_ratio"), rep.batch_fill_ratio);
    drop(server); // graceful drain
    total as f64 / elapsed.as_secs_f64()
}

fn main() {
    let total = env_usize("HTTP_N", 256);
    let clients = env_usize("HTTP_CLIENTS", 8);
    let total = (total / clients).max(1) * clients;

    println!("http_serving: {total} requests, {clients} closed-loop clients\n");
    println!(
        "{:<12} {:>14} {:>14} {:>14} {:>10}   (req/s; factor = http json/in-process)",
        "batch size", "in-process", "http json", "http bin", "factor"
    );

    let mut artifact = BenchArtifact::new("http");
    artifact.set_u64("requests", total as u64);
    artifact.set_u64("clients", clients as u64);

    let mut sane = true;
    let mut json_rps_at_8 = f64::NAN;
    let mut bin_rps_at_8 = f64::NAN;
    for max_batch in [1usize, 8] {
        // --- in-process baseline: same pipeline, no network ---
        let inproc_rps = {
            let srv = Arc::new(AsyncInferenceServer::start(config(max_batch)).expect("server"));
            let s2 = Arc::clone(&srv);
            let elapsed = drive(clients, total, move |_c, img| {
                s2.infer("mnist", img).expect("infer");
            });
            let rps = total as f64 / elapsed.as_secs_f64();
            if let Ok(mut s) = Arc::try_unwrap(srv) {
                s.stop();
            }
            rps
        };

        // --- over the wire: the JSON tier, then the binary tensor route ---
        let http_rps = run_http(max_batch, clients, total, false, true, &mut artifact, &mut sane);
        let bin_rps = run_http(max_batch, clients, total, true, true, &mut artifact, &mut sane);

        let factor = http_rps / inproc_rps;
        sane &= factor > 0.05; // the wire may cost, but not 20x
        artifact.set_f64(&format!("inprocess.batch_{max_batch}.req_s"), inproc_rps);
        artifact.set_f64(&format!("http.batch_{max_batch}.req_s"), http_rps);
        artifact.set_f64(&format!("http_bin.batch_{max_batch}.req_s"), bin_rps);
        artifact.set_f64(&format!("overhead_factor.batch_{max_batch}"), factor);
        if max_batch == 8 {
            json_rps_at_8 = http_rps;
            bin_rps_at_8 = bin_rps;
        }
        println!(
            "{:<12} {:>14.1} {:>14.1} {:>14.1} {:>9.2}x",
            max_batch, inproc_rps, http_rps, bin_rps, factor
        );
    }

    // The point of the binary wire path: req/s it buys over the JSON tier
    // at batch 8. The committed baseline gates this above 1.0 in `--check`
    // mode; here we only sanity-check it is a real positive ratio (single
    // unchecked runs on loaded machines are too noisy for a hard gate).
    let bin_factor = bin_rps_at_8 / json_rps_at_8;
    sane &= bin_factor.is_finite() && bin_factor > 0.0;
    artifact.set_f64("json_vs_binary_overhead_factor", bin_factor);
    println!("\njson_vs_binary_overhead_factor (batch 8): {bin_factor:.2}x");

    // --- tracing overhead: the same JSON batch-8 run with request spans
    // disabled. The factor is untraced/traced req/s (>1 means tracing
    // costs throughput); best-of-3 on both sides damps the noise a
    // single closed-loop run carries. `--check` gates it at 1.05x —
    // request-scoped tracing must stay within 5% of free.
    let traced_rps = (0..2)
        .map(|_| run_http(8, clients, total, false, true, &mut artifact, &mut sane))
        .fold(json_rps_at_8, f64::max);
    let untraced_rps = (0..3)
        .map(|_| run_http(8, clients, total, false, false, &mut artifact, &mut sane))
        .fold(f64::NAN, f64::max);
    let tracing_factor = untraced_rps / traced_rps;
    sane &= tracing_factor.is_finite() && tracing_factor > 0.0;
    artifact.set_f64("tracing_overhead_factor", tracing_factor);
    println!("tracing_overhead_factor (batch 8, untraced/traced): {tracing_factor:.3}x");
    if std::env::args().any(|a| a == "--check") && tracing_factor > 1.05 {
        println!("REGRESSION: tracing_overhead_factor {tracing_factor:.3} exceeds the 1.05x budget");
        std::process::exit(1);
    }

    // Artifact + optional baseline gate before the pass/fail logic, so CI
    // always gets the JSON even on a failing run.
    match write_and_check(&artifact, BASELINE) {
        Ok(regs) if regs.is_empty() => {}
        Ok(regs) => {
            for r in &regs {
                println!("REGRESSION: {r}");
            }
            std::process::exit(1);
        }
        Err(e) => {
            println!("bench artifact error: {e}");
            std::process::exit(1);
        }
    }

    if sane {
        println!("\nhttp_serving: OK (all requests answered 200, overhead within bounds)");
    } else {
        println!("\nhttp_serving: WARNING — failed requests or pathological overhead");
        std::process::exit(1);
    }
}
