//! Bench: regenerate Table II (overheads, µs, n=1000) on the live stack.
//! `cargo bench --bench table2_overhead`.
//!
//! Absolute numbers differ from the paper's Ultra96/A53 host; the
//! reproduction target is the *shape*: setup ≫ reconfiguration ≫ dispatch,
//! TF-path ≥ HSA-path in each row, reconfiguration ≈ 7.4 ms (modeled PCAP).

use tf_fpga::bench::tables::table2;

fn main() {
    let n = std::env::var("TABLE2_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    // PJRT setup included when artifacts exist (the shipped configuration).
    let use_pjrt = tf_fpga::runtime::artifact::ArtifactStore::open_default().is_ok();
    let (t, m) = table2(n, use_pjrt);
    println!("{t}");

    assert!(m.tf_setup_us > m.hsa_setup_us, "setup ordering: {m:?}");
    assert!(
        (m.reconfig_us - 7424.0).abs() < 100.0,
        "reconfiguration off the paper's 7424 µs: {m:?}"
    );
    assert!(m.tf_setup_us > m.reconfig_us || !use_pjrt,
        "with PJRT compile included, setup dominates reconfiguration");
    assert!(m.tf_dispatch_us < 1000.0 && m.hsa_dispatch_us < 1000.0);
    // Ratio context vs the paper.
    println!(
        "paper ratios: setup 4.0x (156230/39032), dispatch 2.7x (27/10); \
         measured: setup {:.1}x, dispatch {:.2}x",
        m.tf_setup_us / m.hsa_setup_us,
        m.tf_dispatch_us / m.hsa_dispatch_us
    );
    println!("\ntable2_overhead: OK");
}
