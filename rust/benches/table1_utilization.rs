//! Bench: regenerate Table I (PL utilization) and verify every row against
//! the published numbers. `cargo bench --bench table1_utilization`.

use tf_fpga::bench::tables::{table1, table1_rows};
use tf_fpga::fpga::resources::ResourceVector;

fn main() {
    let t = table1();
    println!("{t}");

    // Published rows (Role 1 only has the LUT column).
    let expected: &[(&str, Option<ResourceVector>, Option<u32>)] = &[
        ("Shell", Some(ResourceVector::new(9915, 8544, 10, 0)), None),
        ("Role 1", None, Some(9984)),
        ("Role 2", Some(ResourceVector::new(9501, 7851, 23, 8)), None),
        ("Role 3", Some(ResourceVector::new(5091, 4935, 21, 6)), None),
        ("Role 4", Some(ResourceVector::new(7881, 7926, 21, 12)), None),
    ];
    let rows = table1_rows();
    let mut ok = true;
    for ((label, got, _est), (elabel, want, want_luts)) in rows.iter().zip(expected) {
        assert_eq!(label, elabel);
        if let Some(want) = want {
            let delta = (got.luts as i64 - want.luts as i64).abs();
            let exact = got.ffs == want.ffs && got.bram36 == want.bram36 && got.dsps == want.dsps;
            let row_ok = delta <= 1 && exact;
            println!(
                "{label}: estimator {got} vs paper {want} -> {}",
                if row_ok { "MATCH" } else { "MISMATCH" }
            );
            ok &= row_ok;
        }
        if let Some(want_luts) = want_luts {
            let row_ok = got.luts == *want_luts;
            println!(
                "{label}: estimator {} LUTs vs paper {want_luts} -> {}",
                got.luts,
                if row_ok { "MATCH" } else { "MISMATCH" }
            );
            ok &= row_ok;
        }
    }
    assert!(ok, "Table I reproduction failed");
    println!("\ntable1_utilization: OK");
}
