//! Microbenchmark of the dispatch hot path (the §Perf target): queue
//! enqueue → packet processor → kernel → completion signal, for both the
//! raw HSA path and the TF session path, plus component costs.
//! `cargo bench --bench dispatch_hotpath`.

use std::sync::Arc;
use tf_fpga::bench::harness::time_n;
use tf_fpga::fpga::device::{ComputeBinding, FpgaAgent, FpgaConfig};
use tf_fpga::fpga::roles;
use tf_fpga::hsa::agent::DeviceType;
use tf_fpga::hsa::packet::AqlPacket;
use tf_fpga::hsa::runtime::HsaRuntime;
use tf_fpga::hsa::signal::Signal;
use tf_fpga::reconfig::policy::PolicyKind;
use tf_fpga::tf::dtype::DType;
use tf_fpga::tf::graph::{Graph, OpKind};
use tf_fpga::tf::session::{Session, SessionOptions};
use tf_fpga::tf::tensor::Tensor;

fn main() {
    let n = std::env::var("HOTPATH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);

    // --- component: signal round trip between two threads ---
    {
        let sig = Signal::new(0);
        let stop = Signal::new(0);
        let (s2, st2) = (sig.clone(), stop.clone());
        let peer = std::thread::spawn(move || {
            // Echo thread: for value v = odd, respond v+1.
            let mut last = 0;
            loop {
                let v = s2.wait_until(None, |x| x > last || st2.load() != 0).unwrap();
                if st2.load() != 0 {
                    break;
                }
                last = v + 1;
                s2.store(last);
            }
        });
        let mut v = 0i64;
        let r = time_n("signal ping-pong", 100, n, || {
            v += 2;
            sig.store(v - 1);
            sig.wait_until(None, |x| x == v).unwrap();
        });
        println!("{}", r.report());
        stop.store(1);
        sig.store(v + 1);
        peer.join().unwrap();
    }

    // --- component: queue enqueue/dequeue (no kernel) ---
    {
        let q = tf_fpga::hsa::queue::Queue::new(64);
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            while let Some(pkt) = q2.dequeue_blocking() {
                if let AqlPacket::BarrierAnd(b) = pkt {
                    b.completion_signal.subtract(1);
                }
            }
        });
        let r = time_n("queue round-trip (barrier pkt)", 100, n, || {
            let done = Signal::new(1);
            q.enqueue(AqlPacket::barrier(vec![], done.clone())).unwrap();
            done.wait_eq(0, None).unwrap();
        });
        println!("{}", r.report());
        q.shutdown();
        consumer.join().unwrap();
    }

    // --- raw HSA dispatch on a warm FPGA role (echo kernel) ---
    {
        let fpga = FpgaAgent::new(FpgaConfig {
            num_regions: 2,
            policy: PolicyKind::Lru.build(0),
            realtime: false,
            realtime_scale: 1.0,
            trace: None,
        });
        let role = roles::paper_roles().remove(0);
        let id = fpga.register_role(
            role,
            ComputeBinding::Native(Arc::new(|ins: &[Tensor]| Ok(ins.to_vec()))),
        );
        let rt = HsaRuntime::builder().with_agent(fpga).build();
        let q = rt.create_queue(rt.agent_by_type(DeviceType::Fpga).unwrap(), 64);
        let x = Tensor::from_f32(&[4, 4], vec![1.0; 16]).unwrap();
        let r = time_n("raw HSA dispatch (warm role)", 100, n, || {
            rt.dispatch_sync(&q, id, vec![x.clone()]).unwrap();
        });
        println!("{}", r.report());
        rt.shutdown();
    }

    // --- TF session dispatch (single-FC graph) ---
    {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[4, 4], DType::F32).unwrap();
        let w = g
            .constant("w", Tensor::from_f32(&[4, 4], vec![0.5; 16]).unwrap())
            .unwrap();
        let b = g.constant("b", Tensor::from_f32(&[4], vec![0.0; 4]).unwrap()).unwrap();
        g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
        let sess = Session::new(g, SessionOptions::native_only()).unwrap();
        let feed = Tensor::from_f32(&[4, 4], vec![1.0; 16]).unwrap();
        let r = time_n("TF session.run (1 FC node, plan replay)", 100, n, || {
            sess.run(&[("x", feed.clone())], &["y"]).unwrap();
        });
        println!("{}", r.report());
        sess.shutdown();
    }

    // --- interpreted graph walk vs compiled plan replay (MLP) ---
    // A 3-layer FC+ReLU MLP: the interpreter re-walks the graph and
    // dispatches each FC and each ReLU separately (6 dispatches); the
    // cached plan fuses every FC+ReLU pair into one dispatch (3) and
    // replays with no per-run graph analysis.
    {
        let mut g = Graph::new();
        let mut prev = g.placeholder("x", &[8, 32], DType::F32).unwrap();
        let mut width = 32usize;
        for (i, next) in [32usize, 32, 10].into_iter().enumerate() {
            let wdata = (0..width * next).map(|v| (v % 7) as f32 * 0.05 - 0.15).collect();
            let w = g
                .constant(format!("w{i}"), Tensor::from_f32(&[width, next], wdata).unwrap())
                .unwrap();
            let b = g
                .constant(format!("b{i}"), Tensor::from_f32(&[next], vec![0.01; next]).unwrap())
                .unwrap();
            let y = g.add(format!("y{i}"), OpKind::FullyConnected, &[prev, w, b]).unwrap();
            prev = g.add(format!("r{i}"), OpKind::Relu, &[y]).unwrap();
            width = next;
        }
        let out = "r2";
        let sess = Session::new(g, SessionOptions::native_only()).unwrap();
        let feed = Tensor::from_f32(&[8, 32], vec![0.5; 8 * 32]).unwrap();

        // Warm the plan cache and report what compilation did.
        let (plan_res, plan_stats) =
            sess.run_with_stats(&[("x", feed.clone())], &[out]).unwrap();
        let (interp_res, interp_stats) =
            sess.run_interpreted(&[("x", feed.clone())], &[out]).unwrap();
        assert_eq!(plan_res[0], interp_res[0], "paths must agree bitwise");
        println!(
            "MLP dispatches: interpreted {} vs plan replay {} ({} fused, {} plan steps)",
            interp_stats.dispatches,
            plan_stats.dispatches,
            plan_stats.fused_dispatches,
            plan_stats.plan_steps
        );
        let cache = sess.plan_cache_stats();
        println!(
            "plan cache: {} entries, compile {} µs total",
            cache.entries, cache.compile_us_total
        );

        let ri = time_n("interpreted executor (MLP 3x FC+ReLU)", 100, n, || {
            sess.run_interpreted(&[("x", feed.clone())], &[out]).unwrap();
        });
        println!("{}", ri.report());
        let rp = time_n("plan replay, cached + fused (same MLP)", 100, n, || {
            sess.run(&[("x", feed.clone())], &[out]).unwrap();
        });
        println!("{}", rp.report());
        println!(
            "replay speedup over interpreter: {:.2}x (p50 {:.2} µs -> {:.2} µs)",
            ri.us.p50 / rp.us.p50.max(0.01),
            ri.us.p50,
            rp.us.p50
        );
        sess.shutdown();
    }

    println!("dispatch_hotpath: OK");
}
