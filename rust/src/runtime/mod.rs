//! Runtime: loads AOT-compiled HLO artifacts and executes them via PJRT.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-backed (not `Send`/`Sync`), so
//! all PJRT objects live on one dedicated *executor service thread*
//! ([`pjrt::PjrtService`]); agents talk to it through a cloneable,
//! thread-safe [`pjrt::PjrtHandle`]. [`artifact`] reads the
//! `artifacts/manifest.json` the Python AOT step writes and loads each
//! module's HLO text.
//!
//! This layer is *optional at runtime and at build time*: without the
//! `pjrt` cargo feature (or when the XLA client fails to come up, or no
//! artifacts exist) the session binds every role to its native Rust
//! kernel instead — same numerics, no PJRT round-trip — so the serving
//! path and all tier-1 tests run on a toolchain-only machine. Requests
//! flow `serve → tf::session → hsa queue → fpga agent → (pjrt | native)`;
//! only that last hop changes.

pub mod artifact;
pub mod pjrt;

pub use artifact::{ArtifactStore, ModuleMeta, TensorMeta};
pub use pjrt::{PjrtHandle, PjrtService};
