//! Runtime: loads AOT-compiled HLO artifacts and executes them via PJRT.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-backed (not `Send`/`Sync`), so
//! all PJRT objects live on one dedicated *executor service thread*
//! ([`pjrt::PjrtService`]); agents talk to it through a cloneable,
//! thread-safe [`pjrt::PjrtHandle`]. [`artifact`] reads the
//! `artifacts/manifest.json` the Python AOT step writes and loads each
//! module's HLO text.

pub mod artifact;
pub mod pjrt;

pub use artifact::{ArtifactStore, ModuleMeta, TensorMeta};
pub use pjrt::{PjrtHandle, PjrtService};
