//! PJRT executor service: one thread owns the (non-`Send`) PJRT client and
//! all compiled executables; the rest of the system talks to it through a
//! cloneable [`PjrtHandle`].
//!
//! Loading a module follows the AOT recipe from /opt/xla-example:
//! HLO *text* → `HloModuleProto::from_text_file` → `XlaComputation` →
//! `client.compile` → execute with `Literal` inputs, unwrap the 1-tuple.
//!
//! The XLA backend is compiled only with the `pjrt` + `pjrt-xla` cargo
//! features together (the `xla` crate needs native XLA libraries that
//! are not in the offline vendor set; `pjrt` alone builds this service
//! with a stub backend so the feature stays CI-green). Without the real
//! backend, [`PjrtService::start`] returns an error and the session
//! falls back to native-kernel numerics — the same math, minus the
//! artifact round-trip.

use crate::hsa::error::{HsaError, Result};
use crate::runtime::artifact::ModuleMeta;
use crate::tf::tensor::Tensor;
use std::sync::mpsc;
use std::thread::JoinHandle;

enum Request {
    Load {
        meta: ModuleMeta,
        reply: mpsc::SyncSender<Result<u128>>,
    },
    Execute {
        module: String,
        inputs: Vec<Tensor>,
        reply: mpsc::SyncSender<Result<Vec<Tensor>>>,
    },
    /// List loaded module names (diagnostics).
    List {
        reply: mpsc::SyncSender<Vec<String>>,
    },
    Shutdown,
}

/// Thread-safe handle to the PJRT service.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: mpsc::Sender<Request>,
}

/// The service: owns the worker thread.
pub struct PjrtService {
    handle: PjrtHandle,
    worker: Option<JoinHandle<()>>,
}

impl PjrtService {
    /// Start the service thread and bring up the PJRT CPU client on it.
    ///
    /// Errors when the `pjrt` feature is not compiled in, or when the XLA
    /// client fails to initialize.
    pub fn start() -> Result<PjrtService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let worker = std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || backend::service_main(rx, ready_tx))
            .map_err(|e| HsaError::Runtime(format!("spawn pjrt thread: {e}")))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = worker.join();
                return Err(e);
            }
            Err(_) => return Err(HsaError::Runtime("pjrt thread died at startup".into())),
        }
        Ok(PjrtService { handle: PjrtHandle { tx }, worker: Some(worker) })
    }

    pub fn handle(&self) -> PjrtHandle {
        self.handle.clone()
    }

    pub fn shutdown(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl PjrtHandle {
    /// Load + compile an artifact module; returns compile time in µs.
    pub fn load_module(&self, meta: &ModuleMeta) -> Result<u128> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Load { meta: meta.clone(), reply })
            .map_err(|_| HsaError::Runtime("pjrt service gone".into()))?;
        rx.recv()
            .map_err(|_| HsaError::Runtime("pjrt service dropped reply".into()))?
    }

    /// Execute a loaded module.
    pub fn execute(&self, module: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request::Execute { module: module.to_string(), inputs, reply })
            .map_err(|_| HsaError::Runtime("pjrt service gone".into()))?;
        rx.recv()
            .map_err(|_| HsaError::Runtime("pjrt service dropped reply".into()))?
    }

    pub fn loaded_modules(&self) -> Vec<String> {
        let (reply, rx) = mpsc::sync_channel(1);
        if self.tx.send(Request::List { reply }).is_err() {
            return Vec::new();
        }
        rx.recv().unwrap_or_default()
    }
}

/// The real XLA-backed service loop. Compiled only when *both* `pjrt`
/// and `pjrt-xla` are enabled: `pjrt` alone builds the full service
/// plumbing (so CI keeps the feature green) but degrades to the stub
/// below, because the `xla` crate needs native XLA libraries outside
/// the offline vendor set (see Cargo.toml).
#[cfg(all(feature = "pjrt", feature = "pjrt-xla"))]
mod backend {
    use super::Request;
    use crate::hsa::error::{HsaError, Result};
    use crate::runtime::artifact::{ModuleMeta, TensorMeta};
    use crate::tf::dtype::DType;
    use crate::tf::tensor::Tensor;
    use std::collections::HashMap;
    use std::sync::mpsc;
    use std::time::Instant;

    struct LoadedModule {
        exe: xla::PjRtLoadedExecutable,
        meta: ModuleMeta,
    }

    pub(super) fn service_main(
        rx: mpsc::Receiver<Request>,
        ready: mpsc::SyncSender<Result<()>>,
    ) {
        let client = match xla::PjRtClient::cpu() {
            Ok(c) => {
                let _ = ready.send(Ok(()));
                c
            }
            Err(e) => {
                let _ = ready.send(Err(HsaError::Runtime(format!("PjRtClient::cpu: {e}"))));
                return;
            }
        };
        let mut modules: HashMap<String, LoadedModule> = HashMap::new();

        while let Ok(req) = rx.recv() {
            match req {
                Request::Load { meta, reply } => {
                    let t0 = Instant::now();
                    let res = load_module(&client, &meta).map(|lm| {
                        modules.insert(meta.name.clone(), lm);
                        t0.elapsed().as_micros()
                    });
                    let _ = reply.send(res);
                }
                Request::Execute { module, inputs, reply } => {
                    let res = match modules.get(&module) {
                        Some(lm) => execute_module(lm, &inputs),
                        None => {
                            Err(HsaError::Runtime(format!("module '{module}' not loaded")))
                        }
                    };
                    let _ = reply.send(res);
                }
                Request::List { reply } => {
                    let mut names: Vec<String> = modules.keys().cloned().collect();
                    names.sort();
                    let _ = reply.send(names);
                }
                Request::Shutdown => break,
            }
        }
    }

    fn load_module(client: &xla::PjRtClient, meta: &ModuleMeta) -> Result<LoadedModule> {
        let path = meta
            .hlo_path
            .to_str()
            .ok_or_else(|| HsaError::Runtime("non-utf8 artifact path".into()))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| HsaError::Runtime(format!("parse {path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| HsaError::Runtime(format!("compile {}: {e}", meta.name)))?;
        Ok(LoadedModule { exe, meta: meta.clone() })
    }

    fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
        let bytes: Vec<u8> = match t.dtype() {
            DType::F32 => t.as_f32()?.iter().flat_map(|v| v.to_le_bytes()).collect(),
            DType::I16 => t.as_i16()?.iter().flat_map(|v| v.to_le_bytes()).collect(),
            DType::I32 => t.as_i32()?.iter().flat_map(|v| v.to_le_bytes()).collect(),
        };
        let ty = match t.dtype() {
            DType::F32 => xla::ElementType::F32,
            DType::I16 => xla::ElementType::S16,
            DType::I32 => xla::ElementType::S32,
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, t.shape(), &bytes)
            .map_err(|e| HsaError::Runtime(format!("literal: {e}")))
    }

    fn literal_to_tensor(lit: &xla::Literal, meta: &TensorMeta) -> Result<Tensor> {
        let out = match meta.dtype {
            DType::F32 => Tensor::from_f32(
                &meta.shape,
                lit.to_vec::<f32>()
                    .map_err(|e| HsaError::Runtime(format!("to_vec f32: {e}")))?,
            )?,
            DType::I16 => Tensor::from_i16(
                &meta.shape,
                lit.to_vec::<i16>()
                    .map_err(|e| HsaError::Runtime(format!("to_vec i16: {e}")))?,
            )?,
            DType::I32 => Tensor::from_i32(
                &meta.shape,
                lit.to_vec::<i32>()
                    .map_err(|e| HsaError::Runtime(format!("to_vec i32: {e}")))?,
            )?,
        };
        Ok(out)
    }

    fn execute_module(lm: &LoadedModule, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        // Validate the signature before touching PJRT: clearer errors.
        if inputs.len() != lm.meta.inputs.len() {
            return Err(HsaError::Runtime(format!(
                "module '{}' expects {} inputs, got {}",
                lm.meta.name,
                lm.meta.inputs.len(),
                inputs.len()
            )));
        }
        for (t, m) in inputs.iter().zip(&lm.meta.inputs) {
            if t.shape() != m.shape.as_slice() || t.dtype() != m.dtype {
                return Err(HsaError::Runtime(format!(
                    "module '{}' input '{}': expected {:?} {}, got {:?} {}",
                    lm.meta.name,
                    m.name,
                    m.shape,
                    m.dtype,
                    t.shape(),
                    t.dtype()
                )));
            }
        }

        let lits: Vec<xla::Literal> =
            inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let bufs = lm
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| HsaError::Runtime(format!("execute {}: {e}", lm.meta.name)))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| HsaError::Runtime(format!("to_literal: {e}")))?;
        let lit = if lm.meta.tuple_output {
            lit.to_tuple1()
                .map_err(|e| HsaError::Runtime(format!("to_tuple1: {e}")))?
        } else {
            lit
        };
        Ok(vec![literal_to_tensor(&lit, &lm.meta.output)?])
    }
}

/// Backend-less stub: report at startup that PJRT is unavailable — either
/// the `pjrt` feature is off entirely, or it is on without the vendored
/// `pjrt-xla` backend. The session treats both as "no PJRT" and binds
/// roles to native kernels (identical math), so `--features pjrt` always
/// builds and tests green even with no XLA toolchain or artifacts.
#[cfg(not(all(feature = "pjrt", feature = "pjrt-xla")))]
mod backend {
    use super::Request;
    use crate::hsa::error::{HsaError, Result};
    use std::sync::mpsc;

    pub(super) fn service_main(
        rx: mpsc::Receiver<Request>,
        ready: mpsc::SyncSender<Result<()>>,
    ) {
        drop(rx);
        let _ = ready.send(Err(HsaError::Runtime(
            "PJRT backend not compiled in (enable the `pjrt` + `pjrt-xla` \
             cargo features after vendoring the `xla` crate)"
                .into(),
        )));
    }
}

#[cfg(all(test, feature = "pjrt", feature = "pjrt-xla"))]
mod tests {
    // PJRT service tests that need real artifacts live in
    // rust/tests/integration_runtime.rs (gated on artifacts/ existing).
    use super::*;

    #[test]
    fn handle_reports_missing_module() {
        let svc = PjrtService::start().expect("pjrt client");
        let err = svc.handle().execute("nope", vec![]).unwrap_err();
        assert!(err.to_string().contains("not loaded"), "{err}");
    }

    #[test]
    fn list_initially_empty() {
        let svc = PjrtService::start().expect("pjrt client");
        assert!(svc.handle().loaded_modules().is_empty());
    }
}

#[cfg(all(test, not(all(feature = "pjrt", feature = "pjrt-xla"))))]
mod tests {
    use super::*;

    #[test]
    fn start_reports_missing_backend() {
        let err = PjrtService::start().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
