//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. See the manifest schema in aot.py.

use crate::hsa::error::{HsaError, Result};
use crate::tf::dtype::DType;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor in a module signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One AOT-lowered module.
#[derive(Debug, Clone)]
pub struct ModuleMeta {
    pub name: String,
    /// Path of the HLO text file, absolute.
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorMeta>,
    pub output: TensorMeta,
    /// Lowered with `return_tuple=True` → unwrap a 1-tuple on execute.
    pub tuple_output: bool,
}

/// Raw weight blob descriptor (for the native CPU baseline).
#[derive(Debug, Clone)]
pub struct WeightMeta {
    pub path: PathBuf,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Parsed artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub modules: BTreeMap<String, ModuleMeta>,
    pub weights: BTreeMap<String, WeightMeta>,
    pub conv_shift: u32,
    pub seed: u64,
}

fn tensor_meta(name: &str, v: &Json) -> Result<TensorMeta> {
    let shape = v
        .get("shape")
        .as_arr()
        .ok_or_else(|| HsaError::Runtime(format!("{name}: missing shape")))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| HsaError::Runtime("bad dim".into())))
        .collect::<Result<Vec<usize>>>()?;
    let dt = v
        .get("dtype")
        .as_str()
        .and_then(DType::from_manifest)
        .ok_or_else(|| HsaError::Runtime(format!("{name}: bad dtype")))?;
    Ok(TensorMeta {
        name: v.get("name").as_str().unwrap_or(name).to_string(),
        shape,
        dtype: dt,
    })
}

impl ArtifactStore {
    /// Parse `<dir>/manifest.json`. Fails with a readable error if the
    /// artifacts have not been built (`make artifacts`).
    pub fn open(dir: impl AsRef<Path>) -> Result<ArtifactStore> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            HsaError::Runtime(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                manifest_path.display()
            ))
        })?;
        let doc = Json::parse(&text)
            .map_err(|e| HsaError::Runtime(format!("manifest: {e}")))?;

        let mut modules = BTreeMap::new();
        if let Some(mods) = doc.get("modules").as_obj() {
            for (name, m) in mods {
                let file = m
                    .get("file")
                    .as_str()
                    .ok_or_else(|| HsaError::Runtime(format!("{name}: no file")))?;
                let inputs = m
                    .get("inputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|v| tensor_meta(name, v))
                    .collect::<Result<Vec<_>>>()?;
                let output = tensor_meta(name, m.get("output"))?;
                modules.insert(
                    name.clone(),
                    ModuleMeta {
                        name: name.clone(),
                        hlo_path: dir.join(file),
                        inputs,
                        output,
                        tuple_output: matches!(m.get("tuple_output"), Json::Bool(true)),
                    },
                );
            }
        }

        let mut weights = BTreeMap::new();
        if let Some(ws) = doc.get("weights").as_obj() {
            for (name, w) in ws {
                let meta = tensor_meta(name, w)?;
                let file = w
                    .get("file")
                    .as_str()
                    .ok_or_else(|| HsaError::Runtime(format!("{name}: no file")))?;
                weights.insert(
                    name.clone(),
                    WeightMeta {
                        path: dir.join(file),
                        shape: meta.shape,
                        dtype: meta.dtype,
                    },
                );
            }
        }

        Ok(ArtifactStore {
            dir,
            modules,
            weights,
            conv_shift: doc.get("conv_shift").as_usize().unwrap_or(8) as u32,
            seed: doc.get("seed").as_f64().unwrap_or(0.0) as u64,
        })
    }

    /// Default location: `$TF_FPGA_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<ArtifactStore> {
        let dir = std::env::var("TF_FPGA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        ArtifactStore::open(dir)
    }

    pub fn module(&self, name: &str) -> Result<&ModuleMeta> {
        self.modules
            .get(name)
            .ok_or_else(|| HsaError::Runtime(format!("no module '{name}' in manifest")))
    }

    /// Load a raw little-endian weight blob as f32 (shape from manifest).
    pub fn load_weight_f32(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let w = self
            .weights
            .get(name)
            .ok_or_else(|| HsaError::Runtime(format!("no weight '{name}'")))?;
        if w.dtype != DType::F32 {
            return Err(HsaError::Runtime(format!("{name} is {}", w.dtype)));
        }
        let bytes = std::fs::read(&w.path)
            .map_err(|e| HsaError::Runtime(format!("read {}: {e}", w.path.display())))?;
        let vals = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok((w.shape.clone(), vals))
    }

    /// Load a raw little-endian weight blob as i16.
    pub fn load_weight_i16(&self, name: &str) -> Result<(Vec<usize>, Vec<i16>)> {
        let w = self
            .weights
            .get(name)
            .ok_or_else(|| HsaError::Runtime(format!("no weight '{name}'")))?;
        if w.dtype != DType::I16 {
            return Err(HsaError::Runtime(format!("{name} is {}", w.dtype)));
        }
        let bytes = std::fs::read(&w.path)
            .map_err(|e| HsaError::Runtime(format!("read {}: {e}", w.path.display())))?;
        let vals = bytes
            .chunks_exact(2)
            .map(|c| i16::from_le_bytes([c[0], c[1]]))
            .collect();
        Ok((w.shape.clone(), vals))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tf_fpga_artifact_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_minimal_manifest() {
        let d = tmpdir("min");
        write_manifest(
            &d,
            r#"{"version":1,"seed":7,"conv_shift":8,"modules":{
                "m":{"file":"m.hlo.txt",
                     "inputs":[{"name":"x","shape":[2,3],"dtype":"f32"}],
                     "output":{"shape":[2],"dtype":"i16"},
                     "tuple_output":true}},
                "weights":{}}"#,
        );
        let store = ArtifactStore::open(&d).unwrap();
        let m = store.module("m").unwrap();
        assert_eq!(m.inputs[0].shape, vec![2, 3]);
        assert_eq!(m.inputs[0].dtype, DType::F32);
        assert_eq!(m.output.dtype, DType::I16);
        assert!(m.tuple_output);
        assert_eq!(store.seed, 7);
        assert!(store.module("nope").is_err());
    }

    #[test]
    fn missing_dir_is_friendly_error() {
        let err = ArtifactStore::open("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn weight_blob_round_trip() {
        let d = tmpdir("w");
        std::fs::create_dir_all(d.join("weights")).unwrap();
        let vals: Vec<f32> = vec![1.5, -2.0, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(d.join("weights/a.bin"), bytes).unwrap();
        write_manifest(
            &d,
            r#"{"modules":{},"weights":{
                "a":{"file":"weights/a.bin","shape":[3],"dtype":"f32"}}}"#,
        );
        let store = ArtifactStore::open(&d).unwrap();
        let (shape, data) = store.load_weight_f32("a").unwrap();
        assert_eq!(shape, vec![3]);
        assert_eq!(data, vals);
        assert!(store.load_weight_i16("a").is_err(), "dtype enforced");
    }

    #[test]
    fn i16_weight_blob() {
        let d = tmpdir("wi16");
        std::fs::create_dir_all(d.join("weights")).unwrap();
        let vals: Vec<i16> = vec![-5, 7, 32767];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(d.join("weights/b.bin"), bytes).unwrap();
        write_manifest(
            &d,
            r#"{"modules":{},"weights":{
                "b":{"file":"weights/b.bin","shape":[3],"dtype":"i16"}}}"#,
        );
        let store = ArtifactStore::open(&d).unwrap();
        let (_, data) = store.load_weight_i16("b").unwrap();
        assert_eq!(data, vals);
    }
}
