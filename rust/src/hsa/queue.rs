//! User-mode AQL queues with the HSA write-index/doorbell protocol.
//!
//! A producer reserves a slot by bumping the write index, fills the slot,
//! then rings the doorbell signal with the new index. Packet processors
//! consume slots in order (read index chases write index). We realize the
//! ring as a fixed-capacity `Vec<Mutex<Option<AqlPacket>>>` — one mutex per
//! slot keeps producers on distinct slots contention-free, as on hardware.
//!
//! Both ends are fully concurrent (MPMC):
//!
//! * **Multi-producer** — any number of threads may [`Queue::enqueue`]
//!   simultaneously; each reserves a distinct slot with one atomic
//!   `fetch_add` and publishes it with a doorbell ring, no submit lock.
//! * **Multi-consumer** — several packet processors may drain one queue
//!   (see `HsaRuntime::create_queue_with_processors`); a consumer *claims*
//!   the read index with a compare-exchange before touching the slot, so
//!   two processors never dequeue the same packet and the read index never
//!   moves backwards. This is what lets multiple kernel dispatches be in
//!   flight on one device at once (one per PR region, as on hardware).
//!
//! Each slot carries a sequence number (the Vyukov bounded-MPMC scheme):
//! the producer for ring index `i` may only fill the slot when its
//! sequence equals `i` (the previous lap's packet was *taken*, not merely
//! claimed), and the consumer that claimed `i` only takes a packet
//! stamped `i+1`. A stalled producer therefore cannot be overtaken by a
//! full-lap peer, and a consumer can never grab a neighbouring lap's
//! packet — reservation order is delivery order, even under contention.
//! Backpressure falls out of the same rule: a producer one lap ahead
//! waits for its slot's sequence to catch up.

use crate::hsa::error::{HsaError, Result};
use crate::hsa::packet::AqlPacket;
use crate::hsa::signal::Signal;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cloneable handle to a queue.
#[derive(Debug, Clone)]
pub struct Queue {
    inner: Arc<QueueInner>,
}

/// One ring slot: Vyukov-style sequence + payload. `seq == i` means the
/// slot is free for the producer of ring index `i`; `seq == i + 1` means
/// packet `i` is stored and waiting for the consumer that claimed `i`.
#[derive(Debug)]
struct Slot {
    seq: u64,
    pkt: Option<AqlPacket>,
}

#[derive(Debug)]
struct QueueInner {
    /// Ring storage; capacity is a power of two (HSA requirement).
    slots: Vec<Mutex<Slot>>,
    capacity_mask: u64,
    /// Next slot a producer will write.
    write_index: AtomicU64,
    /// Next slot the packet processor will read.
    read_index: AtomicU64,
    /// Doorbell: stores the latest published write index.
    doorbell: Signal,
    shut_down: AtomicBool,
    id: u64,
}

static NEXT_QUEUE_ID: AtomicU64 = AtomicU64::new(1);

impl Queue {
    /// Create a queue with `capacity` slots (rounded up to a power of two).
    pub fn new(capacity: usize) -> Queue {
        let cap = capacity.next_power_of_two().max(2);
        let slots = (0..cap)
            .map(|i| Mutex::new(Slot { seq: i as u64, pkt: None }))
            .collect();
        Queue {
            inner: Arc::new(QueueInner {
                slots,
                capacity_mask: (cap - 1) as u64,
                write_index: AtomicU64::new(0),
                read_index: AtomicU64::new(0),
                doorbell: Signal::new(-1),
                shut_down: AtomicBool::new(false),
                id: NEXT_QUEUE_ID.fetch_add(1, Ordering::Relaxed),
            }),
        }
    }

    pub fn id(&self) -> u64 {
        self.inner.id
    }

    pub fn capacity(&self) -> usize {
        self.inner.slots.len()
    }

    /// Packets currently in flight (enqueued, not yet consumed).
    pub fn depth(&self) -> u64 {
        let w = self.inner.write_index.load(Ordering::Acquire);
        let r = self.inner.read_index.load(Ordering::Acquire);
        w.saturating_sub(r)
    }

    /// Producer side: reserve a slot, store the packet, ring the doorbell.
    /// Blocks (spin+yield) while the ring is full — AQL backpressure.
    pub fn enqueue(&self, packet: AqlPacket) -> Result<u64> {
        if self.inner.shut_down.load(Ordering::Acquire) {
            return Err(HsaError::QueueShutDown);
        }
        // Reserve.
        let idx = self.inner.write_index.fetch_add(1, Ordering::AcqRel);
        // Backpressure + publish: the slot's sequence reaches `idx` only
        // once the previous lap's packet has been *taken* (not merely
        // claimed), so a full-lap producer can neither clobber a pending
        // packet nor overtake a stalled peer that reserved an earlier
        // index for the same slot.
        let slot = &self.inner.slots[(idx & self.inner.capacity_mask) as usize];
        loop {
            {
                let mut guard = slot.lock().unwrap();
                if guard.seq == idx {
                    guard.pkt = Some(packet);
                    guard.seq = idx + 1;
                    break;
                }
            }
            std::thread::yield_now();
        }
        // Ring the doorbell with the newest visible index. Monotonic max:
        // concurrent producers may race; the processor only needs "some
        // index >= mine" to wake.
        self.ring_doorbell(idx as i64);
        Ok(idx)
    }

    fn ring_doorbell(&self, idx: i64) {
        // store-max: keep the doorbell monotonic.
        // (Signal has no compare-exchange; emulate under its lock via add.)
        let cur = self.inner.doorbell.load();
        if idx > cur {
            self.inner.doorbell.store(idx);
        } else {
            // Still notify waiters; a later producer may have published a
            // slot an earlier doorbell already covers.
            self.inner.doorbell.store(cur);
        }
    }

    /// Consumer side (packet processor): block until a packet is available,
    /// then take it. Returns `None` after shutdown once drained.
    ///
    /// Safe to call from several threads at once: each consumer claims the
    /// read index with a compare-exchange first, so packets are handed out
    /// exactly once and in ring order even with a pool of processors.
    pub fn dequeue_blocking(&self) -> Option<AqlPacket> {
        loop {
            let r = self.inner.read_index.load(Ordering::Acquire);
            let w = self.inner.write_index.load(Ordering::Acquire);
            if r < w {
                // Claim slot r before touching it; a lost race just retries
                // with the advanced index.
                if self
                    .inner
                    .read_index
                    .compare_exchange(r, r + 1, Ordering::AcqRel, Ordering::Acquire)
                    .is_err()
                {
                    continue;
                }
                let slot = &self.inner.slots[(r & self.inner.capacity_mask) as usize];
                loop {
                    {
                        let mut guard = slot.lock().unwrap();
                        // Take only the packet stamped for *this* ring
                        // index — a neighbouring lap's payload stays put.
                        if guard.seq == r + 1 {
                            let pkt = guard.pkt.take().expect("sequenced slot has packet");
                            // Free the slot for the producer one lap ahead.
                            guard.seq = r + self.inner.capacity_mask + 1;
                            return Some(pkt);
                        }
                    }
                    // The producer bumped the write index but hasn't stored
                    // the payload yet: it is about to, spin briefly.
                    std::thread::yield_now();
                }
            }
            if self.inner.shut_down.load(Ordering::Acquire) {
                return None;
            }
            // Spin-poll briefly (hot dispatch path: the producer usually
            // publishes within a few µs), then sleep on the doorbell until
            // a producer publishes index >= r. No spinning on single-core
            // hosts (see util::spin_enabled).
            let spin_start = std::time::Instant::now();
            let mut published = false;
            while crate::util::spin_enabled()
                && spin_start.elapsed() < std::time::Duration::from_micros(20)
            {
                if self.inner.write_index.load(Ordering::Acquire) > r
                    || self.inner.shut_down.load(Ordering::Acquire)
                {
                    published = true;
                    break;
                }
                std::hint::spin_loop();
            }
            if !published {
                let _ = self.inner.doorbell.wait_until(
                    Some(std::time::Duration::from_millis(50)),
                    |db| db >= r as i64,
                );
            }
        }
    }

    /// Mark the queue for shutdown and wake the processor.
    pub fn shutdown(&self) {
        self.inner.shut_down.store(true, Ordering::Release);
        // Wake any sleeping consumer.
        let cur = self.inner.doorbell.load();
        self.inner.doorbell.store(cur);
    }

    pub fn is_shut_down(&self) -> bool {
        self.inner.shut_down.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsa::packet::AqlPacket;
    use crate::hsa::signal::Signal;
    use std::thread;

    fn noop_packet() -> AqlPacket {
        AqlPacket::barrier(vec![], Signal::new(1))
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(Queue::new(3).capacity(), 4);
        assert_eq!(Queue::new(16).capacity(), 16);
        assert_eq!(Queue::new(0).capacity(), 2);
    }

    #[test]
    fn fifo_order_single_producer() {
        let q = Queue::new(8);
        for i in 0..5 {
            let (pkt, _) = AqlPacket::dispatch(i, vec![], Signal::new(1));
            q.enqueue(pkt).unwrap();
        }
        for i in 0..5 {
            match q.dequeue_blocking().unwrap() {
                AqlPacket::KernelDispatch(d) => assert_eq!(d.kernel_object, i),
                _ => panic!("wrong packet type"),
            }
        }
    }

    #[test]
    fn depth_tracks_in_flight() {
        let q = Queue::new(8);
        assert_eq!(q.depth(), 0);
        q.enqueue(noop_packet()).unwrap();
        q.enqueue(noop_packet()).unwrap();
        assert_eq!(q.depth(), 2);
        q.dequeue_blocking().unwrap();
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn enqueue_after_shutdown_fails() {
        let q = Queue::new(4);
        q.shutdown();
        assert!(matches!(q.enqueue(noop_packet()), Err(HsaError::QueueShutDown)));
    }

    #[test]
    fn dequeue_returns_none_when_drained_after_shutdown() {
        let q = Queue::new(4);
        q.enqueue(noop_packet()).unwrap();
        q.shutdown();
        assert!(q.dequeue_blocking().is_some());
        assert!(q.dequeue_blocking().is_none());
    }

    #[test]
    fn consumer_wakes_on_doorbell() {
        let q = Queue::new(4);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.dequeue_blocking());
        thread::sleep(std::time::Duration::from_millis(20));
        q.enqueue(noop_packet()).unwrap();
        assert!(h.join().unwrap().is_some());
    }

    #[test]
    fn backpressure_blocks_then_releases() {
        let q = Queue::new(2); // capacity 2
        q.enqueue(noop_packet()).unwrap();
        q.enqueue(noop_packet()).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.enqueue(noop_packet()));
        thread::sleep(std::time::Duration::from_millis(20));
        // Third producer has reserved its index but is blocked on the full
        // ring (depth counts reservations).
        assert_eq!(q.depth(), 3);
        q.dequeue_blocking().unwrap();
        h.join().unwrap().unwrap();
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn multi_producer_packets_all_arrive() {
        let q = Queue::new(64);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..50u64 {
                        let (pkt, _) =
                            AqlPacket::dispatch(p * 1000 + i, vec![], Signal::new(1));
                        q.enqueue(pkt).unwrap();
                    }
                })
            })
            .collect();
        let mut seen = Vec::new();
        for _ in 0..200 {
            match q.dequeue_blocking().unwrap() {
                AqlPacket::KernelDispatch(d) => seen.push(d.kernel_object),
                _ => panic!(),
            }
        }
        for p in producers {
            p.join().unwrap();
        }
        seen.sort();
        let mut expect: Vec<u64> =
            (0..4).flat_map(|p| (0..50).map(move |i| p * 1000 + i)).collect();
        expect.sort();
        assert_eq!(seen, expect);
    }

    #[test]
    fn multi_consumer_each_packet_delivered_exactly_once() {
        use std::sync::Mutex as StdMutex;
        let q = Queue::new(16);
        let seen = std::sync::Arc::new(StdMutex::new(Vec::new()));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let seen = std::sync::Arc::clone(&seen);
                thread::spawn(move || {
                    while let Some(pkt) = q.dequeue_blocking() {
                        if let AqlPacket::KernelDispatch(d) = pkt {
                            seen.lock().unwrap().push(d.kernel_object);
                        }
                    }
                })
            })
            .collect();
        for i in 0..120u64 {
            let (pkt, _) = AqlPacket::dispatch(i, vec![], Signal::new(1));
            q.enqueue(pkt).unwrap();
        }
        // Give consumers time to drain, then shut down and join.
        while q.depth() > 0 {
            thread::yield_now();
        }
        q.shutdown();
        for c in consumers {
            c.join().unwrap();
        }
        let mut got = seen.lock().unwrap().clone();
        got.sort();
        assert_eq!(got, (0..120).collect::<Vec<u64>>(), "no loss, no duplication");
    }

    #[test]
    fn full_lap_producers_do_not_clobber_claimed_slots() {
        // Tiny ring, many more packets than slots, concurrent consumer:
        // exercises the producer-waits-for-empty-slot path.
        let q = Queue::new(2);
        let q2 = q.clone();
        let consumer = thread::spawn(move || {
            let mut n = 0u64;
            while q2.dequeue_blocking().is_some() {
                n += 1;
            }
            n
        });
        for _ in 0..64 {
            q.enqueue(noop_packet()).unwrap();
        }
        while q.depth() > 0 {
            thread::yield_now();
        }
        q.shutdown();
        assert_eq!(consumer.join().unwrap(), 64);
    }
}
