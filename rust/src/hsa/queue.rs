//! User-mode AQL queues with the HSA write-index/doorbell protocol.
//!
//! A producer reserves a slot by bumping the write index, fills the slot,
//! then rings the doorbell signal with the new index. The packet processor
//! consumes slots in order (read index chases write index). We realize the
//! ring as a fixed-capacity `Vec<Mutex<Option<AqlPacket>>>` — one mutex per
//! slot keeps producers on distinct slots contention-free, as on hardware.

use crate::hsa::error::{HsaError, Result};
use crate::hsa::packet::AqlPacket;
use crate::hsa::signal::Signal;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cloneable handle to a queue.
#[derive(Debug, Clone)]
pub struct Queue {
    inner: Arc<QueueInner>,
}

#[derive(Debug)]
struct QueueInner {
    /// Ring storage; capacity is a power of two (HSA requirement).
    slots: Vec<Mutex<Option<AqlPacket>>>,
    capacity_mask: u64,
    /// Next slot a producer will write.
    write_index: AtomicU64,
    /// Next slot the packet processor will read.
    read_index: AtomicU64,
    /// Doorbell: stores the latest published write index.
    doorbell: Signal,
    shut_down: AtomicBool,
    id: u64,
}

static NEXT_QUEUE_ID: AtomicU64 = AtomicU64::new(1);

impl Queue {
    /// Create a queue with `capacity` slots (rounded up to a power of two).
    pub fn new(capacity: usize) -> Queue {
        let cap = capacity.next_power_of_two().max(2);
        let slots = (0..cap).map(|_| Mutex::new(None)).collect();
        Queue {
            inner: Arc::new(QueueInner {
                slots,
                capacity_mask: (cap - 1) as u64,
                write_index: AtomicU64::new(0),
                read_index: AtomicU64::new(0),
                doorbell: Signal::new(-1),
                shut_down: AtomicBool::new(false),
                id: NEXT_QUEUE_ID.fetch_add(1, Ordering::Relaxed),
            }),
        }
    }

    pub fn id(&self) -> u64 {
        self.inner.id
    }

    pub fn capacity(&self) -> usize {
        self.inner.slots.len()
    }

    /// Packets currently in flight (enqueued, not yet consumed).
    pub fn depth(&self) -> u64 {
        let w = self.inner.write_index.load(Ordering::Acquire);
        let r = self.inner.read_index.load(Ordering::Acquire);
        w.saturating_sub(r)
    }

    /// Producer side: reserve a slot, store the packet, ring the doorbell.
    /// Blocks (spin+yield) while the ring is full — AQL backpressure.
    pub fn enqueue(&self, packet: AqlPacket) -> Result<u64> {
        if self.inner.shut_down.load(Ordering::Acquire) {
            return Err(HsaError::QueueShutDown);
        }
        // Reserve.
        let idx = self.inner.write_index.fetch_add(1, Ordering::AcqRel);
        // Backpressure: wait until the slot is free (reader caught up to
        // within one lap).
        loop {
            let r = self.inner.read_index.load(Ordering::Acquire);
            if idx - r <= self.inner.capacity_mask {
                break;
            }
            std::thread::yield_now();
        }
        // Publish payload.
        let slot = &self.inner.slots[(idx & self.inner.capacity_mask) as usize];
        *slot.lock().unwrap() = Some(packet);
        // Ring the doorbell with the newest visible index. Monotonic max:
        // concurrent producers may race; the processor only needs "some
        // index >= mine" to wake.
        self.ring_doorbell(idx as i64);
        Ok(idx)
    }

    fn ring_doorbell(&self, idx: i64) {
        // store-max: keep the doorbell monotonic.
        // (Signal has no compare-exchange; emulate under its lock via add.)
        let cur = self.inner.doorbell.load();
        if idx > cur {
            self.inner.doorbell.store(idx);
        } else {
            // Still notify waiters; a later producer may have published a
            // slot an earlier doorbell already covers.
            self.inner.doorbell.store(cur);
        }
    }

    /// Consumer side (packet processor): block until a packet is available,
    /// then take it. Returns `None` after shutdown once drained.
    pub fn dequeue_blocking(&self) -> Option<AqlPacket> {
        loop {
            let r = self.inner.read_index.load(Ordering::Acquire);
            let w = self.inner.write_index.load(Ordering::Acquire);
            if r < w {
                let slot = &self.inner.slots[(r & self.inner.capacity_mask) as usize];
                let mut guard = slot.lock().unwrap();
                if let Some(pkt) = guard.take() {
                    drop(guard);
                    self.inner.read_index.store(r + 1, Ordering::Release);
                    return Some(pkt);
                }
                // Producer reserved the slot but hasn't stored yet: spin.
                drop(guard);
                std::thread::yield_now();
                continue;
            }
            if self.inner.shut_down.load(Ordering::Acquire) {
                return None;
            }
            // Spin-poll briefly (hot dispatch path: the producer usually
            // publishes within a few µs), then sleep on the doorbell until
            // a producer publishes index >= r. No spinning on single-core
            // hosts (see util::spin_enabled).
            let spin_start = std::time::Instant::now();
            let mut published = false;
            while crate::util::spin_enabled()
                && spin_start.elapsed() < std::time::Duration::from_micros(20)
            {
                if self.inner.write_index.load(Ordering::Acquire) > r
                    || self.inner.shut_down.load(Ordering::Acquire)
                {
                    published = true;
                    break;
                }
                std::hint::spin_loop();
            }
            if !published {
                let _ = self.inner.doorbell.wait_until(
                    Some(std::time::Duration::from_millis(50)),
                    |db| db >= r as i64,
                );
            }
        }
    }

    /// Mark the queue for shutdown and wake the processor.
    pub fn shutdown(&self) {
        self.inner.shut_down.store(true, Ordering::Release);
        // Wake any sleeping consumer.
        let cur = self.inner.doorbell.load();
        self.inner.doorbell.store(cur);
    }

    pub fn is_shut_down(&self) -> bool {
        self.inner.shut_down.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsa::packet::AqlPacket;
    use crate::hsa::signal::Signal;
    use std::thread;

    fn noop_packet() -> AqlPacket {
        AqlPacket::barrier(vec![], Signal::new(1))
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(Queue::new(3).capacity(), 4);
        assert_eq!(Queue::new(16).capacity(), 16);
        assert_eq!(Queue::new(0).capacity(), 2);
    }

    #[test]
    fn fifo_order_single_producer() {
        let q = Queue::new(8);
        for i in 0..5 {
            let (pkt, _) = AqlPacket::dispatch(i, vec![], Signal::new(1));
            q.enqueue(pkt).unwrap();
        }
        for i in 0..5 {
            match q.dequeue_blocking().unwrap() {
                AqlPacket::KernelDispatch(d) => assert_eq!(d.kernel_object, i),
                _ => panic!("wrong packet type"),
            }
        }
    }

    #[test]
    fn depth_tracks_in_flight() {
        let q = Queue::new(8);
        assert_eq!(q.depth(), 0);
        q.enqueue(noop_packet()).unwrap();
        q.enqueue(noop_packet()).unwrap();
        assert_eq!(q.depth(), 2);
        q.dequeue_blocking().unwrap();
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn enqueue_after_shutdown_fails() {
        let q = Queue::new(4);
        q.shutdown();
        assert!(matches!(q.enqueue(noop_packet()), Err(HsaError::QueueShutDown)));
    }

    #[test]
    fn dequeue_returns_none_when_drained_after_shutdown() {
        let q = Queue::new(4);
        q.enqueue(noop_packet()).unwrap();
        q.shutdown();
        assert!(q.dequeue_blocking().is_some());
        assert!(q.dequeue_blocking().is_none());
    }

    #[test]
    fn consumer_wakes_on_doorbell() {
        let q = Queue::new(4);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.dequeue_blocking());
        thread::sleep(std::time::Duration::from_millis(20));
        q.enqueue(noop_packet()).unwrap();
        assert!(h.join().unwrap().is_some());
    }

    #[test]
    fn backpressure_blocks_then_releases() {
        let q = Queue::new(2); // capacity 2
        q.enqueue(noop_packet()).unwrap();
        q.enqueue(noop_packet()).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.enqueue(noop_packet()));
        thread::sleep(std::time::Duration::from_millis(20));
        // Third producer has reserved its index but is blocked on the full
        // ring (depth counts reservations).
        assert_eq!(q.depth(), 3);
        q.dequeue_blocking().unwrap();
        h.join().unwrap().unwrap();
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn multi_producer_packets_all_arrive() {
        let q = Queue::new(64);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..50u64 {
                        let (pkt, _) =
                            AqlPacket::dispatch(p * 1000 + i, vec![], Signal::new(1));
                        q.enqueue(pkt).unwrap();
                    }
                })
            })
            .collect();
        let mut seen = Vec::new();
        for _ in 0..200 {
            match q.dequeue_blocking().unwrap() {
                AqlPacket::KernelDispatch(d) => seen.push(d.kernel_object),
                _ => panic!(),
            }
        }
        for p in producers {
            p.join().unwrap();
        }
        seen.sort();
        let mut expect: Vec<u64> =
            (0..4).flat_map(|p| (0..50).map(move |i| p * 1000 + i)).collect();
        expect.sort();
        assert_eq!(seen, expect);
    }
}
