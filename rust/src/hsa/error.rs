//! Error type shared by the HSA runtime layers.

use crate::tf::tensor::TensorError;

#[derive(Debug, thiserror::Error)]
pub enum HsaError {
    #[error("no agent of type {0} found")]
    NoSuchAgent(String),

    #[error("unknown kernel object {0:#x}")]
    UnknownKernel(u64),

    #[error("queue is shut down")]
    QueueShutDown,

    #[error("signal wait timed out after {0:?}")]
    SignalTimeout(std::time::Duration),

    #[error("kernel execution failed: {0}")]
    KernelFailed(String),

    #[error("tensor error: {0}")]
    Tensor(#[from] TensorError),

    #[error("memory error: {0}")]
    Memory(String),

    #[error("runtime error: {0}")]
    Runtime(String),
}

pub type Result<T> = std::result::Result<T, HsaError>;
