//! Error type shared by the HSA runtime layers.

use crate::tf::tensor::TensorError;

#[derive(Debug, thiserror::Error)]
pub enum HsaError {
    #[error("no agent of type {0} found")]
    NoSuchAgent(String),

    #[error("unknown kernel object {0:#x}")]
    UnknownKernel(u64),

    #[error("queue is shut down")]
    QueueShutDown,

    #[error("signal wait timed out after {0:?}")]
    SignalTimeout(std::time::Duration),

    #[error("kernel execution failed: {0}")]
    KernelFailed(String),

    #[error("agent down: {0}")]
    AgentDown(String),

    #[error("tensor error: {0}")]
    Tensor(#[from] TensorError),

    #[error("memory error: {0}")]
    Memory(String),

    #[error("runtime error: {0}")]
    Runtime(String),
}

/// Display prefix of [`HsaError::AgentDown`]. Packet processors stringify
/// agent errors into the kernarg output slot, so by the time a waiter sees
/// one it is a `KernelFailed(String)` — the prefix is how the retry paths
/// recognize an agent failure (retryable elsewhere) from a genuine kernel
/// failure (not retryable).
pub const AGENT_DOWN_PREFIX: &str = "agent down: ";

/// Whether a kernel-failure message (the stringified error a packet
/// processor wrote into the output slot) indicates the *agent* died, as
/// opposed to the kernel itself failing.
pub fn message_indicates_agent_down(msg: &str) -> bool {
    msg.starts_with(AGENT_DOWN_PREFIX)
}

impl HsaError {
    /// Whether this error means the dispatched-to agent is down (killed or
    /// fault-injected), so the dispatch is safe to retry on another agent.
    pub fn indicates_agent_down(&self) -> bool {
        match self {
            HsaError::AgentDown(_) => true,
            HsaError::KernelFailed(msg) => message_indicates_agent_down(msg),
            _ => false,
        }
    }

    /// The name of the downed agent, when this error carries one.
    pub fn agent_down_name(&self) -> Option<&str> {
        match self {
            HsaError::AgentDown(name) => Some(name),
            HsaError::KernelFailed(msg) => {
                msg.strip_prefix(AGENT_DOWN_PREFIX).map(|rest| rest.trim())
            }
            _ => None,
        }
    }
}

pub type Result<T> = std::result::Result<T, HsaError>;
