//! The HSA runtime: agent discovery, queue creation, packet processors.
//!
//! Mirrors the lifecycle of the real runtime: `hsa_init` (here:
//! [`HsaRuntime::builder`] + agents), `hsa_queue_create` (spawns a packet
//! processor thread per queue, the software analogue of the hardware queue
//! scheduler), kernel dispatch via AQL packets + doorbell, and
//! `hsa_shut_down` (drain + join).

use crate::hsa::agent::{Agent, DeviceType};
use crate::hsa::error::{HsaError, Result};
use crate::hsa::memory::{ultra96_regions, MemoryPool};
use crate::hsa::packet::{AqlPacket, KernelArgs};
use crate::hsa::queue::Queue;
use crate::hsa::signal::Signal;
use crate::tf::tensor::Tensor;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Default timeout for synchronous dispatches (generous: includes PJRT
/// first-run compilation on the kernel's executor).
pub const DISPATCH_TIMEOUT: Duration = Duration::from_secs(120);

pub struct HsaRuntimeBuilder {
    agents: Vec<Arc<dyn Agent>>,
}

impl HsaRuntimeBuilder {
    pub fn with_agent(mut self, agent: Arc<dyn Agent>) -> Self {
        self.agents.push(agent);
        self
    }

    /// Register every member of a multi-FPGA pool as an independent agent
    /// (each with its own PR regions, ICAP and reconfiguration manager).
    /// Build the pool first with [`crate::sharding::FpgaPool::new`] so
    /// role registration and the [`crate::sharding::Router`] can keep
    /// using the same handles; `agent_by_type(DeviceType::Fpga)` resolves
    /// to the pool's first member.
    pub fn with_fpga_pool(mut self, pool: &crate::sharding::FpgaPool) -> Self {
        for agent in pool.agents() {
            self.agents.push(Arc::clone(agent) as Arc<dyn Agent>);
        }
        self
    }

    pub fn build(self) -> HsaRuntime {
        HsaRuntime {
            agents: self.agents,
            queues: Mutex::new(Vec::new()),
            regions: ultra96_regions(),
        }
    }
}

struct QueueRecord {
    queue: Queue,
    processors: Vec<JoinHandle<()>>,
    agent_name: String,
}

/// The runtime instance (one per process in HSA; plain struct here so tests
/// can create as many as they like).
pub struct HsaRuntime {
    agents: Vec<Arc<dyn Agent>>,
    queues: Mutex<Vec<QueueRecord>>,
    regions: Vec<MemoryPool>,
}

impl HsaRuntime {
    pub fn builder() -> HsaRuntimeBuilder {
        HsaRuntimeBuilder { agents: Vec::new() }
    }

    /// All discovered agents.
    pub fn agents(&self) -> &[Arc<dyn Agent>] {
        &self.agents
    }

    /// First agent of the requested device type (`hsa_iterate_agents` +
    /// filter, the common pattern).
    pub fn agent_by_type(&self, ty: DeviceType) -> Result<Arc<dyn Agent>> {
        self.agents
            .iter()
            .find(|a| a.info().device_type == ty)
            .cloned()
            .ok_or_else(|| HsaError::NoSuchAgent(ty.to_string()))
    }

    /// Discoverable memory regions.
    pub fn regions(&self) -> &[MemoryPool] {
        &self.regions
    }

    /// Create a queue bound to `agent` and spawn its packet processor.
    pub fn create_queue(&self, agent: Arc<dyn Agent>, size: usize) -> Queue {
        self.create_queue_with_processors(agent, size, 1)
    }

    /// Create a queue drained by a *pool* of `workers` packet processors.
    ///
    /// With more than one worker, independent kernel dispatches on this
    /// queue execute concurrently — the software analogue of a device with
    /// several compute units (for the FPGA agent: several PR regions), and
    /// the mechanism that lets an async serving front keep multiple
    /// batches in flight at once. Packets are still *handed out* in ring
    /// order, but retirement order is whatever the kernels' runtimes give;
    /// callers needing cross-packet ordering must use barrier packets or
    /// completion signals. Note the AQL barrier bit's "block later packets"
    /// semantics only holds on single-worker queues.
    pub fn create_queue_with_processors(
        &self,
        agent: Arc<dyn Agent>,
        size: usize,
        workers: usize,
    ) -> Queue {
        let size = size.min(agent.info().queue_max_size);
        let queue = Queue::new(size);
        let name = agent.info().name.clone();
        let processors = (0..workers.max(1))
            .map(|i| {
                let q2 = queue.clone();
                let a2 = Arc::clone(&agent);
                std::thread::Builder::new()
                    .name(format!("pktproc-{name}-{i}"))
                    .spawn(move || packet_processor(q2, a2))
                    .expect("spawn packet processor")
            })
            .collect();
        self.queues.lock().unwrap().push(QueueRecord {
            queue: queue.clone(),
            processors,
            agent_name: name,
        });
        queue
    }

    /// Asynchronous dispatch: enqueue a kernel packet, return the
    /// completion signal and the output slot.
    pub fn dispatch_async(
        &self,
        queue: &Queue,
        kernel_object: u64,
        inputs: Vec<Tensor>,
    ) -> Result<(Signal, KernelArgs)> {
        let completion = Signal::new(1);
        let (pkt, args) = AqlPacket::dispatch(kernel_object, inputs, completion.clone());
        queue.enqueue(pkt)?;
        Ok((completion, args))
    }

    /// Synchronous dispatch: enqueue, wait for retire, return outputs.
    pub fn dispatch_sync(
        &self,
        queue: &Queue,
        kernel_object: u64,
        inputs: Vec<Tensor>,
    ) -> Result<Vec<Tensor>> {
        let (completion, args) = self.dispatch_async(queue, kernel_object, inputs)?;
        completion.wait_eq(0, Some(DISPATCH_TIMEOUT))?;
        match args.take_output() {
            Some(Ok(outs)) => Ok(outs),
            Some(Err(msg)) => Err(HsaError::KernelFailed(msg)),
            None => Err(HsaError::KernelFailed(
                "kernel retired without writing outputs".into(),
            )),
        }
    }

    /// Enqueue a barrier-AND packet over `deps`.
    pub fn barrier(&self, queue: &Queue, deps: Vec<Signal>) -> Result<Signal> {
        let completion = Signal::new(1);
        queue.enqueue(AqlPacket::barrier(deps, completion.clone()))?;
        Ok(completion)
    }

    /// Shut down all queues and join their processors.
    pub fn shutdown(&self) {
        let mut queues = self.queues.lock().unwrap();
        for rec in queues.iter() {
            rec.queue.shutdown();
        }
        for rec in queues.iter_mut() {
            for h in rec.processors.drain(..) {
                if h.join().is_err() {
                    eprintln!("packet processor for {} panicked", rec.agent_name);
                }
            }
        }
        queues.clear();
    }
}

impl Drop for HsaRuntime {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The per-queue packet processor loop (what the hardware queue scheduler
/// or kernel-mode driver does on a real HSA system).
fn packet_processor(queue: Queue, agent: Arc<dyn Agent>) {
    while let Some(pkt) = queue.dequeue_blocking() {
        match pkt {
            AqlPacket::KernelDispatch(d) => {
                let res = agent.execute(&d);
                if let Err(e) = res {
                    let mut slot = d.args.output.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(Err(e.to_string()));
                    }
                }
                d.completion_signal.subtract(1);
            }
            AqlPacket::BarrierAnd(b) => {
                for dep in &b.dep_signals {
                    // Barrier-AND blocks the *queue* until deps clear.
                    let _ = dep.wait_eq(0, None);
                }
                b.completion_signal.subtract(1);
            }
            AqlPacket::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsa::agent::AgentInfo;
    use crate::hsa::packet::KernelDispatchPacket;

    /// Trivial test agent: kernel 0 echoes inputs; kernel 1 fails; kernel 2
    /// sleeps briefly (for barrier ordering tests).
    struct EchoAgent {
        info: AgentInfo,
    }

    impl EchoAgent {
        fn new() -> Arc<Self> {
            Arc::new(EchoAgent {
                info: AgentInfo {
                    name: "echo".into(),
                    vendor: "test".into(),
                    device_type: DeviceType::Cpu,
                    queue_max_size: 64,
                    isa: "test".into(),
                    clock_mhz: 1000,
                    compute_units: 1,
                },
            })
        }
    }

    impl Agent for EchoAgent {
        fn info(&self) -> &AgentInfo {
            &self.info
        }

        fn execute(&self, packet: &KernelDispatchPacket) -> Result<()> {
            match packet.kernel_object {
                0 => {
                    *packet.args.output.lock().unwrap() =
                        Some(Ok(packet.args.inputs.clone()));
                    Ok(())
                }
                1 => Err(HsaError::KernelFailed("injected failure".into())),
                2 => {
                    std::thread::sleep(Duration::from_millis(30));
                    *packet.args.output.lock().unwrap() = Some(Ok(vec![]));
                    Ok(())
                }
                k => Err(HsaError::UnknownKernel(k)),
            }
        }
    }

    fn runtime() -> HsaRuntime {
        HsaRuntime::builder().with_agent(EchoAgent::new()).build()
    }

    #[test]
    fn discovery_by_type() {
        let rt = runtime();
        assert!(rt.agent_by_type(DeviceType::Cpu).is_ok());
        assert!(matches!(
            rt.agent_by_type(DeviceType::Fpga),
            Err(HsaError::NoSuchAgent(_))
        ));
    }

    #[test]
    fn sync_dispatch_round_trip() {
        let rt = runtime();
        let agent = rt.agent_by_type(DeviceType::Cpu).unwrap();
        let q = rt.create_queue(agent, 16);
        let t = Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap();
        let out = rt.dispatch_sync(&q, 0, vec![t.clone()]).unwrap();
        assert_eq!(out, vec![t]);
        rt.shutdown();
    }

    #[test]
    fn failed_kernel_propagates_error() {
        let rt = runtime();
        let agent = rt.agent_by_type(DeviceType::Cpu).unwrap();
        let q = rt.create_queue(agent, 16);
        let err = rt.dispatch_sync(&q, 1, vec![]).unwrap_err();
        assert!(matches!(err, HsaError::KernelFailed(_)), "{err}");
    }

    #[test]
    fn unknown_kernel_object_errors() {
        let rt = runtime();
        let agent = rt.agent_by_type(DeviceType::Cpu).unwrap();
        let q = rt.create_queue(agent, 16);
        assert!(rt.dispatch_sync(&q, 99, vec![]).is_err());
    }

    #[test]
    fn async_dispatch_and_signal() {
        let rt = runtime();
        let agent = rt.agent_by_type(DeviceType::Cpu).unwrap();
        let q = rt.create_queue(agent, 16);
        let (sig, args) = rt.dispatch_async(&q, 0, vec![]).unwrap();
        sig.wait_eq(0, Some(Duration::from_secs(5))).unwrap();
        assert!(matches!(args.take_output(), Some(Ok(_))));
    }

    #[test]
    fn barrier_waits_for_dependencies() {
        let rt = runtime();
        let agent = rt.agent_by_type(DeviceType::Cpu).unwrap();
        let q = rt.create_queue(agent.clone(), 16);
        let q2 = rt.create_queue(agent, 16);
        // Slow kernel on q, barrier on q2 depending on it.
        let (slow_sig, _args) = rt.dispatch_async(&q, 2, vec![]).unwrap();
        let barrier_done = rt.barrier(&q2, vec![slow_sig.clone()]).unwrap();
        barrier_done.wait_eq(0, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(slow_sig.load(), 0, "barrier retired before its dep");
    }

    #[test]
    fn processor_pool_overlaps_kernel_execution() {
        let rt = runtime();
        let agent = rt.agent_by_type(DeviceType::Cpu).unwrap();
        let q = rt.create_queue_with_processors(agent, 16, 4);
        let t0 = std::time::Instant::now();
        // Four 30 ms kernels; a single processor would serialize to 120 ms.
        let pending: Vec<_> =
            (0..4).map(|_| rt.dispatch_async(&q, 2, vec![]).unwrap()).collect();
        for (sig, _) in &pending {
            sig.wait_eq(0, Some(Duration::from_secs(5))).unwrap();
        }
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_millis(110),
            "kernels should overlap across the processor pool, took {elapsed:?}"
        );
        rt.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_drops_clean() {
        let rt = runtime();
        let agent = rt.agent_by_type(DeviceType::Cpu).unwrap();
        let _q = rt.create_queue(agent, 16);
        rt.shutdown();
        rt.shutdown();
    }

    #[test]
    fn regions_exposed() {
        let rt = runtime();
        assert_eq!(rt.regions().len(), 3);
    }
}
