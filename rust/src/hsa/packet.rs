//! AQL (Architected Queuing Language) packets.
//!
//! The packet layout follows HSA PPS §2.9: a 16-bit header (packet type,
//! acquire/release fence scopes, barrier bit) followed by a type-specific
//! body. We keep the header encoding bit-exact (it is cheap and lets the
//! tests assert protocol conformance) while the body carries Rust-native
//! payloads (tensors instead of raw GPU pointers).

use crate::hsa::signal::Signal;
use crate::tf::tensor::Tensor;
use std::sync::{Arc, Mutex};

/// HSA packet type field values (PPS Table 2-4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum PacketType {
    VendorSpecific = 0,
    Invalid = 1,
    KernelDispatch = 2,
    BarrierAnd = 3,
    AgentDispatch = 4,
    BarrierOr = 5,
}

/// Memory fence scope for acquire/release (PPS §2.9.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FenceScope {
    None = 0,
    Agent = 1,
    System = 2,
}

/// The 16-bit AQL packet header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub packet_type: PacketType,
    pub barrier: bool,
    pub acquire: FenceScope,
    pub release: FenceScope,
}

impl Header {
    /// Encode per HSA PPS: type[7:0], barrier[8], acquire[10:9], release[12:11].
    pub fn encode(self) -> u16 {
        (self.packet_type as u16)
            | ((self.barrier as u16) << 8)
            | ((self.acquire as u16) << 9)
            | ((self.release as u16) << 11)
    }

    pub fn decode(bits: u16) -> Option<Header> {
        let packet_type = match bits & 0xff {
            0 => PacketType::VendorSpecific,
            1 => PacketType::Invalid,
            2 => PacketType::KernelDispatch,
            3 => PacketType::BarrierAnd,
            4 => PacketType::AgentDispatch,
            5 => PacketType::BarrierOr,
            _ => return None,
        };
        let scope = |v: u16| match v {
            0 => Some(FenceScope::None),
            1 => Some(FenceScope::Agent),
            2 => Some(FenceScope::System),
            _ => None,
        };
        Some(Header {
            packet_type,
            barrier: bits & (1 << 8) != 0,
            acquire: scope((bits >> 9) & 0b11)?,
            release: scope((bits >> 11) & 0b11)?,
        })
    }

    pub fn dispatch() -> Header {
        Header {
            packet_type: PacketType::KernelDispatch,
            barrier: false,
            acquire: FenceScope::System,
            release: FenceScope::System,
        }
    }

    pub fn barrier_and() -> Header {
        Header {
            packet_type: PacketType::BarrierAnd,
            barrier: true,
            acquire: FenceScope::System,
            release: FenceScope::System,
        }
    }
}

/// Kernel arguments: input tensors in, output tensors out through a slot
/// the dispatcher can read after the completion signal fires (the software
/// stand-in for the kernarg segment + output buffers).
#[derive(Debug, Clone)]
pub struct KernelArgs {
    pub inputs: Vec<Tensor>,
    /// Filled by the packet processor on retire.
    pub output: Arc<Mutex<Option<std::result::Result<Vec<Tensor>, String>>>>,
}

impl KernelArgs {
    pub fn new(inputs: Vec<Tensor>) -> KernelArgs {
        KernelArgs { inputs, output: Arc::new(Mutex::new(None)) }
    }

    /// Take the result after completion (None if the kernel never retired).
    pub fn take_output(&self) -> Option<std::result::Result<Vec<Tensor>, String>> {
        self.output.lock().unwrap().take()
    }
}

/// Kernel-dispatch packet body.
#[derive(Debug, Clone)]
pub struct KernelDispatchPacket {
    pub header: Header,
    /// Opaque kernel object handle (registry id of the registered kernel —
    /// for FPGA agents this names a pre-synthesized bitstream / role).
    pub kernel_object: u64,
    /// Grid/workgroup sizes are kept for protocol fidelity; the simulated
    /// devices derive their own parallelism from the kernel workload.
    pub grid_size: [u32; 3],
    pub workgroup_size: [u16; 3],
    pub args: KernelArgs,
    /// Decremented to 0 when the kernel retires.
    pub completion_signal: Signal,
}

/// Barrier-AND packet body: the packet processor stalls until all
/// dependency signals are 0, then decrements the completion signal.
#[derive(Debug, Clone)]
pub struct BarrierAndPacket {
    pub header: Header,
    /// Up to 5 dependencies, per the HSA packet layout.
    pub dep_signals: Vec<Signal>,
    pub completion_signal: Signal,
}

/// A queue slot.
#[derive(Debug, Clone)]
pub enum AqlPacket {
    KernelDispatch(KernelDispatchPacket),
    BarrierAnd(BarrierAndPacket),
    /// Ends the packet-processor thread (runtime-internal, not part of AQL).
    Shutdown,
}

impl AqlPacket {
    pub fn dispatch(
        kernel_object: u64,
        inputs: Vec<Tensor>,
        completion_signal: Signal,
    ) -> (AqlPacket, KernelArgs) {
        let args = KernelArgs::new(inputs);
        let pkt = AqlPacket::KernelDispatch(KernelDispatchPacket {
            header: Header::dispatch(),
            kernel_object,
            grid_size: [1, 1, 1],
            workgroup_size: [1, 1, 1],
            args: args.clone(),
            completion_signal,
        });
        (pkt, args)
    }

    pub fn barrier(dep_signals: Vec<Signal>, completion_signal: Signal) -> AqlPacket {
        assert!(dep_signals.len() <= 5, "barrier-AND carries at most 5 deps");
        AqlPacket::BarrierAnd(BarrierAndPacket {
            header: Header::barrier_and(),
            dep_signals,
            completion_signal,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_encodes_dispatch_per_spec() {
        let h = Header::dispatch();
        let bits = h.encode();
        assert_eq!(bits & 0xff, 2); // KernelDispatch
        assert_eq!((bits >> 9) & 0b11, 2); // acquire system
        assert_eq!((bits >> 11) & 0b11, 2); // release system
        assert_eq!(bits & (1 << 8), 0); // no barrier bit
    }

    #[test]
    fn header_round_trips() {
        for pt in [
            PacketType::VendorSpecific,
            PacketType::KernelDispatch,
            PacketType::BarrierAnd,
            PacketType::BarrierOr,
            PacketType::AgentDispatch,
        ] {
            for barrier in [false, true] {
                let h = Header {
                    packet_type: pt,
                    barrier,
                    acquire: FenceScope::Agent,
                    release: FenceScope::System,
                };
                assert_eq!(Header::decode(h.encode()), Some(h));
            }
        }
    }

    #[test]
    fn decode_rejects_bad_type() {
        assert_eq!(Header::decode(200), None);
    }

    #[test]
    fn kernel_args_output_slot() {
        let args = KernelArgs::new(vec![]);
        assert!(args.take_output().is_none());
        *args.output.lock().unwrap() = Some(Ok(vec![]));
        assert!(matches!(args.take_output(), Some(Ok(v)) if v.is_empty()));
        assert!(args.take_output().is_none(), "take consumes");
    }

    #[test]
    #[should_panic(expected = "at most 5")]
    fn barrier_rejects_too_many_deps() {
        let sigs: Vec<Signal> = (0..6).map(|_| Signal::new(0)).collect();
        AqlPacket::barrier(sigs, Signal::new(1));
    }
}
