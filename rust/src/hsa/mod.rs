//! HSA-Foundation-style runtime (the paper's §III substrate).
//!
//! The paper dispatches TensorFlow kernels through "HSA runtime calls" so
//! that FPGAs, CPUs and GPUs share one queue/signal/memory model. This
//! module implements that runtime shape in userspace Rust:
//!
//! * [`signal::Signal`] — HSA signals (relaxed/blocking waits, doorbells,
//!   completion counters);
//! * [`packet::AqlPacket`] — Architected Queuing Language packets
//!   (kernel-dispatch and barrier-AND, with the standard header fields);
//! * [`queue::Queue`] — user-mode ring-buffer queues with a write-index /
//!   doorbell protocol and a packet-processor thread per queue;
//! * [`agent::Agent`] — the device abstraction the packet processor calls
//!   into (implemented by `cpu::CpuAgent` and `fpga::FpgaAgent`);
//! * [`memory`] — region descriptors and a tracking allocator;
//! * [`runtime::HsaRuntime`] — discovery, queue creation, shutdown.

pub mod agent;
pub mod error;
pub mod memory;
pub mod packet;
pub mod queue;
pub mod runtime;
pub mod signal;

pub use agent::{Agent, AgentInfo, DeviceType};
pub use error::HsaError;
pub use packet::{AqlPacket, BarrierAndPacket, KernelArgs, KernelDispatchPacket};
pub use queue::Queue;
pub use runtime::HsaRuntime;
pub use signal::Signal;
