//! HSA signals: 64-bit values with atomic updates and blocking waits.
//!
//! Semantics follow the HSA runtime spec's `hsa_signal_t`: creation with an
//! initial value, `store`/`add`/`subtract` with release semantics, and
//! condition waits (`wait_eq`, `wait_lt`) with an optional timeout. A
//! kernel-dispatch completion signal is initialized to 1 and decremented by
//! the packet processor when the kernel retires; a barrier-AND packet waits
//! for all its dependency signals to reach 0.
//!
//! Implementation (§Perf, EXPERIMENTS.md): the value is an `AtomicI64` so
//! the waiter's spin phase is a plain load (no lock-line bouncing); the
//! mutex+condvar pair exists only for the sleep path. Updaters store the
//! value, take the (empty) mutex as a memory barrier against missed
//! wake-ups, and notify.

use crate::hsa::error::{HsaError, Result};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Spin budget before falling back to the condvar (see `wait_until`).
const SPIN_BUDGET: Duration = Duration::from_micros(15);

#[derive(Debug)]
struct Inner {
    value: AtomicI64,
    sleep_lock: Mutex<()>,
    cv: Condvar,
}

/// Cloneable handle to a signal (all clones observe the same value).
#[derive(Debug, Clone)]
pub struct Signal {
    inner: Arc<Inner>,
}

impl Signal {
    pub fn new(initial: i64) -> Signal {
        Signal {
            inner: Arc::new(Inner {
                value: AtomicI64::new(initial),
                sleep_lock: Mutex::new(()),
                cv: Condvar::new(),
            }),
        }
    }

    #[inline]
    pub fn load(&self) -> i64 {
        self.inner.value.load(Ordering::Acquire)
    }

    /// Non-blocking poll for the common completion condition (value 0 —
    /// a retired kernel-dispatch packet). Used by async callers that want
    /// to check a pending dispatch without sleeping on it.
    #[inline]
    pub fn is_zero(&self) -> bool {
        self.load() == 0
    }

    fn wake(&self) {
        // Pairing with the waiter's check-under-lock prevents the missed
        // wake-up: we cannot publish between its predicate check and its
        // cv.wait because we take the same lock first.
        drop(self.inner.sleep_lock.lock().unwrap());
        self.inner.cv.notify_all();
    }

    pub fn store(&self, v: i64) {
        self.inner.value.store(v, Ordering::Release);
        self.wake();
    }

    pub fn add(&self, d: i64) -> i64 {
        let v = self.inner.value.fetch_add(d, Ordering::AcqRel) + d;
        self.wake();
        v
    }

    pub fn subtract(&self, d: i64) -> i64 {
        self.add(-d)
    }

    /// Block until `pred(value)` holds; `timeout=None` waits forever.
    ///
    /// Hot path: an adaptive spin phase (~15 µs of plain atomic loads)
    /// precedes the condvar sleep, so warm kernel dispatches never pay the
    /// futex wake-up latency (EXPERIMENTS.md §Perf: ~13 µs → ~3 µs).
    pub fn wait_until(
        &self,
        timeout: Option<Duration>,
        pred: impl Fn(i64) -> bool,
    ) -> Result<i64> {
        // Fast path.
        let v = self.load();
        if pred(v) {
            return Ok(v);
        }
        let start = Instant::now();
        // Spin phase (skipped on single-core hosts, where spinning only
        // delays the thread being waited for).
        if crate::util::spin_enabled() {
            loop {
                let v = self.load();
                if pred(v) {
                    return Ok(v);
                }
                if start.elapsed() > SPIN_BUDGET {
                    break;
                }
                std::hint::spin_loop();
            }
        }
        // Sleep phase.
        let mut guard = self.inner.sleep_lock.lock().unwrap();
        loop {
            let v = self.load();
            if pred(v) {
                return Ok(v);
            }
            match timeout {
                None => guard = self.inner.cv.wait(guard).unwrap(),
                Some(t) => {
                    let elapsed = start.elapsed();
                    if elapsed >= t {
                        return Err(HsaError::SignalTimeout(t));
                    }
                    let (g, _res) =
                        self.inner.cv.wait_timeout(guard, t - elapsed).unwrap();
                    guard = g;
                }
            }
        }
    }

    /// Wait for the signal to reach exactly `v`.
    pub fn wait_eq(&self, v: i64, timeout: Option<Duration>) -> Result<i64> {
        self.wait_until(timeout, |x| x == v)
    }

    /// Wait for the signal to drop below `v` (HSA's `HSA_SIGNAL_CONDITION_LT`).
    pub fn wait_lt(&self, v: i64, timeout: Option<Duration>) -> Result<i64> {
        self.wait_until(timeout, |x| x < v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn store_load() {
        let s = Signal::new(5);
        assert_eq!(s.load(), 5);
        s.store(-3);
        assert_eq!(s.load(), -3);
    }

    #[test]
    fn add_subtract() {
        let s = Signal::new(1);
        assert_eq!(s.add(4), 5);
        assert_eq!(s.subtract(5), 0);
    }

    #[test]
    fn wait_eq_immediate() {
        let s = Signal::new(0);
        assert_eq!(s.wait_eq(0, Some(Duration::from_millis(10))).unwrap(), 0);
    }

    #[test]
    fn wait_times_out() {
        let s = Signal::new(1);
        let err = s.wait_eq(0, Some(Duration::from_millis(20))).unwrap_err();
        assert!(matches!(err, HsaError::SignalTimeout(_)));
    }

    #[test]
    fn wait_wakes_on_decrement_from_other_thread() {
        let s = Signal::new(1);
        let s2 = s.clone();
        let h = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            s2.subtract(1);
        });
        assert_eq!(s.wait_eq(0, Some(Duration::from_secs(5))).unwrap(), 0);
        h.join().unwrap();
    }

    #[test]
    fn wait_past_spin_budget_still_wakes() {
        // Sleep phase (not spin) must catch the update: delay > budget.
        let s = Signal::new(1);
        let s2 = s.clone();
        let h = thread::spawn(move || {
            thread::sleep(Duration::from_millis(50));
            s2.store(0);
        });
        assert_eq!(s.wait_eq(0, Some(Duration::from_secs(5))).unwrap(), 0);
        h.join().unwrap();
    }

    #[test]
    fn wait_lt_condition() {
        let s = Signal::new(3);
        let s2 = s.clone();
        let h = thread::spawn(move || {
            for _ in 0..3 {
                thread::sleep(Duration::from_millis(5));
                s2.subtract(1);
            }
        });
        assert!(s.wait_lt(1, Some(Duration::from_secs(5))).unwrap() < 1);
        h.join().unwrap();
    }

    #[test]
    fn clones_share_state() {
        let a = Signal::new(0);
        let b = a.clone();
        a.store(9);
        assert_eq!(b.load(), 9);
    }

    #[test]
    fn many_waiters_all_wake() {
        let s = Signal::new(1);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let s = s.clone();
                thread::spawn(move || s.wait_eq(0, Some(Duration::from_secs(5))).is_ok())
            })
            .collect();
        thread::sleep(Duration::from_millis(30));
        s.store(0);
        for h in handles {
            assert!(h.join().unwrap());
        }
    }
}
