//! The HSA agent abstraction: anything that consumes kernel-dispatch
//! packets (CPU cores, the FPGA's PR-region fabric, GPUs...).

use crate::hsa::error::Result;
use crate::hsa::packet::KernelDispatchPacket;
use std::fmt;

/// Device classes the runtime can discover (paper Fig. 1: CPU, GPU, FPGA,
/// DSP all behind the same runtime).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceType {
    Cpu,
    Fpga,
    Gpu,
    Dsp,
}

impl fmt::Display for DeviceType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Static agent properties (subset of `hsa_agent_get_info`).
#[derive(Debug, Clone)]
pub struct AgentInfo {
    pub name: String,
    pub vendor: String,
    pub device_type: DeviceType,
    /// Maximum AQL queue size in packets.
    pub queue_max_size: usize,
    /// ISA string, e.g. "armv8-a53" or "zu3eg-pr".
    pub isa: String,
    /// Peak clock in MHz (used by the timing models).
    pub clock_mhz: u32,
    /// Number of compute units (CPU cores / PR regions).
    pub compute_units: u32,
}

/// An agent executes kernel-dispatch packets. Implementations:
/// [`crate::cpu::CpuAgent`], [`crate::fpga::FpgaAgent`].
///
/// The trait stays deliberately minimal — device-specific capability
/// probes live on the concrete types. In particular the FPGA's
/// reconfiguration-cost probes (`FpgaAgent::reconfig_cost`,
/// `FpgaAgent::icap_busy`, `FpgaAgent::try_prefetch`) are not part of the
/// HSA surface: the shard router holds `Arc<FpgaAgent>` directly and
/// queries them when picking a dispatch target, while generic HSA callers
/// see only dispatch execution and virtual time.
pub trait Agent: Send + Sync {
    fn info(&self) -> &AgentInfo;

    /// Execute one kernel dispatch synchronously (the packet processor
    /// thread calls this; concurrency across agents comes from each agent
    /// having its own queue + processor thread).
    fn execute(&self, packet: &KernelDispatchPacket) -> Result<()>;

    /// Virtual nanoseconds this agent's device clock has advanced (timing
    /// model output; wall-clock-independent).
    fn virtual_time_ns(&self) -> u128 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_type_display() {
        assert_eq!(DeviceType::Fpga.to_string(), "Fpga");
        assert_eq!(DeviceType::Cpu.to_string(), "Cpu");
    }

    #[test]
    fn device_type_ordering_stable() {
        assert!(DeviceType::Cpu < DeviceType::Fpga);
    }
}
