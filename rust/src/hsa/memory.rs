//! HSA memory regions and a tracking allocator.
//!
//! Tensors live in ordinary Rust `Vec`s; what this module models is the
//! *accounting* the HSA runtime performs — region discovery
//! (`hsa_agent_iterate_regions`) and allocation limits — so the coordinator
//! can enforce device memory budgets (the Ultra96 shares 2 GiB LPDDR4
//! between the A53s and the PL) and the tests can assert no leaks.

use crate::hsa::error::{HsaError, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// HSA memory segment kinds (PPS §2.8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Segment {
    /// System-visible global memory.
    Global,
    /// Kernel argument segment.
    KernArg,
    /// Group (scratch/local) memory — the FPGA's BRAM-backed buffers.
    Group,
}

/// A discoverable memory region.
#[derive(Debug, Clone)]
pub struct RegionInfo {
    pub name: String,
    pub segment: Segment,
    pub size_bytes: u64,
    /// Smallest allocation granule.
    pub granule: u64,
}

/// Handle to an allocation (freeing is explicit; `Drop` is intentionally
/// not used so tests can detect leaks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AllocId(u64);

#[derive(Debug)]
struct PoolState {
    live: BTreeMap<u64, u64>, // id -> size
    used: u64,
    peak: u64,
}

/// A tracking allocator over one region.
#[derive(Debug, Clone)]
pub struct MemoryPool {
    info: RegionInfo,
    state: Arc<Mutex<PoolState>>,
    next_id: Arc<AtomicU64>,
}

impl MemoryPool {
    pub fn new(info: RegionInfo) -> MemoryPool {
        MemoryPool {
            info,
            state: Arc::new(Mutex::new(PoolState {
                live: BTreeMap::new(),
                used: 0,
                peak: 0,
            })),
            next_id: Arc::new(AtomicU64::new(1)),
        }
    }

    pub fn info(&self) -> &RegionInfo {
        &self.info
    }

    /// Allocate `size` bytes (rounded up to the granule).
    pub fn alloc(&self, size: u64) -> Result<AllocId> {
        let granule = self.info.granule.max(1);
        let rounded = size.div_ceil(granule) * granule;
        let mut st = self.state.lock().unwrap();
        if st.used + rounded > self.info.size_bytes {
            return Err(HsaError::Memory(format!(
                "region '{}' exhausted: used {} + req {} > {}",
                self.info.name, st.used, rounded, self.info.size_bytes
            )));
        }
        let id = AllocId(self.next_id.fetch_add(1, Ordering::Relaxed));
        st.used += rounded;
        st.peak = st.peak.max(st.used);
        st.live.insert(id.0, rounded);
        Ok(id)
    }

    pub fn free(&self, id: AllocId) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        match st.live.remove(&id.0) {
            Some(sz) => {
                st.used -= sz;
                Ok(())
            }
            None => Err(HsaError::Memory(format!("double free / unknown alloc {id:?}"))),
        }
    }

    pub fn used_bytes(&self) -> u64 {
        self.state.lock().unwrap().used
    }

    pub fn peak_bytes(&self) -> u64 {
        self.state.lock().unwrap().peak
    }

    pub fn live_allocations(&self) -> usize {
        self.state.lock().unwrap().live.len()
    }
}

/// Standard regions for the simulated Ultra96 (2 GiB LPDDR4 shared; 512 KiB
/// of role-local BRAM treated as group memory; a small kernarg segment).
pub fn ultra96_regions() -> Vec<MemoryPool> {
    vec![
        MemoryPool::new(RegionInfo {
            name: "lpddr4-global".into(),
            segment: Segment::Global,
            size_bytes: 2 << 30,
            granule: 4096,
        }),
        MemoryPool::new(RegionInfo {
            name: "kernarg".into(),
            segment: Segment::KernArg,
            size_bytes: 16 << 20,
            granule: 64,
        }),
        MemoryPool::new(RegionInfo {
            name: "pl-bram-group".into(),
            segment: Segment::Group,
            size_bytes: 512 << 10,
            granule: 32,
        }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(size: u64, granule: u64) -> MemoryPool {
        MemoryPool::new(RegionInfo {
            name: "t".into(),
            segment: Segment::Global,
            size_bytes: size,
            granule,
        })
    }

    #[test]
    fn alloc_free_cycle() {
        let p = pool(1024, 1);
        let a = p.alloc(100).unwrap();
        assert_eq!(p.used_bytes(), 100);
        p.free(a).unwrap();
        assert_eq!(p.used_bytes(), 0);
        assert_eq!(p.peak_bytes(), 100);
    }

    #[test]
    fn granule_rounding() {
        let p = pool(1024, 64);
        let _ = p.alloc(1).unwrap();
        assert_eq!(p.used_bytes(), 64);
    }

    #[test]
    fn exhaustion_fails_cleanly() {
        let p = pool(128, 1);
        let _a = p.alloc(100).unwrap();
        assert!(p.alloc(29).is_err());
        assert_eq!(p.used_bytes(), 100, "failed alloc must not leak");
    }

    #[test]
    fn double_free_rejected() {
        let p = pool(128, 1);
        let a = p.alloc(8).unwrap();
        p.free(a).unwrap();
        assert!(p.free(a).is_err());
    }

    #[test]
    fn peak_tracks_high_water() {
        let p = pool(1000, 1);
        let a = p.alloc(600).unwrap();
        p.free(a).unwrap();
        let _b = p.alloc(100).unwrap();
        assert_eq!(p.peak_bytes(), 600);
        assert_eq!(p.used_bytes(), 100);
    }

    #[test]
    fn ultra96_regions_all_segments() {
        let pools = ultra96_regions();
        let segs: Vec<Segment> = pools.iter().map(|p| p.info().segment).collect();
        assert!(segs.contains(&Segment::Global));
        assert!(segs.contains(&Segment::KernArg));
        assert!(segs.contains(&Segment::Group));
    }
}
