//! Benchmark support: timing harness + the paper's table generators
//! (shared by `rust/benches/*`, the CLI and the integration tests).

pub mod artifact;
pub mod harness;
pub mod tables;

pub use artifact::{compare_to_baseline, write_and_check, BenchArtifact};
pub use harness::{time_n, BenchResult};
pub use tables::{table1, table2, table3, Table2Measurement, Table3Row};
