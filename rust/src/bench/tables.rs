//! Generators for the paper's Tables I–III.
//!
//! Each function *runs the stack* (not just the models) and renders a table
//! in the paper's format, returning the raw numbers for assertions.

use crate::bench::harness::time_n;
use crate::fpga::resources::{ResourceVector, ZU3EG};
use crate::fpga::roles;
use crate::fpga::synthesis::estimate;
use crate::hsa::agent::{Agent, DeviceType};
use crate::metrics::report::Table;
use crate::tf::dtype::DType;
use crate::tf::graph::{Graph, OpKind};
use crate::tf::session::{Session, SessionOptions};
use crate::tf::tensor::Tensor;
use crate::util::prng::Rng;

// ---------------------------------------------------------------------------
// Table I — utilization of the programmable logic
// ---------------------------------------------------------------------------

/// Rows: (label, resources, estimated?).
pub fn table1_rows() -> Vec<(&'static str, ResourceVector, bool)> {
    vec![
        ("Shell", roles::shell_resources(), false),
        ("Role 1", estimate(&roles::role1_components()), true),
        ("Role 2", estimate(&roles::role2_components()), false),
        ("Role 3", estimate(&roles::role3_components()), false),
        ("Role 4", estimate(&roles::role4_components()), false),
    ]
}

pub fn table1() -> Table {
    let mut t = Table::new(
        "TABLE I: Utilization of the Programmable Logic (ZU3EG)",
        &["Kernel", "LUTs", "FFs", "BRAM", "DSPs"],
    );
    for (label, r, est) in table1_rows() {
        let u = r.utilization_pct(&ZU3EG);
        let cell = |v: u32, p: f64| format!("{v} ({p:.1}%)");
        let mut row = vec![
            label.to_string(),
            cell(r.luts, u[0]),
            cell(r.ffs, u[1]),
            cell(r.bram36, u[2]),
            cell(r.dsps, u[3]),
        ];
        if est {
            row[0] = format!("{label} *");
        }
        t.row(&row);
    }
    t.footnote("Role 1: only the LUT column survived in the published table; other columns estimated from the role-2 structure (see DESIGN.md §6).");
    t.footnote("paper: Shell 9915/8544/10/0, Role1 9984 LUT, Role2 9501/7851/23/8, Role3 5091/4935/21/6, Role4 7881/7926/21/12");
    t
}

// ---------------------------------------------------------------------------
// Table II — overhead of FPGA TensorFlow [µs]
// ---------------------------------------------------------------------------

/// Raw measurements behind Table II.
#[derive(Debug, Clone, Copy)]
pub struct Table2Measurement {
    pub tf_setup_us: f64,
    pub hsa_setup_us: f64,
    /// Modeled PCAP reconfiguration (paper: 7424 µs). TF column is 0: the
    /// TF layer adds nothing on top of the runtime-managed reconfiguration.
    pub reconfig_us: f64,
    pub tf_dispatch_us: f64,
    pub hsa_dispatch_us: f64,
}

/// Measure the stack. `n` = iterations for the dispatch rows (paper: 1000).
/// `use_pjrt` controls whether setup includes PJRT client + artifact
/// compilation (it does in the shipped config when artifacts exist).
pub fn table2_measure(n: usize, use_pjrt: bool) -> Table2Measurement {
    // --- setup costs (averaged over a few bring-ups) ---
    let reps = 3;
    let mut tf_setup = 0.0;
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let sess = Session::new(
            dispatch_graph(),
            SessionOptions { use_pjrt, ..SessionOptions::default() },
        )
        .expect("session");
        tf_setup += t0.elapsed().as_secs_f64() * 1e6;
        sess.shutdown();
    }
    let tf_setup_us = tf_setup / reps as f64;

    // HSA-only bring-up: the same compute backend (agents, runtime,
    // queues, role registration, and — when enabled — the PJRT service
    // with artifact compilation), but no TF frontend (no graph, registry,
    // placer, session). The TF−HSA delta is therefore the frontend cost,
    // the paper's Table II comparison.
    let mut hsa_setup = 0.0;
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let mut pjrt = None;
        if use_pjrt {
            if let Ok(store) = crate::runtime::artifact::ArtifactStore::open_default() {
                if let Ok(svc) = crate::runtime::pjrt::PjrtService::start() {
                    for name in
                        ["role1_fc", "role2_fc_barrier", "role3_conv5x5", "role4_conv3x3"]
                    {
                        if let Ok(meta) = store.module(name) {
                            let _ = svc.handle().load_module(meta);
                        }
                    }
                    pjrt = Some(svc);
                }
            }
        }
        let cpu = crate::cpu::device::CpuAgent::with_defaults();
        let fpga = crate::fpga::device::FpgaAgent::with_defaults();
        for b in roles::paper_roles() {
            fpga.register_role(
                b,
                crate::fpga::device::ComputeBinding::Native(std::sync::Arc::new(
                    |ins: &[Tensor]| Ok(ins.to_vec()),
                )),
            );
        }
        let rt = crate::hsa::runtime::HsaRuntime::builder()
            .with_agent(cpu)
            .with_agent(fpga)
            .build();
        let _q1 = rt.create_queue(rt.agent_by_type(DeviceType::Cpu).unwrap(), 256);
        let _q2 = rt.create_queue(rt.agent_by_type(DeviceType::Fpga).unwrap(), 256);
        hsa_setup += t0.elapsed().as_secs_f64() * 1e6;
        rt.shutdown();
        drop(pjrt);
    }
    let hsa_setup_us = hsa_setup / reps as f64;

    // --- reconfiguration (modeled PCAP time for one role bitstream) ---
    let reconfig_us =
        crate::fpga::icap::Icap::default().reconfig_time_us(roles::ROLE_BITSTREAM_BYTES) as f64;

    // --- dispatch latency (warm role; n iterations) ---
    let sess = Session::new(
        dispatch_graph(),
        SessionOptions { use_pjrt: false, ..SessionOptions::default() },
    )
    .expect("session");
    let x = Tensor::from_f32(&[4, 4], vec![1.0; 16]).unwrap();
    let w = Tensor::from_f32(&[4, 4], vec![0.5; 16]).unwrap();
    let b = Tensor::from_f32(&[4], vec![0.0; 4]).unwrap();

    // Warm both paths (role residency + caches) before timing either.
    let feeds = [("x", x.clone())];
    for _ in 0..50.min(n) {
        let _ = sess.run(&feeds, &["y"]).expect("run");
        let _ = sess
            .dispatch_raw(DeviceType::Fpga, "fc", vec![x.clone(), w.clone(), b.clone()])
            .expect("dispatch");
    }

    // TF path: session.run of a single-FC graph (placement + executor +
    // HSA dispatch).
    let tf = time_n("tf dispatch", 0, n, || {
        let _ = sess.run(&feeds, &["y"]).expect("run");
    });

    // Raw HSA path: direct queue dispatch of the same kernel.
    let hsa = time_n("hsa dispatch", 0, n, || {
        let _ = sess
            .dispatch_raw(DeviceType::Fpga, "fc", vec![x.clone(), w.clone(), b.clone()])
            .expect("dispatch");
    });
    sess.shutdown();

    // p50 is the robust per-dispatch cost on a shared host (the mean is
    // dominated by scheduler-preemption outliers).
    Table2Measurement {
        tf_setup_us,
        hsa_setup_us,
        reconfig_us,
        tf_dispatch_us: tf.us.p50,
        hsa_dispatch_us: hsa.us.p50,
    }
}

fn dispatch_graph() -> Graph {
    let mut g = Graph::new();
    let x = g.placeholder("x", &[4, 4], DType::F32).unwrap();
    let w = g.constant("w", Tensor::from_f32(&[4, 4], vec![0.5; 16]).unwrap()).unwrap();
    let b = g.constant("b", Tensor::from_f32(&[4], vec![0.0; 4]).unwrap()).unwrap();
    g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
    g
}

pub fn table2(n: usize, use_pjrt: bool) -> (Table, Table2Measurement) {
    let m = table2_measure(n, use_pjrt);
    let mut t = Table::new(
        format!("TABLE II: Overhead of FPGA TensorFlow [µs] (n={n})"),
        &["Operation", "Occurrence", "TensorFlow", "HSA Runtime"],
    );
    t.row(&[
        "device/kernel setup".into(),
        "once".into(),
        format!("{:.0}", m.tf_setup_us),
        format!("{:.0}", m.hsa_setup_us),
    ]);
    t.row(&[
        "reconfiguration".into(),
        "if not configured".into(),
        "0".into(),
        format!("{:.0}", m.reconfig_us),
    ]);
    t.row(&[
        "dispatch latency".into(),
        "every dispatch".into(),
        format!("{:.0}", m.tf_dispatch_us),
        format!("{:.0}", m.hsa_dispatch_us),
    ]);
    t.footnote("paper (Ultra96/A53): setup 156230 / 39032, reconfiguration 0 / 7424, dispatch 27 / 10");
    t.footnote("reconfiguration is the modeled PCAP transfer (bitstream bytes / bandwidth); setup+dispatch are measured on this host");
    t
    .footnote("shape preserved: setup >> reconfig >> dispatch; TF-path dispatch > HSA-path dispatch");
    (t.clone(), m)
}

// ---------------------------------------------------------------------------
// Table III — efficiency benefit compared to CPU (OP/cycle increase)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table3Row {
    pub role: &'static str,
    pub fpga_ops_per_cycle: f64,
    pub cpu_ops_per_cycle: f64,
    pub increase: f64,
    pub paper_increase: f64,
}

/// Run `n` dispatches of each role on the FPGA session and the CPU baseline
/// session, then compute OP/cycle from the *measured virtual clocks* of the
/// two agents (not just the closed-form models).
pub fn table3_measure(n: usize) -> Vec<Table3Row> {
    let paper = [6.51, 3.03, 18.62, 6.98];
    let mut rng = Rng::new(42);

    // role workloads (the paper's benchmark shapes)
    let fc_x = {
        let mut v = vec![0f32; 64 * 64];
        rng.fill_f32_normal(&mut v, 0.0, 1.0);
        Tensor::from_f32(&[64, 64], v).unwrap()
    };
    let fc_w = {
        let mut v = vec![0f32; 64 * 64];
        rng.fill_f32_normal(&mut v, 0.0, 0.1);
        Tensor::from_f32(&[64, 64], v).unwrap()
    };
    let fc_b = Tensor::from_f32(&[64], vec![0.1; 64]).unwrap();
    let conv_x = {
        let mut v = vec![0i16; 784];
        rng.fill_i16(&mut v, -256, 255);
        Tensor::from_i16(&[1, 28, 28], v).unwrap()
    };

    let kernels: [(&'static str, &str, Vec<Tensor>, u64); 4] = [
        ("Role 1", "fc", vec![fc_x.clone(), fc_w.clone(), fc_b.clone()], {
            let s = roles::role1_spec();
            s.op.ops()
        }),
        ("Role 2", "fc_barrier", vec![fc_x, fc_w, fc_b], roles::role2_spec().op.ops()),
        ("Role 3", "conv5x5_i16", vec![conv_x.clone()], roles::role3_spec().op.ops()),
        ("Role 4", "conv3x3_i16", vec![conv_x], roles::role4_spec().op.ops()),
    ];

    let mut rows = Vec::new();
    for (i, (role, kernel, inputs, ops)) in kernels.into_iter().enumerate() {
        // Fresh sessions per role so virtual clocks start at zero.
        let fpga_sess =
            Session::new(Graph::new(), SessionOptions::native_only()).expect("session");
        let cpu_sess =
            Session::new(Graph::new(), SessionOptions::cpu_baseline()).expect("session");

        for _ in 0..n {
            fpga_sess
                .dispatch_raw(DeviceType::Fpga, kernel, inputs.clone())
                .expect("fpga dispatch");
            cpu_sess
                .dispatch_raw(DeviceType::Cpu, kernel, inputs.clone())
                .expect("cpu dispatch");
        }

        // FPGA cycles: virtual time minus reconfiguration, at the PL clock.
        let fpga_ns = fpga_sess.fpga_agent().virtual_time_ns() as f64;
        let reconfig_ns = fpga_sess.reconfig_stats().reconfig_us_total as f64 * 1000.0;
        let fpga_cycles =
            (fpga_ns - reconfig_ns) * roles::PL_CLOCK_MHZ as f64 / 1000.0;
        // CPU cycles from the A53 model's virtual clock.
        let cpu_ns = cpu_sess.cpu_agent().virtual_time_ns() as f64;
        let cpu_mhz = cpu_sess.cpu_agent().model().clock_mhz as f64;
        let cpu_cycles = cpu_ns * cpu_mhz / 1000.0;

        let total_ops = (ops * n as u64) as f64;
        let fpga_opc = total_ops / fpga_cycles;
        let cpu_opc = total_ops / cpu_cycles;
        rows.push(Table3Row {
            role,
            fpga_ops_per_cycle: fpga_opc,
            cpu_ops_per_cycle: cpu_opc,
            increase: fpga_opc / cpu_opc,
            paper_increase: paper[i],
        });
        fpga_sess.shutdown();
        cpu_sess.shutdown();
    }
    rows
}

pub fn table3(n: usize) -> (Table, Vec<Table3Row>) {
    let rows = table3_measure(n);
    let mut t = Table::new(
        format!("TABLE III: Efficiency benefit compared to CPU (n={n})"),
        &["", "Role 1", "Role 2", "Role 3", "Role 4"],
    );
    let fmt_row = |label: &str, f: &dyn Fn(&Table3Row) -> String| {
        let mut cells = vec![label.to_string()];
        cells.extend(rows.iter().map(|r| f(r)));
        cells
    };
    t.row(&fmt_row("OP/cycle increase", &|r| format!("{:.2}x", r.increase)));
    t.row(&fmt_row("  FPGA OP/cycle", &|r| format!("{:.2}", r.fpga_ops_per_cycle)));
    t.row(&fmt_row("  A53 OP/cycle", &|r| format!("{:.2}", r.cpu_ops_per_cycle)));
    t.row(&fmt_row("  paper", &|r| format!("{:.2}x", r.paper_increase)));
    t.footnote("measured from agent virtual clocks over real dispatches (reconfiguration excluded)");
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_prints_all_rows() {
        let t = table1();
        let s = t.to_string();
        for label in ["Shell", "Role 1", "Role 2", "Role 3", "Role 4"] {
            assert!(s.contains(label), "{s}");
        }
        assert!(s.contains("9915 (14.1%)"), "{s}");
        assert!(s.contains("9501 (13.5%)"), "{s}");
    }

    #[test]
    fn table3_small_n_reproduces_ratios() {
        // n=3 keeps this test fast; ratios are deterministic (virtual time).
        let rows = table3_measure(3);
        let want = [6.51, 3.03, 18.62, 6.98];
        for (row, want) in rows.iter().zip(want) {
            let err = (row.increase - want).abs() / want;
            assert!(
                err < 0.03,
                "{}: {:.2}x vs paper {want}x",
                row.role,
                row.increase
            );
        }
    }

    #[test]
    fn table2_shape_holds_native() {
        // Small n; no PJRT so the test runs without artifacts.
        let m = table2_measure(20, false);
        assert!(m.tf_setup_us > m.hsa_setup_us, "TF setup adds frontend cost: {m:?}");
        assert!(m.reconfig_us > 7000.0 && m.reconfig_us < 8000.0);
        // The TF path does strictly more work per dispatch, but on x86 the
        // frontend adds only ~1 µs over the ~3 µs queue round-trip, so with
        // a small n under a parallel test run the p50s can cross from
        // scheduler noise. The real ordering is checked by the
        // table2_overhead bench (n=1000 on a quiet machine); here we only
        // guard against gross anomalies and regressions.
        assert!(
            m.tf_dispatch_us > 0.5 * m.hsa_dispatch_us,
            "TF dispatch anomalously cheap vs raw HSA: {m:?}"
        );
        assert!(
            m.tf_dispatch_us < 100.0 && m.hsa_dispatch_us < 100.0,
            "dispatch latency regressed: {m:?}"
        );
    }
}
