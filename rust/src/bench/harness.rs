//! Minimal timing harness (criterion is not in the offline vendor set; the
//! paper's tables are n-iteration means anyway, n=1000).

use crate::util::stats::Summary;
use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time summary in µs.
    pub us: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<32} n={:<5} mean={:>9.2}µs p50={:>9.2}µs p99={:>9.2}µs max={:>9.2}µs",
            self.name, self.us.n, self.us.mean, self.us.p50, self.us.p99, self.us.max
        )
    }
}

/// Time `f` for `n` iterations after `warmup` unmeasured ones.
pub fn time_n(name: &str, warmup: usize, n: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    BenchResult { name: name.to_string(), us: Summary::from_values(&samples) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_produces_n_samples() {
        let r = time_n("noop", 2, 25, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.us.n, 25);
        assert!(r.us.mean >= 0.0);
    }

    #[test]
    fn sleep_is_measured() {
        let r = time_n("sleep", 0, 3, || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        assert!(r.us.mean >= 1900.0, "mean {}", r.us.mean);
    }

    #[test]
    fn report_contains_name() {
        let r = time_n("abc", 0, 1, || {});
        assert!(r.report().contains("abc"));
    }
}
