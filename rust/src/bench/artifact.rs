//! Machine-readable benchmark artifacts (`BENCH_<area>.json`).
//!
//! Bench runners assemble a [`BenchArtifact`] — a nested JSON document of
//! headline numbers (req/s, p50/p99 latency, batch fill, scaling ratios)
//! — and write it next to the bench (or into `$BENCH_OUT_DIR`), so CI can
//! upload the file and trend dashboards can diff runs without scraping
//! stdout tables.
//!
//! The companion [`compare_to_baseline`] implements `--check` mode: walk
//! the current document against a committed baseline and flag any metric
//! that regressed beyond a tolerance. Two conventions keep the comparison
//! self-describing:
//!
//! * keys ending in `_us` are latencies — **lower** is better; every
//!   other numeric key is a rate/ratio — **higher** is better;
//! * a baseline of `null` means "machine-dependent, do not gate" (the
//!   committed baselines null out absolute throughput and keep only
//!   scaling ratios, which are hardware-independent floors).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// One bench run's worth of headline metrics, keyed by dotted paths.
pub struct BenchArtifact {
    area: String,
    root: Json,
}

impl BenchArtifact {
    /// `area` names the file: `BENCH_<area>.json`.
    pub fn new(area: &str) -> BenchArtifact {
        BenchArtifact { area: area.to_string(), root: Json::Obj(BTreeMap::new()) }
    }

    /// Set a metric at a dotted path (`"serving.batch_8.req_s"`),
    /// creating intermediate objects as needed. Overwrites on repeat.
    pub fn set(&mut self, path: &str, value: Json) {
        let mut node = &mut self.root;
        let parts: Vec<&str> = path.split('.').collect();
        for (i, part) in parts.iter().enumerate() {
            let map = match node {
                Json::Obj(m) => m,
                other => {
                    // A scalar was set where an object now needs to live:
                    // replace it (last write wins, like the leaves).
                    *other = Json::Obj(BTreeMap::new());
                    match other {
                        Json::Obj(m) => m,
                        _ => unreachable!(),
                    }
                }
            };
            if i == parts.len() - 1 {
                map.insert(part.to_string(), value);
                return;
            }
            node = map
                .entry(part.to_string())
                .or_insert_with(|| Json::Obj(BTreeMap::new()));
        }
    }

    pub fn set_f64(&mut self, path: &str, v: f64) {
        self.set(path, Json::Num(v));
    }

    pub fn set_u64(&mut self, path: &str, v: u64) {
        self.set(path, Json::Num(v as f64));
    }

    /// The assembled document.
    pub fn json(&self) -> &Json {
        &self.root
    }

    /// Destination path: `$BENCH_OUT_DIR/BENCH_<area>.json` (the current
    /// directory when the variable is unset — for `cargo bench` that is
    /// the crate root, which is what CI uploads).
    pub fn path(&self) -> PathBuf {
        let dir = std::env::var_os("BENCH_OUT_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        dir.join(format!("BENCH_{}.json", self.area))
    }

    /// Write the artifact (pretty-printed, trailing newline) and return
    /// where it landed.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = self.path();
        std::fs::write(&path, format!("{}\n", self.root.pretty()))?;
        Ok(path)
    }
}

/// Walk `current` against `baseline` and report every metric that
/// regressed beyond `tolerance` (0.2 = 20%). Keys ending `_us` must not
/// rise above `baseline * (1 + tolerance)`; all other numeric keys must
/// not fall below `baseline * (1 - tolerance)`. Baseline `null` leaves
/// and keys missing from the baseline are not gated; keys present in the
/// baseline but missing from `current` are reported (a bench silently
/// dropping a metric should fail `--check`, not pass it).
pub fn compare_to_baseline(current: &Json, baseline: &Json, tolerance: f64) -> Vec<String> {
    let mut regressions = Vec::new();
    walk("", current, baseline, tolerance, &mut regressions);
    regressions
}

fn walk(path: &str, current: &Json, baseline: &Json, tol: f64, out: &mut Vec<String>) {
    match baseline {
        Json::Null => {}
        Json::Obj(bm) => {
            for (key, bv) in bm {
                let child_path = if path.is_empty() {
                    key.clone()
                } else {
                    format!("{path}.{key}")
                };
                match current.as_obj().and_then(|cm| cm.get(key)) {
                    Some(cv) => walk(&child_path, cv, bv, tol, out),
                    None => {
                        if *bv != Json::Null {
                            out.push(format!("{child_path}: missing from current run"));
                        }
                    }
                }
            }
        }
        Json::Num(b) => {
            let Some(c) = current.as_f64() else {
                out.push(format!("{path}: expected a number, got {current}"));
                return;
            };
            let key = path.rsplit('.').next().unwrap_or(path);
            if key.ends_with("_us") {
                let limit = b * (1.0 + tol);
                if c > limit {
                    out.push(format!(
                        "{path}: {c} exceeds baseline {b} by more than {:.0}%",
                        tol * 100.0
                    ));
                }
            } else {
                let floor = b * (1.0 - tol);
                if c < floor {
                    out.push(format!(
                        "{path}: {c} below baseline {b} by more than {:.0}%",
                        tol * 100.0
                    ));
                }
            }
        }
        // Booleans/strings in a baseline are informational, not gated.
        _ => {}
    }
}

/// Shared `--check` driver for bench mains: write the artifact, then — if
/// `--check` was passed on the command line — compare against the
/// committed baseline text and return the regression list for the caller
/// to report and exit nonzero on.
pub fn write_and_check(
    artifact: &BenchArtifact,
    baseline_text: &str,
) -> std::io::Result<Vec<String>> {
    let path = artifact.write()?;
    println!("bench artifact: {}", path.display());
    if !std::env::args().any(|a| a == "--check") {
        return Ok(Vec::new());
    }
    let baseline = Json::parse(baseline_text).map_err(|e| {
        std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("baseline is not valid JSON: {e}"),
        )
    })?;
    Ok(compare_to_baseline(artifact.json(), &baseline, 0.2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dotted_set_builds_nested_objects() {
        let mut a = BenchArtifact::new("test");
        a.set_f64("serving.batch_8.req_s", 1234.5);
        a.set_u64("serving.batch_8.p99_us", 900);
        a.set_f64("speedup.batch_8", 3.1);
        let j = a.json();
        assert_eq!(j.get("serving").get("batch_8").get("req_s").as_f64(), Some(1234.5));
        assert_eq!(j.get("serving").get("batch_8").get("p99_us").as_usize(), Some(900));
        // Round-trips through the writer.
        let back = Json::parse(&j.pretty()).unwrap();
        assert_eq!(&back, j);
    }

    #[test]
    fn latency_keys_gate_upward_and_rates_gate_downward() {
        let baseline =
            Json::parse(r#"{"a":{"p99_us":100,"req_s":1000}}"#).unwrap();
        // Within tolerance both directions: no regressions.
        let ok = Json::parse(r#"{"a":{"p99_us":115,"req_s":850}}"#).unwrap();
        assert!(compare_to_baseline(&ok, &baseline, 0.2).is_empty());
        // Latency 21% up and throughput 21% down both flag.
        let bad = Json::parse(r#"{"a":{"p99_us":121,"req_s":790}}"#).unwrap();
        let regs = compare_to_baseline(&bad, &baseline, 0.2);
        assert_eq!(regs.len(), 2, "{regs:?}");
        assert!(regs.iter().any(|r| r.contains("a.p99_us")), "{regs:?}");
        assert!(regs.iter().any(|r| r.contains("a.req_s")), "{regs:?}");
        // Faster latency and higher throughput never flag.
        let better = Json::parse(r#"{"a":{"p99_us":10,"req_s":9000}}"#).unwrap();
        assert!(compare_to_baseline(&better, &baseline, 0.2).is_empty());
    }

    #[test]
    fn null_baselines_are_not_gated_but_missing_metrics_are() {
        let baseline =
            Json::parse(r#"{"a":{"req_s":null,"fill":8},"b":null}"#).unwrap();
        // req_s wildly low and "b" absent: both fine (nulled out).
        let run = Json::parse(r#"{"a":{"req_s":1,"fill":8}}"#).unwrap();
        assert!(compare_to_baseline(&run, &baseline, 0.2).is_empty());
        // But a gated key vanishing from the run is a failure.
        let dropped = Json::parse(r#"{"a":{"req_s":1}}"#).unwrap();
        let regs = compare_to_baseline(&dropped, &baseline, 0.2);
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("a.fill") && regs[0].contains("missing"), "{regs:?}");
    }
}
