//! CPU substrate: the ARM Cortex-A53 baseline of Table III.
//!
//! [`a53`] is the cycle model (how many cycles the A53 needs for a given
//! kernel workload); [`device`] is the HSA agent executing kernels natively
//! (real numerics) while charging virtual time from the model.

pub mod a53;
pub mod device;

pub use a53::{A53Model, CpuKernelClass};
pub use device::{CpuAgent, CpuKernel};
