//! ARM Cortex-A53 timing model — Table III's denominator.
//!
//! The A53 is an in-order, dual-issue core with a 64-bit NEON datapath.
//! Peak arithmetic rates per cycle (one NEON pipe, armv8-a):
//!
//! * f32 FMA: one 2-lane `fmla.2s` per cycle → 4 FLOPs/cycle peak;
//! * int16 MAC: one 4-lane widening `smlal` per cycle → 8 OPs/cycle peak.
//!
//! Real kernels achieve a fraction of peak. The efficiency factors below
//! are *calibrated* so the model reproduces the paper's measured Table III
//! ratios on the role workloads (the paper gives no baseline source code,
//! so these stand in for its "plain ARM Cortex A53 implementation"; see
//! DESIGN.md §6 for the derivation of each number):
//!
//! * dense f32 GEMM: 30.7 % of peak (1.228 OP/cycle) — compiler-scheduled
//!   scalar-ish FMA with NEON autovectorization hampered by the K-loop
//!   reduction;
//! * 5×5 int16 conv: 31.9 % of peak (2.549 OP/cycle) — 25-tap register
//!   pressure forces spills;
//! * 3×3 int16 conv: 64.6 % of peak (5.171 OP/cycle) — 9 taps fit the
//!   register file, good NEON utilization;
//! * streaming ops: 25 % of peak.

use crate::fpga::datapath::RoleOp;

/// Kernel classes the model distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuKernelClass {
    FcF32,
    /// int16 conv with few taps (<= 9): register-resident.
    ConvI16Small,
    /// int16 conv with many taps: spilling.
    ConvI16Large,
    Stream,
    /// Non-arithmetic ops (relu, pool, reshape): charged per element.
    Memory,
}

impl CpuKernelClass {
    pub fn for_role_op(op: &RoleOp) -> CpuKernelClass {
        match op {
            RoleOp::FcF32 { .. } => CpuKernelClass::FcF32,
            RoleOp::ConvI16 { kh, kw, .. } => {
                if kh * kw <= 9 {
                    CpuKernelClass::ConvI16Small
                } else {
                    CpuKernelClass::ConvI16Large
                }
            }
            RoleOp::Stream { .. } => CpuKernelClass::Stream,
        }
    }
}

/// The timing model.
#[derive(Debug, Clone)]
pub struct A53Model {
    pub clock_mhz: u32,
    /// Fixed per-kernel-call overhead (function setup, cache warmup).
    pub call_overhead_cycles: u64,
}

impl Default for A53Model {
    fn default() -> Self {
        // Ultra96 A53 cluster runs at 1.2 GHz (bounded to 1.0 under Linux
        // cpufreq defaults; we model the nominal 1200 MHz).
        A53Model { clock_mhz: 1200, call_overhead_cycles: 320 }
    }
}

impl A53Model {
    /// Peak arithmetic OPs per cycle for a kernel class.
    pub fn peak_ops_per_cycle(&self, class: CpuKernelClass) -> f64 {
        match class {
            CpuKernelClass::FcF32 => 4.0,
            CpuKernelClass::ConvI16Small | CpuKernelClass::ConvI16Large => 8.0,
            CpuKernelClass::Stream => 4.0,
            CpuKernelClass::Memory => 2.0,
        }
    }

    /// Calibrated achieved efficiency (fraction of peak).
    pub fn efficiency(&self, class: CpuKernelClass) -> f64 {
        match class {
            CpuKernelClass::FcF32 => 0.30699,
            CpuKernelClass::ConvI16Large => 0.31861,
            CpuKernelClass::ConvI16Small => 0.64641,
            CpuKernelClass::Stream => 0.25,
            CpuKernelClass::Memory => 0.50,
        }
    }

    /// Achieved OPs per cycle.
    pub fn ops_per_cycle(&self, class: CpuKernelClass) -> f64 {
        self.peak_ops_per_cycle(class) * self.efficiency(class)
    }

    /// Cycles to execute `ops` arithmetic operations of `class`.
    pub fn cycles_for_ops(&self, class: CpuKernelClass, ops: u64) -> u64 {
        let rate = self.ops_per_cycle(class);
        self.call_overhead_cycles + (ops as f64 / rate).ceil() as u64
    }

    /// Cycles for a role workload.
    pub fn cycles_for_role_op(&self, op: &RoleOp) -> u64 {
        self.cycles_for_ops(CpuKernelClass::for_role_op(op), op.ops())
    }

    /// Nanoseconds for a role workload at the modeled clock.
    pub fn exec_ns(&self, op: &RoleOp) -> u64 {
        self.cycles_for_role_op(op) * 1000 / self.clock_mhz as u64
    }

    /// Achieved OP/cycle on a workload including call overhead — the
    /// number Table III divides into the FPGA rate.
    pub fn achieved_ops_per_cycle(&self, op: &RoleOp) -> f64 {
        op.ops() as f64 / self.cycles_for_role_op(op) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::roles;

    #[test]
    fn class_selection() {
        assert_eq!(
            CpuKernelClass::for_role_op(&RoleOp::FcF32 { m: 1, k: 1, n: 1 }),
            CpuKernelClass::FcF32
        );
        assert_eq!(
            CpuKernelClass::for_role_op(&RoleOp::ConvI16 {
                cin: 1, h: 9, w: 9, kh: 3, kw: 3, filters: 2
            }),
            CpuKernelClass::ConvI16Small
        );
        assert_eq!(
            CpuKernelClass::for_role_op(&RoleOp::ConvI16 {
                cin: 1, h: 9, w: 9, kh: 5, kw: 5, filters: 1
            }),
            CpuKernelClass::ConvI16Large
        );
    }

    #[test]
    fn cycles_scale_with_ops() {
        let m = A53Model::default();
        let small = m.cycles_for_ops(CpuKernelClass::FcF32, 1_000);
        let large = m.cycles_for_ops(CpuKernelClass::FcF32, 1_000_000);
        assert!(large > small * 100);
    }

    /// The headline check: FPGA-role OP/cycle over A53 OP/cycle reproduces
    /// Table III — 6.51x / 3.03x / 18.62x / 6.98x (±2 %).
    #[test]
    fn table3_ratios_reproduce() {
        let cpu = A53Model::default();
        let expected = [
            (roles::role1_spec(), 6.51),
            (roles::role2_spec(), 3.03),
            (roles::role3_spec(), 18.62),
            (roles::role4_spec(), 6.98),
        ];
        for (spec, want) in expected {
            let fpga_opc = spec.ops_per_cycle(&spec.op);
            let cpu_opc = cpu.achieved_ops_per_cycle(&spec.op);
            let ratio = fpga_opc / cpu_opc;
            let err = (ratio - want).abs() / want;
            assert!(
                err < 0.02,
                "{}: ratio {ratio:.2} vs paper {want} ({:.1}% off)",
                spec.name,
                err * 100.0
            );
        }
    }

    #[test]
    fn exec_ns_positive_and_scales_with_clock() {
        let mut m = A53Model::default();
        let op = RoleOp::FcF32 { m: 64, k: 64, n: 64 };
        let t = m.exec_ns(&op);
        assert!(t > 0);
        m.clock_mhz *= 2;
        assert!(m.exec_ns(&op) < t);
    }
}
