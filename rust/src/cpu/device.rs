//! The CPU HSA agent: executes registered kernels natively (real numerics)
//! and charges virtual time from the A53 model (Table III's baseline).

use crate::cpu::a53::{A53Model, CpuKernelClass};
use crate::fpga::datapath::RoleOp;
use crate::hsa::agent::{Agent, AgentInfo, DeviceType};
use crate::hsa::error::{HsaError, Result};
use crate::hsa::packet::KernelDispatchPacket;
use crate::tf::tensor::Tensor;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// A kernel registered on the CPU agent.
#[derive(Clone)]
pub struct CpuKernel {
    pub name: String,
    pub func: Arc<dyn Fn(&[Tensor]) -> Result<Vec<Tensor>> + Send + Sync>,
    /// Timing class for the A53 model.
    pub class: CpuKernelClass,
    /// Workload template: rescaled by the actual input shape at dispatch to
    /// derive the op count. `None` charges per element moved.
    pub op_template: Option<RoleOp>,
}

/// The A53-modeled CPU agent.
pub struct CpuAgent {
    info: AgentInfo,
    model: A53Model,
    kernels: RwLock<HashMap<u64, CpuKernel>>,
    next_id: AtomicU64,
    virtual_ns: AtomicU64,
    dispatches: AtomicU64,
}

impl CpuAgent {
    pub fn new(model: A53Model) -> Arc<CpuAgent> {
        Arc::new(CpuAgent {
            info: AgentInfo {
                name: "cortex-a53".into(),
                vendor: "arm (modeled)".into(),
                device_type: DeviceType::Cpu,
                queue_max_size: 4096,
                isa: "armv8-a+neon".into(),
                clock_mhz: model.clock_mhz,
                compute_units: 4,
            },
            model,
            kernels: RwLock::new(HashMap::new()),
            next_id: AtomicU64::new(0x1000_0000),
            virtual_ns: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
        })
    }

    pub fn with_defaults() -> Arc<CpuAgent> {
        CpuAgent::new(A53Model::default())
    }

    /// Register a kernel; returns its kernel-object handle.
    pub fn register_kernel(&self, kernel: CpuKernel) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.kernels.write().unwrap().insert(id, kernel);
        id
    }

    pub fn model(&self) -> &A53Model {
        &self.model
    }

    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Modeled cycles the agent has spent (virtual_ns * clock).
    pub fn virtual_cycles(&self) -> u64 {
        self.virtual_ns.load(Ordering::Relaxed) * self.info.clock_mhz as u64 / 1000
    }

    fn charge(&self, kernel: &CpuKernel, inputs: &[Tensor], outputs: &[Tensor]) {
        let ns = match kernel.op_template.as_ref().and_then(|t| t.with_input_shape(inputs))
        {
            Some(op) => self.model.exec_ns(&op),
            None => {
                // Memory-class: elements moved at the modeled rate.
                let elems: u64 =
                    inputs.iter().chain(outputs).map(|t| t.len() as u64).sum();
                let cycles = self
                    .model
                    .cycles_for_ops(kernel.class, elems.max(1));
                cycles * 1000 / self.model.clock_mhz as u64
            }
        };
        self.virtual_ns.fetch_add(ns, Ordering::Relaxed);
    }
}

impl Agent for CpuAgent {
    fn info(&self) -> &AgentInfo {
        &self.info
    }

    fn execute(&self, packet: &KernelDispatchPacket) -> Result<()> {
        let kernel = {
            let map = self.kernels.read().unwrap();
            map.get(&packet.kernel_object)
                .cloned()
                .ok_or(HsaError::UnknownKernel(packet.kernel_object))?
        };
        let outputs = (kernel.func)(&packet.args.inputs)?;
        self.charge(&kernel, &packet.args.inputs, &outputs);
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        *packet.args.output.lock().unwrap() = Some(Ok(outputs));
        Ok(())
    }

    fn virtual_time_ns(&self) -> u128 {
        self.virtual_ns.load(Ordering::Relaxed) as u128
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsa::packet::AqlPacket;
    use crate::hsa::signal::Signal;

    fn relu_kernel() -> CpuKernel {
        CpuKernel {
            name: "relu".into(),
            func: Arc::new(|ins| Ok(vec![crate::ops::relu_f32(&ins[0])?])),
            class: CpuKernelClass::Memory,
            op_template: None,
        }
    }

    fn dispatch(agent: &CpuAgent, obj: u64, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (pkt, args) = AqlPacket::dispatch(obj, inputs, Signal::new(1));
        match pkt {
            AqlPacket::KernelDispatch(d) => {
                agent.execute(&d)?;
                Ok(args.take_output().unwrap().map_err(HsaError::KernelFailed)?)
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn executes_registered_kernel() {
        let agent = CpuAgent::with_defaults();
        let id = agent.register_kernel(relu_kernel());
        let t = Tensor::from_f32(&[3], vec![-1.0, 0.5, 2.0]).unwrap();
        let out = dispatch(&agent, id, vec![t]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[0.0, 0.5, 2.0]);
        assert_eq!(agent.dispatches(), 1);
    }

    #[test]
    fn unknown_kernel_rejected() {
        let agent = CpuAgent::with_defaults();
        let t = Tensor::zeros(&[1], crate::tf::dtype::DType::F32);
        assert!(dispatch(&agent, 42, vec![t]).is_err());
    }

    #[test]
    fn virtual_time_advances_with_work() {
        let agent = CpuAgent::with_defaults();
        let fc = CpuKernel {
            name: "fc".into(),
            func: Arc::new(|ins| {
                Ok(vec![crate::ops::fc_f32(&ins[0], &ins[1], &ins[2])?])
            }),
            class: CpuKernelClass::FcF32,
            op_template: Some(RoleOp::FcF32 { m: 0, k: 8, n: 8 }),
        };
        let id = agent.register_kernel(fc);
        let x = Tensor::zeros(&[4, 8], crate::tf::dtype::DType::F32);
        let w = Tensor::zeros(&[8, 8], crate::tf::dtype::DType::F32);
        let b = Tensor::zeros(&[8], crate::tf::dtype::DType::F32);
        let t0 = agent.virtual_time_ns();
        dispatch(&agent, id, vec![x, w, b]).unwrap();
        let t1 = agent.virtual_time_ns();
        assert!(t1 > t0, "virtual clock must advance");
        // Bigger batch charges more.
        let x2 = Tensor::zeros(&[64, 8], crate::tf::dtype::DType::F32);
        let w2 = Tensor::zeros(&[8, 8], crate::tf::dtype::DType::F32);
        let b2 = Tensor::zeros(&[8], crate::tf::dtype::DType::F32);
        dispatch(&agent, id, vec![x2, w2, b2]).unwrap();
        let t2 = agent.virtual_time_ns();
        assert!(t2 - t1 > t1 - t0);
    }

    #[test]
    fn kernel_ids_distinct() {
        let agent = CpuAgent::with_defaults();
        let a = agent.register_kernel(relu_kernel());
        let b = agent.register_kernel(relu_kernel());
        assert_ne!(a, b);
    }
}
