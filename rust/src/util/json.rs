//! Minimal JSON parser and writer — the interchange layer between the
//! Python frontend and the Rust runtime (`artifacts/manifest.json`, model
//! bundles' `model.json`).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Serialization comes in two forms: compact via
//! [`std::fmt::Display`] and human-readable via [`Json::pretty`]. Numbers
//! are written in the shortest form that round-trips `f64` — and therefore
//! any `f32` widened into one, which is what lets model weights embedded
//! in a bundle survive a save/load cycle bit-for-bit. Written because
//! `serde`/`serde_json` are not available in the offline vendor set.
//!
//! Since the parser also decodes **network-exposed** input (the [`crate::net`]
//! HTTP frontend feeds request bodies through it), parsing is bounded:
//! [`JsonLimits`] caps the nesting depth (the parser recurses per nesting
//! level, so an adversarial `[[[[...` document would otherwise overflow
//! the stack) and the total payload length. [`Json::parse`] applies
//! `JsonLimits::default()`; servers pass stricter limits through
//! [`Json::parse_with_limits`]. Violations surface as named
//! [`JsonErrorKind`]s so callers can map them to specific wire errors
//! (HTTP 400 vs 413) instead of string-matching.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// What a [`JsonError`] is about — named so callers (the HTTP frontend in
/// particular) can branch on the violation instead of matching message
/// text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JsonErrorKind {
    /// Malformed JSON (bad token, truncated document, trailing bytes...).
    Syntax,
    /// Nesting exceeded [`JsonLimits::max_depth`]; parsing stopped before
    /// the recursion could grow the stack any further.
    TooDeep,
    /// The document is longer than [`JsonLimits::max_bytes`]; rejected up
    /// front without parsing anything.
    TooLarge,
}

/// Parse error with byte offset context and a named [`JsonErrorKind`].
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
    pub kind: JsonErrorKind,
}

/// Hard bounds applied while parsing. `max_depth` counts nested
/// containers (each object/array level recurses once, so this is also the
/// parser's stack bound); `max_bytes` caps the whole document length.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JsonLimits {
    pub max_depth: usize,
    pub max_bytes: usize,
}

impl Default for JsonLimits {
    /// Depth-capped, length-unbounded: the stack hazard applies to every
    /// parse (so `Json::parse` always carries the depth gate — any real
    /// `model.json` nests a handful of levels, never 128), but trusted
    /// local documents (bundles with megabytes of embedded weights) must
    /// not hit an arbitrary size ceiling. Byte limits are for network
    /// boundaries, which pass their own [`JsonLimits`] explicitly.
    fn default() -> JsonLimits {
        JsonLimits { max_depth: 128, max_bytes: usize::MAX }
    }
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed) under
    /// `JsonLimits::default()`.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        Json::parse_with_limits(text, JsonLimits::default())
    }

    /// Parse under explicit [`JsonLimits`] — the entry point for
    /// network-exposed input, where the caller knows how much nesting and
    /// payload its protocol legitimately needs.
    pub fn parse_with_limits(text: &str, limits: JsonLimits) -> Result<Json, JsonError> {
        if text.len() > limits.max_bytes {
            return Err(JsonError {
                offset: 0,
                msg: format!(
                    "document is {} bytes, limit {}",
                    text.len(),
                    limits.max_bytes
                ),
                kind: JsonErrorKind::TooLarge,
            });
        }
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0, max_depth: limits.max_depth };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Array index lookup; returns `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    /// Number as `f32`. The narrowing cast is exact whenever the document
    /// was written from an `f32` in the first place (widening to `f64` is
    /// lossless and the writer prints the shortest `f64` round-trip form).
    pub fn as_f32(&self) -> Option<f32> {
        self.as_f64().map(|n| n as f32)
    }

    /// `f32` → `Json::Num`, widening losslessly so the value round-trips.
    pub fn from_f32(v: f32) -> Json {
        Json::Num(v as f64)
    }

    pub fn from_usize(n: usize) -> Json {
        Json::Num(n as f64)
    }

    /// Pretty-print with two-space indentation. `Json::parse(&v.pretty())`
    /// reconstructs an equal value, same as the compact `Display` form.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        write_json(&mut s, self, Some(0)).expect("write to String cannot fail");
        s
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
    max_depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { offset: self.i, msg: msg.into(), kind: JsonErrorKind::Syntax }
    }

    /// Entering a container (object/array): bump the depth and refuse to
    /// recurse past the limit. Errors abort the whole parse, so the
    /// matching decrement only happens on the success paths.
    fn descend(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > self.max_depth {
            return Err(JsonError {
                offset: self.i,
                msg: format!("nesting depth exceeds limit {}", self.max_depth),
                kind: JsonErrorKind::TooDeep,
            });
        }
        Ok(())
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte 0x{c:02x}"))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.descend()?;
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.descend()?;
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Reassemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("bad utf8"))?;
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number '{txt}'")))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

/// Escape and quote `s` per the JSON string grammar.
fn write_escaped<W: fmt::Write>(w: &mut W, s: &str) -> fmt::Result {
    w.write_char('"')?;
    for c in s.chars() {
        match c {
            '"' => w.write_str("\\\"")?,
            '\\' => w.write_str("\\\\")?,
            '\n' => w.write_str("\\n")?,
            '\r' => w.write_str("\\r")?,
            '\t' => w.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(w, "\\u{:04x}", c as u32)?,
            c => w.write_char(c)?,
        }
    }
    w.write_char('"')
}

/// Shortest round-trip number form. JSON has no NaN/Infinity, so
/// non-finite values degrade to `null`; negative zero keeps its sign
/// (`-0.0`) so f32/f64 bit patterns survive a round trip.
fn write_num<W: fmt::Write>(w: &mut W, n: f64) -> fmt::Result {
    if !n.is_finite() {
        w.write_str("null")
    } else if n == 0.0 && n.is_sign_negative() {
        w.write_str("-0.0")
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        write!(w, "{}", n as i64)
    } else {
        // Rust's f64 Display is the shortest string that parses back to
        // the identical bits — exactly the round-trip guarantee we need.
        write!(w, "{n}")
    }
}

fn newline_indent<W: fmt::Write>(w: &mut W, level: usize) -> fmt::Result {
    w.write_char('\n')?;
    for _ in 0..level {
        w.write_str("  ")?;
    }
    Ok(())
}

/// Shared serializer: `indent: None` is the compact `Display` form,
/// `Some(level)` the pretty form.
fn write_json<W: fmt::Write>(w: &mut W, v: &Json, indent: Option<usize>) -> fmt::Result {
    match v {
        Json::Null => w.write_str("null"),
        Json::Bool(b) => write!(w, "{b}"),
        Json::Num(n) => write_num(w, *n),
        Json::Str(s) => write_escaped(w, s),
        Json::Arr(a) => {
            if a.is_empty() {
                return w.write_str("[]");
            }
            w.write_char('[')?;
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    w.write_char(',')?;
                }
                if let Some(level) = indent {
                    newline_indent(w, level + 1)?;
                    write_json(w, item, Some(level + 1))?;
                } else {
                    write_json(w, item, None)?;
                }
            }
            if let Some(level) = indent {
                newline_indent(w, level)?;
            }
            w.write_char(']')
        }
        Json::Obj(m) => {
            if m.is_empty() {
                return w.write_str("{}");
            }
            w.write_char('{')?;
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    w.write_char(',')?;
                }
                if let Some(level) = indent {
                    newline_indent(w, level + 1)?;
                    write_escaped(w, k)?;
                    w.write_str(": ")?;
                    write_json(w, item, Some(level + 1))?;
                } else {
                    write_escaped(w, k)?;
                    w.write_char(':')?;
                    write_json(w, item, None)?;
                }
            }
            if let Some(level) = indent {
                newline_indent(w, level)?;
            }
            w.write_char('}')
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_json(f, self, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""é\t\\ 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é\t\\ 😀"));
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn missing_keys_are_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(v.idx(3), &Json::Null);
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn display_round_trips() {
        let doc = r#"{"a":[1,2.5,"x\"y"],"b":true}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn writer_escapes_round_trip() {
        for s in [
            "plain",
            "quote\" backslash\\ slash/",
            "ctrl:\u{1}\u{8}\u{c}\u{1f}",
            "newline\n tab\t cr\r",
            "unicode é 😀 héllo",
            "",
        ] {
            let v = Json::Str(s.to_string());
            let compact = v.to_string();
            assert_eq!(Json::parse(&compact).unwrap().as_str(), Some(s), "{compact}");
            assert_eq!(Json::parse(&v.pretty()).unwrap().as_str(), Some(s));
        }
    }

    #[test]
    fn writer_f32_values_round_trip_exactly() {
        let vals = [
            0.1f32,
            -0.0,
            1.0 / 3.0,
            f32::MAX,
            f32::MIN_POSITIVE,
            1e-45,            // smallest subnormal
            16_777_216.0,     // 2^24, the f32 integer-precision edge
            -2.5e-7,
            1234.5678,
        ];
        for v in vals {
            let doc = Json::from_f32(v).to_string();
            let back = Json::parse(&doc).unwrap().as_f32().unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "{v} -> {doc} -> {back}");
        }
    }

    #[test]
    fn writer_nonfinite_degrades_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_parses_back_equal_and_is_indented() {
        let doc = r#"{"a":[1,2.5,"x\"y"],"b":true,"c":{},"d":[]}"#;
        let v = Json::parse(doc).unwrap();
        let p = v.pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
        assert!(p.contains("\n  \"a\": [\n"), "pretty form:\n{p}");
        assert!(p.contains("\"c\": {}"), "empty containers stay inline:\n{p}");
    }

    #[test]
    fn adversarial_nesting_is_rejected_not_a_stack_overflow() {
        // 500k open brackets: without the depth gate this recurses 500k
        // frames deep. With it, parsing stops at the limit with a named
        // error long before the stack is in danger.
        let bomb = "[".repeat(500_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert_eq!(err.kind, JsonErrorKind::TooDeep, "{err}");
        assert!(err.msg.contains("128"), "names the limit: {err}");
        // Same for objects.
        let obomb = "{\"k\":".repeat(500_000);
        let err = Json::parse(&obomb).unwrap_err();
        assert_eq!(err.kind, JsonErrorKind::TooDeep, "{err}");
    }

    #[test]
    fn depth_limit_is_exact() {
        let limits = JsonLimits { max_depth: 4, max_bytes: 1 << 20 };
        assert_eq!(
            Json::parse_with_limits("[[[[1]]]]", limits).unwrap().idx(0).idx(0).idx(0).idx(0),
            &Json::Num(1.0),
            "depth exactly at the limit parses"
        );
        let err = Json::parse_with_limits("[[[[[1]]]]]", limits).unwrap_err();
        assert_eq!(err.kind, JsonErrorKind::TooDeep);
        // Sibling containers do not accumulate: depth is nesting, not count.
        let wide = format!("[{}]", vec!["[1]"; 1000].join(","));
        assert!(Json::parse_with_limits(&wide, limits).is_ok(), "wide is not deep");
    }

    #[test]
    fn oversized_payload_is_rejected_up_front() {
        let limits = JsonLimits { max_depth: 8, max_bytes: 16 };
        assert!(Json::parse_with_limits("[1,2,3]", limits).is_ok());
        let err = Json::parse_with_limits("[1,2,3,4,5,6,7,8]", limits).unwrap_err();
        assert_eq!(err.kind, JsonErrorKind::TooLarge);
        assert!(err.msg.contains("16"), "names the limit: {err}");
        assert_eq!(err.offset, 0, "rejected before parsing");
    }

    #[test]
    fn syntax_errors_keep_the_syntax_kind() {
        for doc in ["{", "[1,]", "\"unterminated", "{}extra"] {
            assert_eq!(Json::parse(doc).unwrap_err().kind, JsonErrorKind::Syntax, "{doc}");
        }
    }

    #[test]
    fn default_limits_admit_bundle_shaped_documents() {
        // Deeply-valued but shallowly-nested, like model.json: a few
        // levels of objects holding long flat arrays.
        let weights: Vec<String> = (0..10_000).map(|i| format!("{}.5", i)).collect();
        let doc = format!(
            "{{\"graph\":{{\"nodes\":[{{\"w\":[{}]}}]}}}}",
            weights.join(",")
        );
        let v = Json::parse(&doc).unwrap();
        assert_eq!(
            v.get("graph").get("nodes").idx(0).get("w").as_arr().unwrap().len(),
            10_000
        );
    }

    #[test]
    fn pretty_and_compact_agree_on_scalars() {
        for doc in ["null", "true", "42", "-7.25", "\"x\""] {
            let v = Json::parse(doc).unwrap();
            assert_eq!(v.to_string(), v.pretty());
        }
    }
}
