//! Minimal JSON parser — enough for `artifacts/manifest.json`.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); no serialization beyond what the metrics
//! reports need. Written because `serde`/`serde_json` are not available in
//! the offline vendor set.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Array index lookup; returns `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { offset: self.i, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte 0x{c:02x}"))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Reassemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("bad utf8"))?;
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number '{txt}'")))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""é\t\\ 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é\t\\ 😀"));
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn missing_keys_are_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(v.idx(3), &Json::Null);
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn display_round_trips() {
        let doc = r#"{"a":[1,2.5,"x\"y"],"b":true}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }
}
