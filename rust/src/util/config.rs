//! INI-style config files for the launcher (`tf-fpga --config run.cfg ...`
//! and `Session` construction from a deployment file) — the "real config
//! system" a framework ships instead of a flag zoo.
//!
//! Format: `key = value` lines, `[section]` headers, `#`/`;` comments.
//! Keys are addressed as `section.key` (keys before any header live in the
//! root section, addressed bare).

use crate::hsa::error::{HsaError, Result};
use crate::reconfig::policy::PolicyKind;
use crate::tf::session::SessionOptions;
use std::collections::BTreeMap;

/// Parsed configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(HsaError::Runtime(format!(
                        "config line {}: empty section name",
                        lineno + 1
                    )));
                }
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(HsaError::Runtime(format!(
                    "config line {}: expected `key = value`, got '{line}'",
                    lineno + 1
                )));
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            if key.split('.').next_back().unwrap_or("").is_empty() {
                return Err(HsaError::Runtime(format!(
                    "config line {}: empty key",
                    lineno + 1
                )));
            }
            values.insert(key, v.trim().to_string());
        }
        Ok(Config { values })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            HsaError::Runtime(format!("read {}: {e}", path.as_ref().display()))
        })?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| {
                v.parse().map_err(|_| {
                    HsaError::Runtime(format!("config '{key}': '{v}' is not an integer"))
                })
            })
            .transpose()
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        self.get(key)
            .map(|v| match v {
                "true" | "yes" | "1" | "on" => Ok(true),
                "false" | "no" | "0" | "off" => Ok(false),
                other => Err(HsaError::Runtime(format!(
                    "config '{key}': '{other}' is not a boolean"
                ))),
            })
            .transpose()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Build `SessionOptions` from the `[session]` section:
    ///
    /// ```ini
    /// [session]
    /// regions = 4
    /// policy = lru            # lru | mru | fifo | random | queue-aware
    /// prefer_fpga = true
    /// soft_placement = true
    /// use_pjrt = true
    /// artifacts = artifacts   # directory
    /// realtime = false
    /// dispatch_workers = 1    # >1: concurrent kernels per queue
    /// ```
    pub fn session_options(&self) -> Result<SessionOptions> {
        let mut o = SessionOptions::default();
        if let Some(n) = self.get_usize("session.regions")? {
            if n == 0 {
                return Err(HsaError::Runtime("session.regions must be >= 1".into()));
            }
            o.num_regions = n;
        }
        if let Some(p) = self.get("session.policy") {
            o.policy = PolicyKind::parse(p).ok_or_else(|| {
                HsaError::Runtime(format!(
                    "session.policy '{p}' (want lru|mru|fifo|random|queue-aware)"
                ))
            })?;
        }
        if let Some(b) = self.get_bool("session.prefer_fpga")? {
            o.prefer_fpga = b;
        }
        if let Some(b) = self.get_bool("session.soft_placement")? {
            o.allow_soft_placement = b;
        }
        if let Some(b) = self.get_bool("session.use_pjrt")? {
            o.use_pjrt = b;
        }
        if let Some(dir) = self.get("session.artifacts") {
            o.artifacts_dir = Some(dir.into());
        }
        if let Some(b) = self.get_bool("session.realtime")? {
            o.realtime = b;
        }
        if let Some(n) = self.get_usize("session.dispatch_workers")? {
            if n == 0 {
                return Err(HsaError::Runtime(
                    "session.dispatch_workers must be >= 1".into(),
                ));
            }
            o.dispatch_workers = n;
        }
        Ok(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# deployment config
top = 1

[session]
regions = 4
policy = fifo
prefer_fpga = false
use_pjrt = off

[serve]
max_batch = 16
; comment
max_delay_ms = 3
";

    #[test]
    fn parses_sections_and_comments() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("top"), Some("1"));
        assert_eq!(c.get("session.regions"), Some("4"));
        assert_eq!(c.get("serve.max_batch"), Some("16"));
        assert_eq!(c.get("serve.max_delay_ms"), Some("3"));
        assert_eq!(c.get("missing"), None);
        assert_eq!(c.len(), 7);
    }

    #[test]
    fn session_options_from_config() {
        let c = Config::parse(SAMPLE).unwrap();
        let o = c.session_options().unwrap();
        assert_eq!(o.num_regions, 4);
        assert_eq!(o.policy, crate::reconfig::policy::PolicyKind::Fifo);
        assert!(!o.prefer_fpga);
        assert!(!o.use_pjrt);
        assert!(o.allow_soft_placement, "untouched default");
    }

    #[test]
    fn typed_getters_validate() {
        let c = Config::parse("x = abc\nb = maybe\n").unwrap();
        assert!(c.get_usize("x").is_err());
        assert!(c.get_bool("b").is_err());
        assert_eq!(c.get_usize("nope").unwrap(), None);
    }

    #[test]
    fn bad_lines_error_with_line_numbers() {
        let err = Config::parse("ok = 1\nnot a kv line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        let err = Config::parse("[]\n").unwrap_err();
        assert!(err.to_string().contains("section"), "{err}");
    }

    #[test]
    fn zero_regions_rejected() {
        let c = Config::parse("[session]\nregions = 0\n").unwrap();
        assert!(c.session_options().is_err());
    }

    #[test]
    fn bad_policy_rejected() {
        let c = Config::parse("[session]\npolicy = belady\n").unwrap();
        assert!(c.session_options().is_err(), "belady needs a trace, not valid here");
    }

    #[test]
    fn whitespace_tolerant() {
        let c = Config::parse("  key   =   spaced value  \n").unwrap();
        assert_eq!(c.get("key"), Some("spaced value"));
    }
}
