//! Mini property-testing harness (no `proptest` in the offline vendor set).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` against `cases` generated
//! inputs and, on failure, performs greedy shrinking via the generator's
//! `shrink` hook before panicking with the minimal counterexample.

use crate::util::prng::Rng;
use std::fmt::Debug;

/// A generator of random test inputs with optional shrinking.
pub trait Gen {
    type Value: Clone + Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller inputs (tried in order during shrinking).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` random inputs. Panics with the (shrunk)
/// counterexample on failure.
pub fn forall<G: Gen>(
    seed: u64,
    cases: usize,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut cur = input;
            let mut msg = first_msg;
            let mut budget = 200usize;
            'outer: while budget > 0 {
                for cand in gen.shrink(&cur) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  input: {cur:?}\n  error: {msg}"
            );
        }
    }
}

/// Generator: u64 in [lo, hi] with halving shrink toward lo.
pub struct U64Range(pub u64, pub u64);

impl Gen for U64Range {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        self.0 + rng.below(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Generator: vector of values from an inner generator, with length and
/// element shrinking.
pub struct VecGen<G> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let len = self.min_len + rng.below((self.max_len - self.min_len + 1) as u64) as usize;
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // Drop halves, then single elements.
            out.push(v[..v.len() / 2.max(self.min_len)].to_vec());
            let mut minus_last = v.clone();
            minus_last.pop();
            out.push(minus_last);
            let mut minus_first = v.clone();
            minus_first.remove(0);
            out.push(minus_first);
        }
        // Shrink one element at a time (first few positions).
        for i in 0..v.len().min(4) {
            for cand in self.inner.shrink(&v[i]) {
                let mut w = v.clone();
                w[i] = cand;
                out.push(w);
            }
        }
        out.retain(|w| w.len() >= self.min_len);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 100, &U64Range(0, 100), |v| {
            if *v <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(2, 100, &U64Range(0, 1000), |v| {
            if *v < 500 {
                Ok(())
            } else {
                Err(format!("{v} too big"))
            }
        });
    }

    #[test]
    fn shrinking_reaches_small_counterexample() {
        let result = std::panic::catch_unwind(|| {
            forall(3, 200, &U64Range(0, 10_000), |v| {
                if *v < 777 {
                    Ok(())
                } else {
                    Err("boom".into())
                }
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(_) => panic!("should have failed"),
        };
        // The greedy shrinker should get at/near the 777 boundary, well
        // below the raw failing sample's expected magnitude.
        let input: u64 = msg
            .split("input: ")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(input < 1600, "shrunk to {input}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecGen { inner: U64Range(0, 9), min_len: 2, max_len: 6 };
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let v = g.generate(&mut rng);
            assert!((2..=6).contains(&v.len()));
            assert!(v.iter().all(|x| *x <= 9));
        }
    }
}
