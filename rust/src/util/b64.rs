//! Standard-alphabet base64 (RFC 4648, with `=` padding) — encoder and
//! strict decoder, implemented here because no third-party codec is in
//! the offline vendor set.
//!
//! Used by the wire layer's middle tier: raw little-endian f32 tensor
//! payloads carried as `instances_b64` / `predictions_b64` strings inside
//! the JSON API, skipping per-number text round-trips while staying
//! JSON-transportable.

const ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode `data` as standard base64 with padding.
pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let triple = (b0 << 16) | (b1 << 8) | b2;
        out.push(ALPHABET[(triple >> 18) as usize & 0x3f] as char);
        out.push(ALPHABET[(triple >> 12) as usize & 0x3f] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[(triple >> 6) as usize & 0x3f] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            ALPHABET[triple as usize & 0x3f] as char
        } else {
            '='
        });
    }
    out
}

fn decode_sym(c: u8) -> Option<u32> {
    match c {
        b'A'..=b'Z' => Some((c - b'A') as u32),
        b'a'..=b'z' => Some((c - b'a' + 26) as u32),
        b'0'..=b'9' => Some((c - b'0' + 52) as u32),
        b'+' => Some(62),
        b'/' => Some(63),
        _ => None,
    }
}

/// Strictly decode standard base64: length must be a multiple of 4,
/// padding only at the end, no whitespace or alternate alphabets.
pub fn decode(s: &str) -> Result<Vec<u8>, String> {
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(format!("base64 length {} is not a multiple of 4", bytes.len()));
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for (i, quad) in bytes.chunks(4).enumerate() {
        let last = (i + 1) * 4 == bytes.len();
        let pad = quad.iter().rev().take_while(|&&c| c == b'=').count();
        if pad > 2 || (pad > 0 && !last) {
            return Err("base64 padding only allowed at the end".into());
        }
        let mut triple: u32 = 0;
        for &c in &quad[..4 - pad] {
            let v = decode_sym(c).ok_or_else(|| {
                format!("invalid base64 character {:?}", c as char)
            })?;
            triple = (triple << 6) | v;
        }
        triple <<= 6 * pad as u32;
        out.push((triple >> 16) as u8);
        if pad < 2 {
            out.push((triple >> 8) as u8);
        }
        if pad < 1 {
            out.push(triple as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn round_trips_all_byte_values() {
        let data: Vec<u8> = (0..=255u8).collect();
        for len in [0, 1, 2, 3, 4, 63, 255, 256] {
            let slice = &data[..len.min(data.len())];
            assert_eq!(decode(&encode(slice)).unwrap(), slice, "len {len}");
        }
    }

    #[test]
    fn strict_decode_rejects_malformed_input() {
        assert!(decode("Zg=").is_err(), "length not multiple of 4");
        assert!(decode("Zg==Zg==").is_err(), "padding mid-stream");
        assert!(decode("Z===").is_err(), "three padding chars");
        assert!(decode("Zm 9").is_err(), "whitespace");
        assert!(decode("Zm9\n").is_err(), "newline");
        assert!(decode("Zm9-").is_err(), "url-safe alphabet rejected");
    }

    #[test]
    fn f32_le_payload_round_trips_bitwise() {
        let vals = [0.0f32, -0.0, 1.5, f32::MIN_POSITIVE / 2.0, -1.0e-40, 3.4e38];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let back = decode(&encode(&bytes)).unwrap();
        for (i, v) in vals.iter().enumerate() {
            let got = f32::from_le_bytes(back[i * 4..i * 4 + 4].try_into().unwrap());
            assert_eq!(got.to_bits(), v.to_bits(), "value {i} not bit-exact");
        }
    }
}
