//! Deterministic PRNGs (SplitMix64 + Xoshiro256++) — the offline vendor set
//! has no `rand` crate; these are the reference implementations.

/// SplitMix64: used for seeding and cheap streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++: the main generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`; unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Standard normal (Box–Muller; one value per call, simple and fine here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick an element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Zipf-distributed index in `[0, n)` with exponent `s` (request-trace
    /// style skew; used by the reconfiguration ablations).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on the precomputable harmonic sum would be faster but
        // n is tiny (role counts); linear scan is fine.
        debug_assert!(n > 0);
        let h: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let target = self.f64() * h;
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            if acc >= target {
                return k - 1;
            }
        }
        n - 1
    }

    pub fn fill_f32_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out {
            *v = mean + std * self.normal() as f32;
        }
    }

    pub fn fill_i16(&mut self, out: &mut [i16], lo: i16, hi: i16) {
        for v in out {
            *v = self.range_i64(lo as i64, hi as i64) as i16;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 8];
        for _ in 0..4000 {
            counts[r.zipf(8, 1.2)] += 1;
        }
        assert!(counts[0] > counts[7] * 3, "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive_hits_bounds() {
        let mut r = Rng::new(19);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            match r.range_i64(-2, 2) {
                -2 => lo_seen = true,
                2 => hi_seen = true,
                v => assert!((-2..=2).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }
}
