//! Timing statistics: summaries with percentiles for the paper-style tables.

use std::time::Duration;

/// Summary statistics over a sample of durations (or any f64 series).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Build from raw values (any unit). Returns a zeroed summary for an
    /// empty sample rather than panicking.
    pub fn from_values(values: &[f64]) -> Summary {
        if values.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut v: Vec<f64> = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: v[n - 1],
        }
    }

    /// Build from durations, in microseconds (the paper's Table II unit).
    pub fn from_durations_us(samples: &[Duration]) -> Summary {
        let us: Vec<f64> = samples.iter().map(|d| d.as_secs_f64() * 1e6).collect();
        Summary::from_values(&us)
    }
}

/// Nearest-rank percentile on a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0 * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Geometric mean (used for speedup aggregation).
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let logs: f64 = values.iter().map(|v| v.ln()).sum();
    (logs / values.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_series() {
        let s = Summary::from_values(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_orders_percentiles() {
        let vals: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = Summary::from_values(&vals);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_sample_is_zeroed() {
        let s = Summary::from_values(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn geomean_of_powers() {
        let g = geomean(&[1.0, 4.0, 16.0]);
        assert!((g - 4.0).abs() < 1e-9);
    }

    #[test]
    fn duration_conversion_is_us() {
        let s = Summary::from_durations_us(&[Duration::from_micros(250); 4]);
        assert!((s.mean - 250.0).abs() < 1.0);
    }
}
