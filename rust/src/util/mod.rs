//! Small self-contained utilities (no third-party deps are available
//! offline beyond `xla`/`anyhow`/`thiserror`/`once_cell`, so JSON parsing,
//! PRNG, statistics and property testing are implemented here).
//!
//! * [`b64`] — standard-alphabet base64 for the wire layer's raw-f32
//!   tensor tier inside the JSON API;
//! * [`config`] — key=value config files that desugar into
//!   `SessionOptions` (the CLI's `--config` flag);
//! * [`json`] — a minimal JSON parser for the artifact manifest and the
//!   Chrome-trace export (no serde offline);
//! * [`prng`] — a splitmix64-style deterministic PRNG so synthetic
//!   weights and property-test inputs are reproducible across runs and
//!   platforms;
//! * [`quickcheck`] — a tiny property-testing harness over that PRNG;
//! * [`stats`] — summary statistics (mean/percentiles/geomean) for the
//!   bench harness and the paper tables;
//! * [`spin_enabled`] — host-level gate for all spin-then-block wait
//!   loops (spinning on a single core only delays the thread being
//!   waited for).

pub mod b64;
pub mod config;
pub mod json;
pub mod prng;
pub mod quickcheck;
pub mod stats;

/// Whether spin-then-block waiting is profitable on this host. On a
/// single-core machine a spinning waiter only steals cycles from the
/// thread it is waiting for, so all hot-path spin phases collapse to
/// immediate blocking (§Perf, EXPERIMENTS.md).
pub fn spin_enabled() -> bool {
    static ENABLED: once_cell::sync::Lazy<bool> = once_cell::sync::Lazy::new(|| {
        std::thread::available_parallelism()
            .map(|n| n.get() > 1)
            .unwrap_or(false)
    });
    *ENABLED
}
