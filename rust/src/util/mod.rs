//! Small self-contained utilities (no third-party deps are available
//! offline beyond `xla`/`anyhow`/`thiserror`/`once_cell`, so JSON parsing,
//! PRNG, statistics and property testing are implemented here).

pub mod config;
pub mod json;
pub mod prng;
pub mod quickcheck;
pub mod stats;

/// Whether spin-then-block waiting is profitable on this host. On a
/// single-core machine a spinning waiter only steals cycles from the
/// thread it is waiting for, so all hot-path spin phases collapse to
/// immediate blocking (§Perf, EXPERIMENTS.md).
pub fn spin_enabled() -> bool {
    static ENABLED: once_cell::sync::Lazy<bool> = once_cell::sync::Lazy::new(|| {
        std::thread::available_parallelism()
            .map(|n| n.get() > 1)
            .unwrap_or(false)
    });
    *ENABLED
}
