//! Fixed-width table formatting (the benches print the paper's tables).

use std::fmt;

/// A printable table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub footnotes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            footnotes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn footnote(&mut self, note: impl Into<String>) -> &mut Self {
        self.footnotes.push(note.into());
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }
}

impl fmt::Display for Table {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let w = self.widths();
        writeln!(f, "== {} ==", self.title)?;
        let line = |f: &mut fmt::Formatter<'_>, cells: &[String]| -> fmt::Result {
            write!(f, "|")?;
            for (i, c) in cells.iter().enumerate() {
                write!(f, " {:<width$} |", c, width = w[i])?;
            }
            writeln!(f)
        };
        line(f, &self.headers)?;
        write!(f, "|")?;
        for wi in &w {
            write!(f, "{:-<width$}|", "", width = wi + 2)?;
        }
        writeln!(f)?;
        for row in &self.rows {
            line(f, row)?;
        }
        for note in &self.footnotes {
            writeln!(f, "  * {note}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "long-header"]);
        t.row_strs(&["1", "2"]);
        t.row_strs(&["wide-cell", "3"]);
        let s = t.to_string();
        assert!(s.contains("== T =="));
        assert!(s.contains("| wide-cell | 3"));
        // All data lines have the same width.
        let lens: Vec<usize> =
            s.lines().filter(|l| l.starts_with('|')).map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn footnotes_printed() {
        let mut t = Table::new("T", &["a"]);
        t.row_strs(&["1"]).footnote("est.");
        assert!(t.to_string().contains("* est."));
    }
}
