//! Measurement and reporting utilities for the paper-style tables.

pub mod histogram;
pub mod report;

pub use histogram::Histogram;
pub use report::Table;
