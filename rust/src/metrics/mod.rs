//! Measurement and reporting utilities: the log2-bucketed latency
//! [`Histogram`] and paper-style [`Table`] rendering, plus the lock-free
//! [`ServeCounters`] the async serving pipeline shares across its submit,
//! batcher and completer threads.

pub mod counters;
pub mod histogram;
pub mod report;

pub use counters::{CounterSnapshot, ServeCounters};
pub use histogram::Histogram;
pub use report::Table;
