//! Measurement and reporting utilities: the log2-bucketed latency
//! [`Histogram`] and paper-style [`Table`] rendering, plus the lock-free
//! [`ServeCounters`] the async serving pipeline shares across its submit,
//! batcher and completer threads, and the per-stage latency
//! [`StageHistograms`] behind the request-scoped observability story.

pub mod counters;
pub mod histogram;
pub mod report;
pub mod stages;

pub use counters::{CounterSnapshot, ServeCounters};
pub use histogram::Histogram;
pub use report::Table;
pub use stages::StageHistograms;
