//! Per-stage latency histograms: one log2 [`Histogram`] per pipeline
//! [`Stage`], shared across the HTTP workers and the serving pipeline
//! threads. The `/metrics` endpoint renders these as real Prometheus
//! histograms (`_bucket`/`_sum`/`_count`), giving every stage of the
//! request path an attribution story without collecting raw samples.

use crate::metrics::histogram::Histogram;
use crate::trace::span::{SpanCtx, Stage};
use std::sync::Mutex;

/// Thread-safe per-stage histogram set.
#[derive(Debug)]
pub struct StageHistograms {
    inner: Mutex<Vec<Histogram>>,
}

impl Default for StageHistograms {
    fn default() -> Self {
        StageHistograms::new()
    }
}

impl StageHistograms {
    pub fn new() -> StageHistograms {
        StageHistograms {
            inner: Mutex::new(vec![Histogram::new(); Stage::ALL.len()]),
        }
    }

    fn idx(stage: Stage) -> usize {
        Stage::ALL
            .iter()
            .position(|s| *s == stage)
            .expect("Stage::ALL covers every variant")
    }

    /// Record one observation (µs) for `stage`.
    pub fn record(&self, stage: Stage, dur_us: u64) {
        self.inner.lock().unwrap()[Self::idx(stage)].record(dur_us);
    }

    /// Fold a finished request span's whole breakdown in.
    pub fn record_span(&self, span: &SpanCtx) {
        let stages = span.stages();
        if stages.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        for (stage, dur_us) in stages {
            inner[Self::idx(stage)].record(dur_us);
        }
    }

    /// Clone-out snapshot, in [`Stage::ALL`] order, for exposition.
    pub fn snapshot(&self) -> Vec<(Stage, Histogram)> {
        let inner = self.inner.lock().unwrap();
        Stage::ALL
            .iter()
            .copied()
            .zip(inner.iter().cloned())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::recorder::TraceRecorder;

    #[test]
    fn records_per_stage_and_snapshots() {
        let h = StageHistograms::new();
        h.record(Stage::BatchWait, 100);
        h.record(Stage::BatchWait, 200);
        h.record(Stage::KernelExec, 50);
        let snap = h.snapshot();
        assert_eq!(snap.len(), Stage::ALL.len());
        let batch = snap.iter().find(|(s, _)| *s == Stage::BatchWait).unwrap();
        assert_eq!(batch.1.count(), 2);
        assert_eq!(batch.1.sum(), 300);
        let kernel = snap.iter().find(|(s, _)| *s == Stage::KernelExec).unwrap();
        assert_eq!(kernel.1.count(), 1);
        let route = snap.iter().find(|(s, _)| *s == Stage::Route).unwrap();
        assert_eq!(route.1.count(), 0);
    }

    #[test]
    fn folds_a_span_breakdown() {
        let span = SpanCtx::new("r", TraceRecorder::new());
        span.record_stage(Stage::AdmissionWait, 3);
        span.record_stage(Stage::ReplySerialize, 9);
        let h = StageHistograms::new();
        h.record_span(&span);
        let snap = h.snapshot();
        let adm = snap.iter().find(|(s, _)| *s == Stage::AdmissionWait).unwrap();
        assert_eq!(adm.1.count(), 1);
        let reply = snap.iter().find(|(s, _)| *s == Stage::ReplySerialize).unwrap();
        assert_eq!(reply.1.sum(), 9);
    }
}
