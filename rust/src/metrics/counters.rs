//! Lock-free serving counters: request/batch accounting and an in-flight
//! gauge with a high-water mark, shared across the submit, batcher and
//! completer threads of the async serving pipeline.

use std::sync::atomic::{AtomicU64, Ordering};

/// Atomic counters for one serving pipeline. All methods are cheap enough
/// for the per-request hot path (relaxed read-modify-writes).
#[derive(Debug, Default)]
pub struct ServeCounters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    /// Sum of batch fills, for mean-fill reporting.
    fill_sum: AtomicU64,
    /// Batches dispatched but not yet retired.
    inflight: AtomicU64,
    max_inflight: AtomicU64,
    /// Cumulative µs of plan compilation *recorded by the pipeline via*
    /// [`ServeCounters::on_plan_compile`] (today: the startup prewarm).
    /// Serve reports source their total from the session's plan-cache
    /// stats instead, which also sees steady-state cache misses.
    plan_compile_us: AtomicU64,
}

/// A point-in-time copy of [`ServeCounters`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub fill_sum: u64,
    pub inflight: u64,
    pub max_inflight: u64,
    pub plan_compile_us: u64,
}

impl CounterSnapshot {
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.fill_sum as f64 / self.batches as f64
        }
    }

    /// Field-wise accumulation for pooled rollups (per-agent or
    /// per-pipeline counters summed into one view). Gauges add too:
    /// the pool's in-flight total is the sum of its members', and the
    /// summed high-water mark is the pool-wide upper bound (individual
    /// peaks need not have coincided).
    pub fn absorb(&mut self, other: &CounterSnapshot) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.failed += other.failed;
        self.batches += other.batches;
        self.fill_sum += other.fill_sum;
        self.inflight += other.inflight;
        self.max_inflight += other.max_inflight;
        self.plan_compile_us += other.plan_compile_us;
    }

    /// Sum of many per-agent/per-pipeline snapshots.
    pub fn rollup<'a>(
        parts: impl IntoIterator<Item = &'a CounterSnapshot>,
    ) -> CounterSnapshot {
        let mut total = CounterSnapshot::default();
        for p in parts {
            total.absorb(p);
        }
        total
    }
}

impl ServeCounters {
    pub fn new() -> ServeCounters {
        ServeCounters::default()
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// A batch of `fill` requests was dispatched; bumps the in-flight
    /// gauge and folds it into the high-water mark.
    pub fn on_batch_dispatch(&self, fill: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.fill_sum.fetch_add(fill, Ordering::Relaxed);
        let now = self.inflight.fetch_add(1, Ordering::AcqRel) + 1;
        self.max_inflight.fetch_max(now, Ordering::AcqRel);
    }

    /// A batch retired; `completed` of its requests succeeded, `failed`
    /// got an error reply.
    pub fn on_batch_complete(&self, completed: u64, failed: u64) {
        self.completed.fetch_add(completed, Ordering::Relaxed);
        self.failed.fetch_add(failed, Ordering::Relaxed);
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    /// Requests rejected before ever being dispatched (bad tensor, model
    /// gone, pipeline torn down): failures only, no batch accounting.
    pub fn on_failed(&self, n: u64) {
        self.failed.fetch_add(n, Ordering::Relaxed);
    }

    /// An execution plan was compiled for this pipeline (µs of compile
    /// time; accumulated so multi-model prewarms sum up).
    pub fn on_plan_compile(&self, us: u64) {
        self.plan_compile_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            fill_sum: self.fill_sum.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Acquire),
            max_inflight: self.max_inflight.load(Ordering::Acquire),
            plan_compile_us: self.plan_compile_us.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_accounting() {
        let c = ServeCounters::new();
        for _ in 0..5 {
            c.on_submit();
        }
        c.on_batch_dispatch(3);
        c.on_batch_dispatch(2);
        assert_eq!(c.inflight(), 2);
        c.on_batch_complete(3, 0);
        c.on_batch_complete(1, 1);
        let s = c.snapshot();
        assert_eq!(s.submitted, 5);
        assert_eq!(s.completed, 4);
        assert_eq!(s.failed, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.inflight, 0);
        assert_eq!(s.max_inflight, 2);
        assert!((s.mean_batch_fill() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn high_water_mark_survives_drain() {
        let c = ServeCounters::new();
        for _ in 0..4 {
            c.on_batch_dispatch(1);
        }
        for _ in 0..4 {
            c.on_batch_complete(1, 0);
        }
        assert_eq!(c.inflight(), 0);
        assert_eq!(c.snapshot().max_inflight, 4);
    }

    #[test]
    fn plan_compile_time_accumulates() {
        let c = ServeCounters::new();
        c.on_plan_compile(120);
        c.on_plan_compile(80);
        assert_eq!(c.snapshot().plan_compile_us, 200);
    }

    #[test]
    fn rejected_requests_do_not_touch_batch_gauges() {
        let c = ServeCounters::new();
        c.on_failed(3);
        let s = c.snapshot();
        assert_eq!(s.failed, 3);
        assert_eq!((s.batches, s.inflight, s.max_inflight), (0, 0, 0));
    }

    #[test]
    fn rollup_sums_field_wise() {
        let a = ServeCounters::new();
        a.on_submit();
        a.on_batch_dispatch(3);
        a.on_batch_complete(3, 0);
        a.on_plan_compile(100);
        let b = ServeCounters::new();
        b.on_submit();
        b.on_submit();
        b.on_batch_dispatch(2);
        let total = CounterSnapshot::rollup([a.snapshot(), b.snapshot()].iter());
        assert_eq!(total.submitted, 3);
        assert_eq!(total.completed, 3);
        assert_eq!(total.batches, 2);
        assert_eq!(total.fill_sum, 5);
        assert_eq!(total.inflight, 1, "b's batch is still in flight");
        assert_eq!(total.max_inflight, 2, "pool-wide upper bound");
        assert_eq!(total.plan_compile_us, 100);
        assert!((total.mean_batch_fill() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServeCounters::new().snapshot();
        assert_eq!(s, CounterSnapshot::default());
        assert_eq!(s.mean_batch_fill(), 0.0);
    }

    #[test]
    fn concurrent_updates_do_not_lose_counts() {
        use std::sync::Arc;
        let c = Arc::new(ServeCounters::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.on_submit();
                        c.on_batch_dispatch(1);
                        c.on_batch_complete(1, 0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = c.snapshot();
        assert_eq!(s.submitted, 4000);
        assert_eq!(s.completed, 4000);
        assert_eq!(s.batches, 4000);
        assert_eq!(s.inflight, 0);
    }
}
