//! Lock-free serving counters: request/batch accounting and an in-flight
//! gauge with a high-water mark, shared across the submit, batcher and
//! completer threads of the async serving pipeline.

use std::sync::atomic::{AtomicU64, Ordering};

/// Atomic counters for one serving pipeline. All methods are cheap enough
/// for the per-request hot path (relaxed read-modify-writes).
#[derive(Debug, Default)]
pub struct ServeCounters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    /// Sum of batch fills, for mean-fill reporting.
    fill_sum: AtomicU64,
    /// Sum of compiled batch capacities over the same dispatches — the
    /// denominator of the batch fill *ratio* (fill_sum / fill_capacity).
    fill_capacity: AtomicU64,
    /// Requests that joined a lane after its flush had already begun
    /// (continuous batching's mid-flush admission window).
    late_joins: AtomicU64,
    /// Bytes that took an extra host-memory hop on the way into a batch
    /// tensor (legacy owned-`Vec` submits, overflow tail moves). The
    /// zero-copy wire paths record nothing here — that is the point.
    bytes_copied: AtomicU64,
    /// Batches dispatched but not yet retired.
    inflight: AtomicU64,
    max_inflight: AtomicU64,
    /// Cumulative µs of plan compilation *recorded by the pipeline via*
    /// [`ServeCounters::on_plan_compile`] (today: the startup prewarm).
    /// Serve reports source their total from the session's plan-cache
    /// stats instead, which also sees steady-state cache misses.
    plan_compile_us: AtomicU64,
}

/// A point-in-time copy of [`ServeCounters`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub fill_sum: u64,
    pub fill_capacity: u64,
    pub late_joins: u64,
    pub bytes_copied: u64,
    pub inflight: u64,
    pub max_inflight: u64,
    pub plan_compile_us: u64,
}

impl CounterSnapshot {
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.fill_sum as f64 / self.batches as f64
        }
    }

    /// Fraction of dispatched batch capacity that carried real requests
    /// (1.0 = every batch left fully packed; 0.0 before any dispatch).
    pub fn batch_fill_ratio(&self) -> f64 {
        if self.fill_capacity == 0 {
            0.0
        } else {
            self.fill_sum as f64 / self.fill_capacity as f64
        }
    }

    /// Field-wise accumulation for pooled rollups (per-agent or
    /// per-pipeline counters summed into one view). Gauges add too:
    /// the pool's in-flight total is the sum of its members', and the
    /// summed high-water mark is the pool-wide upper bound (individual
    /// peaks need not have coincided).
    pub fn absorb(&mut self, other: &CounterSnapshot) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.failed += other.failed;
        self.batches += other.batches;
        self.fill_sum += other.fill_sum;
        self.fill_capacity += other.fill_capacity;
        self.late_joins += other.late_joins;
        self.bytes_copied += other.bytes_copied;
        self.inflight += other.inflight;
        self.max_inflight += other.max_inflight;
        self.plan_compile_us += other.plan_compile_us;
    }

    /// Sum of many per-agent/per-pipeline snapshots.
    pub fn rollup<'a>(
        parts: impl IntoIterator<Item = &'a CounterSnapshot>,
    ) -> CounterSnapshot {
        let mut total = CounterSnapshot::default();
        for p in parts {
            total.absorb(p);
        }
        total
    }
}

impl ServeCounters {
    pub fn new() -> ServeCounters {
        ServeCounters::default()
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// A batch of `fill` requests was dispatched into a lane compiled for
    /// `capacity` rows; bumps the in-flight gauge and folds it into the
    /// high-water mark.
    pub fn on_batch_dispatch(&self, fill: u64, capacity: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.fill_sum.fetch_add(fill, Ordering::Relaxed);
        self.fill_capacity.fetch_add(capacity, Ordering::Relaxed);
        let now = self.inflight.fetch_add(1, Ordering::AcqRel) + 1;
        self.max_inflight.fetch_max(now, Ordering::AcqRel);
    }

    /// `n` requests were admitted into a batch whose flush had already
    /// begun (they ride the in-flight batch instead of waiting a cycle).
    pub fn on_late_joins(&self, n: u64) {
        if n > 0 {
            self.late_joins.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// `n` bytes took an extra host-memory copy on the ingestion path.
    pub fn on_bytes_copied(&self, n: u64) {
        if n > 0 {
            self.bytes_copied.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// A batch retired; `completed` of its requests succeeded, `failed`
    /// got an error reply.
    pub fn on_batch_complete(&self, completed: u64, failed: u64) {
        self.completed.fetch_add(completed, Ordering::Relaxed);
        self.failed.fetch_add(failed, Ordering::Relaxed);
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    /// Requests rejected before ever being dispatched (bad tensor, model
    /// gone, pipeline torn down): failures only, no batch accounting.
    pub fn on_failed(&self, n: u64) {
        self.failed.fetch_add(n, Ordering::Relaxed);
    }

    /// An execution plan was compiled for this pipeline (µs of compile
    /// time; accumulated so multi-model prewarms sum up).
    pub fn on_plan_compile(&self, us: u64) {
        self.plan_compile_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            fill_sum: self.fill_sum.load(Ordering::Relaxed),
            fill_capacity: self.fill_capacity.load(Ordering::Relaxed),
            late_joins: self.late_joins.load(Ordering::Relaxed),
            bytes_copied: self.bytes_copied.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Acquire),
            max_inflight: self.max_inflight.load(Ordering::Acquire),
            plan_compile_us: self.plan_compile_us.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_accounting() {
        let c = ServeCounters::new();
        for _ in 0..5 {
            c.on_submit();
        }
        c.on_batch_dispatch(3, 4);
        c.on_batch_dispatch(2, 4);
        assert_eq!(c.inflight(), 2);
        c.on_batch_complete(3, 0);
        c.on_batch_complete(1, 1);
        let s = c.snapshot();
        assert_eq!(s.submitted, 5);
        assert_eq!(s.completed, 4);
        assert_eq!(s.failed, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.inflight, 0);
        assert_eq!(s.max_inflight, 2);
        assert!((s.mean_batch_fill() - 2.5).abs() < 1e-9);
        assert!((s.batch_fill_ratio() - 5.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn high_water_mark_survives_drain() {
        let c = ServeCounters::new();
        for _ in 0..4 {
            c.on_batch_dispatch(1, 1);
        }
        for _ in 0..4 {
            c.on_batch_complete(1, 0);
        }
        assert_eq!(c.inflight(), 0);
        assert_eq!(c.snapshot().max_inflight, 4);
        assert_eq!(c.snapshot().batch_fill_ratio(), 1.0);
    }

    #[test]
    fn plan_compile_time_accumulates() {
        let c = ServeCounters::new();
        c.on_plan_compile(120);
        c.on_plan_compile(80);
        assert_eq!(c.snapshot().plan_compile_us, 200);
    }

    #[test]
    fn rejected_requests_do_not_touch_batch_gauges() {
        let c = ServeCounters::new();
        c.on_failed(3);
        let s = c.snapshot();
        assert_eq!(s.failed, 3);
        assert_eq!((s.batches, s.inflight, s.max_inflight), (0, 0, 0));
        assert_eq!(s.batch_fill_ratio(), 0.0);
    }

    #[test]
    fn late_joins_and_bytes_copied_accumulate() {
        let c = ServeCounters::new();
        c.on_late_joins(0); // no-op
        c.on_late_joins(2);
        c.on_late_joins(1);
        c.on_bytes_copied(0); // no-op
        c.on_bytes_copied(4096);
        let s = c.snapshot();
        assert_eq!(s.late_joins, 3);
        assert_eq!(s.bytes_copied, 4096);
    }

    #[test]
    fn rollup_sums_field_wise() {
        let a = ServeCounters::new();
        a.on_submit();
        a.on_batch_dispatch(3, 8);
        a.on_batch_complete(3, 0);
        a.on_plan_compile(100);
        a.on_late_joins(1);
        a.on_bytes_copied(64);
        let b = ServeCounters::new();
        b.on_submit();
        b.on_submit();
        b.on_batch_dispatch(2, 8);
        let total = CounterSnapshot::rollup([a.snapshot(), b.snapshot()].iter());
        assert_eq!(total.submitted, 3);
        assert_eq!(total.completed, 3);
        assert_eq!(total.batches, 2);
        assert_eq!(total.fill_sum, 5);
        assert_eq!(total.fill_capacity, 16);
        assert_eq!(total.late_joins, 1);
        assert_eq!(total.bytes_copied, 64);
        assert_eq!(total.inflight, 1, "b's batch is still in flight");
        assert_eq!(total.max_inflight, 2, "pool-wide upper bound");
        assert_eq!(total.plan_compile_us, 100);
        assert!((total.mean_batch_fill() - 2.5).abs() < 1e-9);
        assert!((total.batch_fill_ratio() - 5.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServeCounters::new().snapshot();
        assert_eq!(s, CounterSnapshot::default());
        assert_eq!(s.mean_batch_fill(), 0.0);
        assert_eq!(s.batch_fill_ratio(), 0.0);
    }

    #[test]
    fn concurrent_updates_do_not_lose_counts() {
        use std::sync::Arc;
        let c = Arc::new(ServeCounters::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.on_submit();
                        c.on_batch_dispatch(1, 1);
                        c.on_batch_complete(1, 0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = c.snapshot();
        assert_eq!(s.submitted, 4000);
        assert_eq!(s.completed, 4000);
        assert_eq!(s.batches, 4000);
        assert_eq!(s.inflight, 0);
    }
}
