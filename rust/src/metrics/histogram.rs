//! Log2-bucketed latency histogram (serve-mode latency reporting).

/// Histogram over `u64` values (µs, ns, cycles — caller's unit) with
/// power-of-two buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>, // bucket i counts values in [2^i, 2^(i+1))
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { buckets: vec![0; 64], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    pub fn record(&mut self, v: u64) {
        let idx = if v == 0 { 0 } else { 63 - v.leading_zeros() as usize };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th value).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max
    }

    /// Merge another histogram in.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut h = Histogram::new();
        for v in [1, 2, 4, 8, 16] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 16);
        assert!((h.mean() - 6.2).abs() < 1e-9);
    }

    #[test]
    fn quantile_monotonic() {
        let mut h = Histogram::new();
        for v in 1..1000u64 {
            h.record(v);
        }
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(0.999).max(h.max()));
    }

    #[test]
    fn zero_value_safe() {
        let mut h = Histogram::new();
        h.record(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        a.record(5);
        let mut b = Histogram::new();
        b.record(100);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), 100);
        assert_eq!(a.min(), 5);
    }

    #[test]
    fn empty_histogram_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
    }
}
