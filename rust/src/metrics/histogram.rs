//! Log2-bucketed latency histogram (serve-mode latency reporting).

/// Histogram over `u64` values (µs, ns, cycles — caller's unit) with
/// power-of-two buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>, // bucket i counts values in [2^i, 2^(i+1))
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { buckets: vec![0; 64], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    pub fn record(&mut self, v: u64) {
        let idx = if v == 0 { 0 } else { 63 - v.leading_zeros() as usize };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th value).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max
    }

    /// Total of all recorded values (same unit the caller recorded in).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Raw per-bucket counts; bucket `i` covers `[2^i, 2^(i+1))`, with the
    /// values 0 and 1 both landing in bucket 0. Exposed for cumulative
    /// Prometheus `_bucket` exposition.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// Percentile estimate (`p` in `[0, 100]`) with intra-bucket linear
    /// interpolation: the p-th sample's bucket is located by cumulative
    /// count, then the estimate is placed proportionally between the
    /// bucket's bounds and clamped to the observed min/max (so a
    /// single-sample histogram answers that sample exactly instead of a
    /// bucket edge). Returns 0.0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
                let hi = (1u128 << (i + 1)) as f64;
                let into = (target - seen) as f64 / c as f64;
                let est = lo + (hi - lo) * into;
                return est.clamp(self.min as f64, self.max as f64);
            }
            seen += c;
        }
        self.max as f64
    }

    /// Merge another histogram in.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut h = Histogram::new();
        for v in [1, 2, 4, 8, 16] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 16);
        assert!((h.mean() - 6.2).abs() < 1e-9);
    }

    #[test]
    fn quantile_monotonic() {
        let mut h = Histogram::new();
        for v in 1..1000u64 {
            h.record(v);
        }
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(0.999).max(h.max()));
    }

    #[test]
    fn zero_value_safe() {
        let mut h = Histogram::new();
        h.record(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        a.record(5);
        let mut b = Histogram::new();
        b.record(100);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), 100);
        assert_eq!(a.min(), 5);
    }

    #[test]
    fn empty_histogram_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.sum(), 0);
    }

    #[test]
    fn percentile_single_bucket_answers_the_sample() {
        // One sample: every percentile is that sample, not a bucket edge.
        let mut h = Histogram::new();
        h.record(5);
        assert_eq!(h.percentile(0.0), 5.0);
        assert_eq!(h.percentile(50.0), 5.0);
        assert_eq!(h.percentile(99.9), 5.0);
    }

    #[test]
    fn percentile_interpolates_within_a_bucket() {
        // 100 samples spread across bucket 6 ([64, 128)): p50 should land
        // near the bucket middle, strictly between the bounds, and stay
        // monotone in p.
        let mut h = Histogram::new();
        for v in 0..100u64 {
            h.record(64 + (v * 63) / 99);
        }
        let p50 = h.percentile(50.0);
        assert!(p50 > 64.0 && p50 < 128.0, "p50 = {p50}");
        assert!((p50 - 96.0).abs() < 16.0, "p50 = {p50} should be near mid-bucket");
        assert!(h.percentile(10.0) <= h.percentile(50.0));
        assert!(h.percentile(50.0) <= h.percentile(99.0));
    }

    #[test]
    fn percentile_after_merge_spans_both_sources() {
        // Per-lane histograms rolled up into a pool-wide view: percentiles
        // of the merged histogram must cover both sources' ranges.
        let mut a = Histogram::new();
        for _ in 0..90 {
            a.record(10);
        }
        let mut b = Histogram::new();
        for _ in 0..10 {
            b.record(5000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        let p50 = a.percentile(50.0);
        assert!(p50 < 64.0, "p50 = {p50} should sit in the low cluster");
        let p99 = a.percentile(99.0);
        assert!(p99 >= 4096.0, "p99 = {p99} should reach the slow cluster");
        assert!(p99 <= 5000.0, "p99 = {p99} clamped to observed max");
    }

    #[test]
    fn bucket_counts_expose_log2_layout() {
        let mut h = Histogram::new();
        h.record(0);
        h.record(1);
        h.record(2);
        h.record(1000);
        let b = h.bucket_counts();
        assert_eq!(b.len(), 64);
        assert_eq!(b[0], 2, "0 and 1 share bucket 0");
        assert_eq!(b[1], 1, "2 lands in [2,4)");
        assert_eq!(b[9], 1, "1000 lands in [512,1024)");
        assert_eq!(h.sum(), 1003);
    }
}
