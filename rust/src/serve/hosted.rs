//! Hosting model bundles inside a serving session.
//!
//! Both servers build **one** session whose graph is the union of every
//! served model's bundle graph, merged under a `{model}/` name prefix.
//! The crate-internal `host_model` performs the merge: it validates the
//! bundle's serving
//! signature (exactly one input and one output endpoint, f32 both ways),
//! rewrites the input placeholder's **leading dimension** to the lane's
//! `max_batch` — batching is along dim 0, whatever the rest of the shape
//! is — and records the merged node names plus per-sample element counts
//! the batcher and completer need. No MNIST geometry anywhere: a bundle
//! with a `[B, 16]` input serves next to one with `[B, 1, 28, 28]`.

use crate::hsa::error::{HsaError, Result};
use crate::serve::batcher::BatchPolicy;
use crate::tf::dtype::DType;
use crate::tf::graph::{Graph, OpKind};
use crate::tf::model::{ModelBundle, SERVE_SIGNATURE};
use std::path::Path;

/// One served model: a lane name, its micro-batching policy, and the
/// bundle (graph + signatures) it executes. Each model gets its own graph
/// subtree (`{name}/...`), batch lane and compiled batch dimension
/// (`batch.max_batch`, which overrides the bundle's exported batch dim).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub batch: BatchPolicy,
    pub bundle: ModelBundle,
    /// Which bundle signature to serve (default `"serve"`).
    pub signature: String,
}

impl ModelSpec {
    /// The built-in MNIST CNN demo bundle under `name` — the historical
    /// default, now just one bundle among any.
    pub fn new(name: impl Into<String>, batch: BatchPolicy) -> ModelSpec {
        ModelSpec::from_bundle(name, ModelBundle::mnist_demo(batch.max_batch), batch)
    }

    pub fn from_bundle(
        name: impl Into<String>,
        bundle: ModelBundle,
        batch: BatchPolicy,
    ) -> ModelSpec {
        ModelSpec {
            name: name.into(),
            batch,
            bundle,
            signature: SERVE_SIGNATURE.to_string(),
        }
    }

    /// Load a bundle directory; the lane takes the bundle's name.
    pub fn from_dir(dir: impl AsRef<Path>, batch: BatchPolicy) -> Result<ModelSpec> {
        let bundle = ModelBundle::load(dir)?;
        Ok(ModelSpec::from_bundle(bundle.name.clone(), bundle, batch))
    }

    pub fn with_signature(mut self, signature: impl Into<String>) -> ModelSpec {
        self.signature = signature.into();
        self
    }
}

/// Public per-model I/O meta, for clients that need to size requests.
/// Carries the served signature and its endpoint *names* as well as the
/// shapes, so network frontends can validate named feeds and list hosted
/// models without reaching into the bundle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelIoMeta {
    /// Name of the signature this lane serves (usually `"serve"`).
    pub signature: String,
    /// Public name of the signature's single input endpoint.
    pub input_name: String,
    /// Public name of the signature's single output endpoint.
    pub output_name: String,
    /// Per-request input shape (the input endpoint's shape minus dim 0).
    pub sample_in_shape: Vec<usize>,
    pub in_elems: usize,
    /// Per-request output shape (the output's shape minus dim 0).
    pub sample_out_shape: Vec<usize>,
    pub out_elems: usize,
}

/// A bundle merged into the serving graph: everything the batcher thread
/// and completers need at flush/retire time.
#[derive(Debug, Clone)]
pub(crate) struct HostedModel {
    pub name: String,
    /// Served signature name and its public endpoint names (what remote
    /// clients feed/fetch by; distinct from the merged node names below).
    pub signature: String,
    pub in_ep_name: String,
    pub out_ep_name: String,
    /// Merged input placeholder node name (`{model}/{node}`).
    pub x_name: String,
    /// Merged output node name.
    pub out_name: String,
    pub max_batch: usize,
    pub sample_in_shape: Vec<usize>,
    pub in_elems: usize,
    /// Full input shape with the batch dim: `[max_batch, sample...]`.
    pub full_in_shape: Vec<usize>,
    /// Per-request output row: filled by [`HostedModel::resolve_output`]
    /// after the merged graph finalizes.
    pub sample_out_shape: Vec<usize>,
    pub out_elems: usize,
    /// Kernels of every compute node in the output's fetch cone, for
    /// eviction-policy demand hints: N queued requests imply N upcoming
    /// dispatches of *each* of these (empty for all-structural graphs).
    pub kernels: Vec<String>,
}

impl HostedModel {
    pub fn io_meta(&self) -> ModelIoMeta {
        ModelIoMeta {
            signature: self.signature.clone(),
            input_name: self.in_ep_name.clone(),
            output_name: self.out_ep_name.clone(),
            sample_in_shape: self.sample_in_shape.clone(),
            in_elems: self.in_elems,
            sample_out_shape: self.sample_out_shape.clone(),
            out_elems: self.out_elems,
        }
    }

    /// After `g.finalize()`: read the output node's inferred shape, check
    /// the batch-along-dim-0 convention, and fill the per-row meta.
    pub fn resolve_output(&mut self, g: &Graph) -> Result<()> {
        let id = g.by_name(&self.out_name).expect("output node was just merged");
        let node = g.node(id);
        let shape = &node.out_shape;
        if shape.first() != Some(&self.max_batch) {
            return Err(HsaError::Runtime(format!(
                "model '{}': output '{}' has shape {shape:?}, which does not batch \
                 along dim 0 (expected leading {})",
                self.name, self.out_name, self.max_batch
            )));
        }
        if node.out_dtype != DType::F32 {
            return Err(HsaError::Runtime(format!(
                "model '{}': output '{}' is {}, the serving pipeline is f32-only \
                 (use tf::model::Model for other dtypes)",
                self.name, self.out_name, node.out_dtype
            )));
        }
        self.sample_out_shape = shape[1..].to_vec();
        self.out_elems = shape[1..].iter().product();
        // Every compute kernel in the output's fetch cone is dispatched
        // once per batch, so all of them carry the lane's queued demand —
        // not just the output node's op (which may even be structural, or
        // a CPU-only tail like a final Relu).
        let live = crate::tf::model::fetch_cone(g, &[id]);
        let mut kernels = Vec::new();
        for node in g.nodes() {
            if live[node.id.0] {
                if let Some(k) = node.op.kernel_name() {
                    if !kernels.contains(&k) {
                        kernels.push(k);
                    }
                }
            }
        }
        self.kernels = kernels;
        Ok(())
    }
}

/// Merge `spec`'s bundle into the shared serving graph under the
/// `{spec.name}/` prefix, overriding the serve input's leading dim with
/// the lane's `max_batch`. Call [`HostedModel::resolve_output`] once the
/// merged graph has been finalized.
pub(crate) fn host_model(g: &mut Graph, spec: &ModelSpec) -> Result<HostedModel> {
    let sig = spec.bundle.signature(&spec.signature)?;
    if sig.inputs.len() != 1 || sig.outputs.len() != 1 {
        return Err(HsaError::Runtime(format!(
            "model '{}': serving needs a single-input/single-output signature, \
             '{}' has {} inputs / {} outputs",
            spec.name,
            spec.signature,
            sig.inputs.len(),
            sig.outputs.len()
        )));
    }
    let in_ep = &sig.inputs[0];
    let out_ep = &sig.outputs[0];
    if in_ep.shape.is_empty() {
        return Err(HsaError::Runtime(format!(
            "model '{}': input endpoint '{}' is a scalar; serving needs a leading \
             batch dimension",
            spec.name, in_ep.name
        )));
    }
    if in_ep.dtype != DType::F32 {
        return Err(HsaError::Runtime(format!(
            "model '{}': input endpoint '{}' is {}, the serving pipeline is f32-only \
             (use tf::model::Model for other dtypes)",
            spec.name, in_ep.name, in_ep.dtype
        )));
    }

    let max_batch = spec.batch.max_batch;
    let sample_in_shape = in_ep.shape[1..].to_vec();
    let mut full_in_shape = Vec::with_capacity(in_ep.shape.len());
    full_in_shape.push(max_batch);
    full_in_shape.extend_from_slice(&sample_in_shape);

    // Merge only the served signature's fetch cone (plus its input
    // placeholder): nodes that exist solely for *other* signatures must
    // not constrain — or even enter — the serving session. Insertion
    // order is topological; node ids shift, so inputs are remapped
    // through the old-id → new-id table.
    let src = &spec.bundle.graph;
    let out_id = src.by_name(&out_ep.node).ok_or_else(|| {
        HsaError::Runtime(format!(
            "model '{}': output endpoint node '{}' not in graph",
            spec.name, out_ep.node
        ))
    })?;
    let in_id = src.by_name(&in_ep.node).ok_or_else(|| {
        HsaError::Runtime(format!(
            "model '{}': input endpoint node '{}' not in graph",
            spec.name, in_ep.node
        ))
    })?;
    let live = crate::tf::model::fetch_cone(src, &[out_id, in_id]);
    let mut idmap = vec![None; src.len()];
    for node in src.nodes() {
        if !live[node.id.0] {
            continue;
        }
        let merged_name = format!("{}/{}", spec.name, node.name);
        let op = if node.name == in_ep.node {
            match &node.op {
                OpKind::Placeholder { dtype, .. } => {
                    OpKind::Placeholder { shape: full_in_shape.clone(), dtype: *dtype }
                }
                // ModelBundle::validate already pinned input endpoints to
                // placeholders; keep a readable error anyway.
                other => {
                    return Err(HsaError::Runtime(format!(
                        "model '{}': input endpoint node '{}' is {other:?}, not a \
                         placeholder",
                        spec.name, in_ep.node
                    )))
                }
            }
        } else {
            node.op.clone()
        };
        let inputs: Vec<_> = node
            .inputs
            .iter()
            .map(|i| idmap[i.0].expect("inputs precede consumers"))
            .collect();
        let id = g.add(merged_name, op, &inputs)?;
        if let Some(d) = node.device {
            g.set_device(id, d);
        }
        idmap[node.id.0] = Some(id);
    }

    Ok(HostedModel {
        name: spec.name.clone(),
        signature: spec.signature.clone(),
        in_ep_name: in_ep.name.clone(),
        out_ep_name: out_ep.name.clone(),
        x_name: format!("{}/{}", spec.name, in_ep.node),
        out_name: format!("{}/{}", spec.name, out_ep.node),
        max_batch,
        in_elems: sample_in_shape.iter().product(),
        sample_in_shape,
        full_in_shape,
        sample_out_shape: Vec::new(),
        out_elems: 0,
        kernels: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn policy(max_batch: usize) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay: Duration::from_millis(1) }
    }

    #[test]
    fn hosting_overrides_the_batch_dim_and_prefixes_names() {
        let mut g = Graph::new();
        // Bundle exported with batch 32; the lane serves batch 4.
        let spec = ModelSpec::from_bundle("tiny", ModelBundle::tiny_fc_demo(32, 16, 4), policy(4));
        let mut h = host_model(&mut g, &spec).unwrap();
        g.finalize().unwrap();
        h.resolve_output(&g).unwrap();
        assert_eq!(h.x_name, "tiny/x");
        assert_eq!(h.out_name, "tiny/y");
        let meta = h.io_meta();
        assert_eq!(meta.signature, "serve");
        assert_eq!(meta.input_name, "x");
        assert_eq!(meta.output_name, "y");
        assert_eq!(h.full_in_shape, vec![4, 16]);
        assert_eq!(h.in_elems, 16);
        assert_eq!(h.sample_out_shape, vec![4]);
        assert_eq!(h.out_elems, 4);
        let x = g.by_name("tiny/x").unwrap();
        assert_eq!(g.node(x).out_shape, vec![4, 16]);
    }

    #[test]
    fn two_models_with_different_shapes_share_one_graph() {
        let mut g = Graph::new();
        let mnist = ModelSpec::new("mnist", policy(8));
        let tiny = ModelSpec::from_bundle("tiny", ModelBundle::tiny_fc_demo(2, 16, 4), policy(2));
        let mut hm = host_model(&mut g, &mnist).unwrap();
        let mut ht = host_model(&mut g, &tiny).unwrap();
        g.finalize().unwrap();
        hm.resolve_output(&g).unwrap();
        ht.resolve_output(&g).unwrap();
        assert_eq!(hm.in_elems, 784);
        assert_eq!(hm.out_elems, 10);
        assert_eq!(ht.in_elems, 16);
        assert_eq!(ht.out_elems, 4);
        assert_eq!(hm.kernels, vec!["mnist_cnn".to_string()]);
        // tiny's cone carries BOTH its kernels (topological order): the
        // relu tail alone would starve the FPGA-placed fc of demand hints.
        assert_eq!(ht.kernels, vec!["fc".to_string(), "relu".to_string()]);
    }

    #[test]
    fn hosting_merges_only_the_served_signatures_cone() {
        use crate::tf::model::{Endpoint, Signature};
        use crate::tf::{DType, Graph as G, OpKind, Tensor};
        // Bundle with a second signature whose cone is pinned to the
        // exported batch dim (Reshape to [32, 16]) — it must neither
        // enter the serving graph nor break the lane's batch override.
        let mut g = G::new();
        let x = g.placeholder("x", &[32, 16], DType::F32).unwrap();
        let w = g.constant("w", Tensor::zeros(&[16, 4], DType::F32)).unwrap();
        let b = g.constant("b", Tensor::zeros(&[4], DType::F32)).unwrap();
        let fc = g.add("fc", OpKind::FullyConnected, &[x, w, b]).unwrap();
        g.add("y", OpKind::Relu, &[fc]).unwrap();
        g.add("debug_view", OpKind::Reshape { shape: vec![16, 32] }, &[x]).unwrap();
        let serve = Signature {
            name: "serve".into(),
            inputs: vec![Endpoint::new("x", "x", &[32, 16], DType::F32)],
            outputs: vec![Endpoint::new("y", "y", &[32, 4], DType::F32)],
        };
        let debug = Signature {
            name: "debug".into(),
            inputs: vec![Endpoint::new("x", "x", &[32, 16], DType::F32)],
            outputs: vec![Endpoint::new("v", "debug_view", &[16, 32], DType::F32)],
        };
        let bundle =
            crate::tf::model::ModelBundle::new("multi", g, vec![serve, debug]).unwrap();

        // Serve at batch 4: the debug Reshape would fail shape inference
        // ([4,16] -> [16,32]) if it were merged; pruning keeps it out.
        let mut host = Graph::new();
        let spec = ModelSpec::from_bundle("multi", bundle, policy(4));
        let mut h = host_model(&mut host, &spec).unwrap();
        host.finalize().unwrap();
        h.resolve_output(&host).unwrap();
        assert!(host.by_name("multi/debug_view").is_none(), "non-cone node merged");
        assert_eq!(h.out_elems, 4);
    }

    #[test]
    fn unknown_signature_is_an_error() {
        let mut g = Graph::new();
        let spec = ModelSpec::new("m", policy(2)).with_signature("train");
        let err = host_model(&mut g, &spec).unwrap_err();
        assert!(err.to_string().contains("train"), "{err}");
    }

    #[test]
    fn non_batching_output_is_rejected_at_resolve() {
        // tiny_fc batches fine; force a mismatch by serving with a batch
        // the convs cannot carry: mnist_layers is rank-3 (no batch dim),
        // so any max_batch != 1 breaks shape inference at finalize.
        let mut g = Graph::new();
        let spec = ModelSpec::from_bundle(
            "layers",
            ModelBundle::mnist_layers_demo(),
            policy(4),
        );
        host_model(&mut g, &spec).unwrap();
        assert!(g.finalize().is_err(), "batch-4 (4,28,28) must fail conv inference");

        // With max_batch = 1 the layered bundle serves (dim 0 is 1).
        let mut g = Graph::new();
        let spec = ModelSpec::from_bundle(
            "layers",
            ModelBundle::mnist_layers_demo(),
            policy(1),
        );
        let mut h = host_model(&mut g, &spec).unwrap();
        g.finalize().unwrap();
        h.resolve_output(&g).unwrap();
        assert_eq!(h.out_elems, 10);
    }
}
