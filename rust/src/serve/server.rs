//! The synchronous inference server: clients submit single flattened
//! input samples; a batcher thread groups them along the model's leading
//! batch dimension and runs the session, padding the final partial batch
//! (the compiled batch dim is `max_batch`, like a real shape-locked
//! bitstream). The model is any loaded [`ModelBundle`] — the default is
//! the built-in MNIST CNN demo.
//!
//! This is the lock-step reference path: exactly one batch is in flight
//! at any moment, so batch formation, kernel execution and reply delivery
//! serialize. [`crate::serve::async_server::AsyncInferenceServer`]
//! overlaps all three — see `benches/serving_throughput.rs` for the
//! difference it makes.

use crate::hsa::error::{HsaError, Result};
use crate::metrics::histogram::Histogram;
use crate::serve::batcher::{Batch, BatchPolicy};
use crate::serve::hosted::{host_model, HostedModel, ModelIoMeta, ModelSpec};
use crate::tf::dtype::DType;
use crate::tf::graph::Graph;
use crate::tf::model::{ModelBundle, SERVE_SIGNATURE};
use crate::tf::session::{Session, SessionOptions};
use crate::tf::tensor::Tensor;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server configuration.
pub struct ServerConfig {
    pub batch: BatchPolicy,
    pub session: SessionOptions,
    /// The model to serve (default: the built-in MNIST CNN demo).
    pub bundle: ModelBundle,
    /// Bundle signature to serve (default `"serve"`).
    pub signature: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch: BatchPolicy::default(),
            session: SessionOptions::default(),
            bundle: ModelBundle::mnist_demo(BatchPolicy::default().max_batch),
            signature: SERVE_SIGNATURE.to_string(),
        }
    }
}

struct Request {
    /// One flattened input sample (`ModelIoMeta::in_elems` f32 values).
    sample: Vec<f32>,
    enqueued: Instant,
    /// Receives one flattened output row.
    reply: mpsc::SyncSender<Result<Vec<f32>>>,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    /// End-to-end request latency in µs.
    pub latency_us_p50: u64,
    pub latency_us_p99: u64,
    pub latency_us_mean: f64,
    /// Time spent compiling the model's execution plan (prewarmed at
    /// startup; steady-state batches replay the cached plan).
    pub plan_compile_us: u64,
    pub reconfig: crate::reconfig::manager::ReconfigStats,
}

struct Shared {
    latency: Histogram,
    requests: u64,
    batches: u64,
    fill_sum: u64,
}

/// A running inference server.
pub struct InferenceServer {
    tx: mpsc::Sender<Option<Request>>,
    worker: Option<JoinHandle<()>>,
    session: Arc<Session>,
    shared: Arc<Mutex<Shared>>,
    info: HostedModel,
}

impl InferenceServer {
    /// Build the session (batch dim = `config.batch.max_batch`, whatever
    /// the bundle was exported with) and start the batcher/worker thread.
    pub fn start(config: ServerConfig) -> Result<InferenceServer> {
        let spec = ModelSpec::from_bundle(
            config.bundle.name.clone(),
            config.bundle,
            config.batch,
        )
        .with_signature(config.signature);
        let mut g = Graph::new();
        let mut info = host_model(&mut g, &spec)?;
        g.finalize()?;
        info.resolve_output(&g)?;
        let session = Arc::new(Session::new(g, config.session)?);
        // Prewarm the plan so the first batch replays instead of compiling.
        let zero = Tensor::zeros(&info.full_in_shape, DType::F32);
        session.warm_plan(&[(info.x_name.as_str(), zero)], &[info.out_name.as_str()])?;

        let (tx, rx) = mpsc::channel::<Option<Request>>();
        let shared = Arc::new(Mutex::new(Shared {
            latency: Histogram::new(),
            requests: 0,
            batches: 0,
            fill_sum: 0,
        }));
        let worker = {
            let session = Arc::clone(&session);
            let shared = Arc::clone(&shared);
            let policy = config.batch;
            let info = info.clone();
            std::thread::Builder::new()
                .name("inference-batcher".into())
                .spawn(move || batcher_loop(rx, session, shared, policy, info))
                .map_err(|e| HsaError::Runtime(format!("spawn batcher: {e}")))?
        };
        Ok(InferenceServer {
            tx,
            worker: Some(worker),
            session,
            shared,
            info,
        })
    }

    /// Submit one flattened input sample; blocks until its output row is
    /// ready.
    pub fn infer(&self, sample: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.infer_async(sample)?;
        rx.recv().map_err(|_| HsaError::Runtime("server dropped request".into()))?
    }

    /// Non-blocking async submit: returns a receiver for the output row.
    pub fn infer_async(
        &self,
        sample: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        if sample.len() != self.info.in_elems {
            return Err(HsaError::Runtime(format!(
                "model '{}': input sample must be {} f32 values (shape {:?}), got {}",
                self.info.name,
                self.info.in_elems,
                self.info.sample_in_shape,
                sample.len()
            )));
        }
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Some(Request { sample, enqueued: Instant::now(), reply }))
            .map_err(|_| HsaError::Runtime("server stopped".into()))?;
        Ok(rx)
    }

    pub fn max_batch(&self) -> usize {
        self.info.max_batch
    }

    /// Per-sample input/output meta of the served model.
    pub fn model_meta(&self) -> ModelIoMeta {
        self.info.io_meta()
    }

    pub fn report(&self) -> ServeReport {
        let s = self.shared.lock().unwrap();
        ServeReport {
            requests: s.requests,
            batches: s.batches,
            mean_batch_fill: if s.batches == 0 {
                0.0
            } else {
                s.fill_sum as f64 / s.batches as f64
            },
            latency_us_p50: s.latency.quantile(0.50),
            latency_us_p99: s.latency.quantile(0.99),
            latency_us_mean: s.latency.mean(),
            plan_compile_us: self.session.plan_cache_stats().compile_us_total,
            reconfig: self.session.reconfig_stats(),
        }
    }

    pub fn stop(&mut self) {
        let _ = self.tx.send(None);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.session.shutdown();
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.stop();
    }
}

enum Msg {
    Req(Request),
    /// Deadline tick (no message arrived before the batch deadline).
    Tick,
    /// Stop sentinel or disconnected channel.
    Stop,
}

fn batcher_loop(
    rx: mpsc::Receiver<Option<Request>>,
    session: Arc<Session>,
    shared: Arc<Mutex<Shared>>,
    policy: BatchPolicy,
    info: HostedModel,
) {
    let mut batch: Batch<Request> = Batch::new(policy);
    loop {
        // Wait for work; with a batch open, wait only until its deadline.
        let msg = match batch.time_left() {
            None => match rx.recv() {
                Ok(Some(r)) => Msg::Req(r),
                Ok(None) | Err(_) => Msg::Stop,
            },
            Some(left) => match rx.recv_timeout(left.max(Duration::from_micros(50))) {
                Ok(Some(r)) => Msg::Req(r),
                Ok(None) => Msg::Stop,
                Err(mpsc::RecvTimeoutError::Timeout) => Msg::Tick,
                Err(mpsc::RecvTimeoutError::Disconnected) => Msg::Stop,
            },
        };
        match msg {
            Msg::Req(r) => {
                // Arm the deadline from the request's true arrival (it may
                // have queued in the submit channel while a batch ran) so
                // channel dwell time cannot silently extend tail latency.
                let arrived = r.enqueued;
                let full = batch.push_at(r, arrived);
                if full || batch.deadline_expired() {
                    flush(&mut batch, &session, &shared, &info);
                }
            }
            Msg::Tick => {
                if batch.deadline_expired() {
                    flush(&mut batch, &session, &shared, &info);
                }
            }
            Msg::Stop => {
                if !batch.is_empty() {
                    flush(&mut batch, &session, &shared, &info);
                }
                break;
            }
        }
    }
}

fn flush(
    batch: &mut Batch<Request>,
    session: &Session,
    shared: &Mutex<Shared>,
    info: &HostedModel,
) {
    let reqs = batch.take();
    let n = reqs.len();
    // Padded to the compiled batch dim.
    let mut data = vec![0f32; info.max_batch * info.in_elems];
    for (i, r) in reqs.iter().enumerate() {
        data[i * info.in_elems..(i + 1) * info.in_elems].copy_from_slice(&r.sample);
    }
    let x = Tensor::from_f32(&info.full_in_shape, data).expect("batch tensor");
    let result = session.run(&[(info.x_name.as_str(), x)], &[info.out_name.as_str()]);
    match result {
        Ok(out) => {
            let rows = out[0].as_f32().expect("f32 output rows");
            let mut s = shared.lock().unwrap();
            for (i, r) in reqs.into_iter().enumerate() {
                let row = rows[i * info.out_elems..(i + 1) * info.out_elems].to_vec();
                s.latency.record(r.enqueued.elapsed().as_micros() as u64);
                s.requests += 1;
                let _ = r.reply.send(Ok(row));
            }
            s.batches += 1;
            s.fill_sum += n as u64;
        }
        Err(e) => {
            let msg = e.to_string();
            let mut s = shared.lock().unwrap();
            for r in reqs {
                s.requests += 1;
                let _ = r.reply.send(Err(HsaError::Runtime(msg.clone())));
            }
            s.batches += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server(max_batch: usize, delay_ms: u64) -> InferenceServer {
        InferenceServer::start(ServerConfig {
            batch: BatchPolicy {
                max_batch,
                max_delay: Duration::from_millis(delay_ms),
            },
            session: SessionOptions::native_only(),
            ..ServerConfig::default()
        })
        .expect("server")
    }

    #[test]
    fn single_request_served_by_deadline() {
        let mut srv = server(8, 5);
        let logits = srv.infer(vec![0.5; 784]).unwrap();
        assert_eq!(logits.len(), 10);
        let rep = srv.report();
        assert_eq!(rep.requests, 1);
        assert_eq!(rep.batches, 1);
        srv.stop();
    }

    #[test]
    fn many_async_requests_batch_up() {
        let mut srv = server(8, 20);
        let rxs: Vec<_> = (0..16)
            .map(|i| srv.infer_async(vec![i as f32 / 16.0; 784]).unwrap())
            .collect();
        for rx in rxs {
            let logits = rx.recv().unwrap().unwrap();
            assert_eq!(logits.len(), 10);
        }
        let rep = srv.report();
        assert_eq!(rep.requests, 16);
        assert!(rep.batches <= 4, "16 requests should need few batches: {rep:?}");
        assert!(rep.mean_batch_fill > 2.0, "{rep:?}");
        srv.stop();
    }

    #[test]
    fn batches_replay_the_prewarmed_plan() {
        let mut srv = server(4, 2);
        let rep0 = srv.report();
        assert!(rep0.plan_compile_us > 0, "prewarm compiles at startup: {rep0:?}");
        for i in 0..3 {
            srv.infer(vec![i as f32 * 0.1; 784]).unwrap();
        }
        let rep = srv.report();
        assert_eq!(
            rep.plan_compile_us, rep0.plan_compile_us,
            "steady-state batches must not recompile: {rep:?}"
        );
        srv.stop();
    }

    #[test]
    fn identical_inputs_identical_outputs_across_batches() {
        let mut srv = server(4, 2);
        let a = srv.infer(vec![0.25; 784]).unwrap();
        let b = srv.infer(vec![0.25; 784]).unwrap();
        assert_eq!(a, b, "padding must not leak across requests");
        srv.stop();
    }

    #[test]
    fn bad_sample_size_rejected_with_expected_meta() {
        let mut srv = server(4, 2);
        let err = srv.infer(vec![0.0; 100]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("784") && msg.contains("100"), "{msg}");
        srv.stop();
    }

    #[test]
    fn serves_a_non_mnist_bundle_shape() {
        let mut srv = InferenceServer::start(ServerConfig {
            batch: BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(2) },
            session: SessionOptions::native_only(),
            bundle: crate::tf::model::ModelBundle::tiny_fc_demo(8, 16, 4),
            ..ServerConfig::default()
        })
        .unwrap();
        let meta = srv.model_meta();
        assert_eq!((meta.in_elems, meta.out_elems), (16, 4));
        let row = srv.infer(vec![0.5; 16]).unwrap();
        assert_eq!(row.len(), 4);
        srv.stop();
    }

    #[test]
    fn stop_is_clean_with_inflight_empty() {
        let mut srv = server(4, 2);
        srv.stop();
        assert!(srv.infer(vec![0.0; 784]).is_err(), "stopped server rejects");
    }
}
