//! The asynchronous batched serving pipeline — the production request
//! path.
//!
//! Where [`super::server::InferenceServer`] runs lock-step (one batch
//! dispatched, *waited on*, and delivered before the next is formed), this
//! server decouples the three stages so they overlap:
//!
//! ```text
//! clients ──▶ LaneSet (shape-bucketed ──▶ batcher thread ──▶ AQL queue
//!             continuous lanes: callers    closes due lanes,  (multi-
//!             decode rows in place via     acquires a          processor:
//!             TensorWriter, wake the       pipeline slot,      kernels run
//!             batcher over an mpsc)        *then* seals the    concurrently
//!                                          batch — arrivals    across PR
//!                                          in between ride     regions)
//!                                          it as late joins
//!                                          run_async ──▶ in-flight channel
//!                                               │
//!                              completer pool ◀─┘  wait on completion
//!                              signals, deliver rows to each caller's
//!                              reply channel, recycle the staging buffer,
//!                              release the pipeline slot — in whatever
//!                              order batches retire
//! ```
//!
//! The batcher never blocks on kernel execution: `Session::run_async`
//! returns as soon as the packet is enqueued, so while batch *n* computes,
//! batch *n+1* is being formed and batch *n-1*'s replies are being
//! delivered. Backpressure is a slot semaphore sized `pipeline_depth`:
//! when the pipeline is full the batcher parks *between* marking a lane
//! closing and sealing its tensor, so the lane keeps admitting same-bucket
//! rows right up to the moment of dispatch (the late-join window).
//! Before each dispatch the batcher publishes per-kernel queue depths to
//! the FPGA eviction policy ([`Session::hint_demand`]), so a `queue-aware`
//! policy won't evict a role the queues are about to need.

use crate::hsa::error::{HsaError, Result};
use crate::metrics::counters::ServeCounters;
use crate::metrics::histogram::Histogram;
use crate::serve::batcher::{BatchPolicy, BucketKey, LaneSet, TakenBatch, TensorWriter};
use crate::serve::hosted::{host_model, HostedModel, ModelIoMeta, ModelSpec};
use crate::tf::dtype::DType;
use crate::tf::graph::Graph;
use crate::tf::session::{PendingRun, Session, SessionOptions};
use crate::tf::tensor::Tensor;
use crate::trace::span::{SpanCtx, Stage};
use std::collections::HashMap;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Async server configuration.
pub struct AsyncServerConfig {
    pub models: Vec<ModelSpec>,
    pub session: SessionOptions,
    /// Max batches in flight past the batcher (pipeline slot semaphore +
    /// completer pool size). The batcher parks when the pipeline is full —
    /// the serving-side backpressure, and the late-join window.
    pub pipeline_depth: usize,
}

impl Default for AsyncServerConfig {
    fn default() -> Self {
        AsyncServerConfig {
            models: vec![ModelSpec::new("mnist", BatchPolicy::default())],
            session: SessionOptions { dispatch_workers: 2, ..Default::default() },
            pipeline_depth: 4,
        }
    }
}

/// Per-request bookkeeping queued in a lane. The input row itself lives
/// in the lane's staging buffer, not here — submitters already decoded it
/// in place through a [`TensorWriter`].
struct Request {
    enqueued: Instant,
    /// Request-scoped span handle: pipeline stages record their slice of
    /// the latency onto it as the request moves through the batcher, the
    /// router and the completer. `SpanCtx::disabled()` for untraced
    /// submits — every recording call is then a no-op branch.
    span: SpanCtx,
    /// Receives one flattened output row (`ModelIoMeta::out_elems` values).
    reply: mpsc::SyncSender<Result<Vec<f32>>>,
}

/// A dispatched batch travelling from the batcher to a completer.
struct InFlight {
    reqs: Vec<Request>,
    pending: PendingRun,
    /// Output elements per request row (completer slices the batch).
    out_elems: usize,
    /// The dispatched input batch plus its feed/fetch names, kept so a
    /// completer can re-dispatch the batch on an alternate agent if the
    /// one it landed on dies mid-flight.
    x: Tensor,
    x_name: String,
    out_name: String,
    /// Lane the staging buffer came from (for recycling on retire).
    lane: usize,
    /// When `run_async` accepted the batch — the start of every member's
    /// `kernel_exec` window (dispatch to retire).
    dispatched_at: Instant,
    /// Pool-wide reconfiguration stall total at dispatch time; the delta
    /// at completion attributes ICAP stall time to this batch's spans.
    stall_us_base: u64,
}

/// Counting semaphore bounding batches in flight. Unlike the old bounded
/// in-flight channel, acquisition happens *before* the batch tensor is
/// sealed — which is what holds the late-join window open under
/// backpressure.
struct Slots {
    avail: Mutex<usize>,
    cv: Condvar,
}

impl Slots {
    fn new(n: usize) -> Slots {
        Slots { avail: Mutex::new(n), cv: Condvar::new() }
    }

    fn acquire(&self) {
        let mut avail = self.avail.lock().unwrap();
        while *avail == 0 {
            avail = self.cv.wait(avail).unwrap();
        }
        *avail -= 1;
    }

    fn release(&self) {
        *self.avail.lock().unwrap() += 1;
        self.cv.notify_one();
    }
}

struct StatsInner {
    latency: Histogram,
}

/// Aggregate statistics of the async pipeline.
#[derive(Debug, Clone)]
pub struct AsyncServeReport {
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    /// Fraction of dispatched batch capacity that carried real requests
    /// (fill_sum / fill_capacity over every dispatch).
    pub batch_fill_ratio: f64,
    /// Requests admitted into a lane after its flush had already begun —
    /// they rode the in-flight batch instead of waiting a cycle.
    pub late_joins: u64,
    /// Bytes that took an extra host-memory copy on the way into a batch
    /// tensor (owned-`Vec` submits, overflow tail moves). The wire paths
    /// decode straight into the staging buffer and record nothing here.
    pub bytes_copied: u64,
    /// High-water mark of batches simultaneously in flight — >1 proves
    /// the pipeline actually overlapped dispatches.
    pub max_inflight: u64,
    pub latency_us_p50: u64,
    pub latency_us_p99: u64,
    pub latency_us_mean: f64,
    /// Total time the session spent compiling execution plans (startup
    /// prewarm plus any later cache misses) — same meaning as the sync
    /// server's field. Note the async hot path dispatches via `run_async`'s
    /// tail fast path; cached plans only serve its synchronous fallback.
    pub plan_compile_us: u64,
    /// Pooled rollup over every FPGA agent (== the single agent's stats
    /// at pool size 1).
    pub reconfig: crate::reconfig::manager::ReconfigStats,
    /// Per-agent shard accounting (dispatches routed, in-flight
    /// high-water, per-agent reconfig stats), in pool order. One entry
    /// for the default single-device session.
    pub pool: Vec<crate::sharding::ShardAgentReport>,
}

enum Msg {
    /// Something changed (row admitted): scan lanes for due batches.
    Wake,
    Stop,
}

/// A running asynchronous inference server.
pub struct AsyncInferenceServer {
    tx: mpsc::Sender<Msg>,
    lanes: Arc<LaneSet<Request>>,
    batcher: Option<JoinHandle<()>>,
    completers: Vec<JoinHandle<()>>,
    session: Arc<Session>,
    stats: Arc<Mutex<StatsInner>>,
    counters: Arc<ServeCounters>,
    metas: HashMap<String, ModelIoMeta>,
}

impl AsyncInferenceServer {
    /// Build one session hosting every model's merged bundle subgraph and
    /// start the batcher thread plus `pipeline_depth` completer threads.
    pub fn start(config: AsyncServerConfig) -> Result<AsyncInferenceServer> {
        if config.models.is_empty() {
            return Err(HsaError::Runtime("no models configured".into()));
        }
        let mut g = Graph::new();
        let mut infos: HashMap<String, HostedModel> = HashMap::new();
        let mut lanes: LaneSet<Request> = LaneSet::new();
        for spec in &config.models {
            if infos.contains_key(&spec.name) {
                return Err(HsaError::Runtime(format!(
                    "duplicate model '{}'",
                    spec.name
                )));
            }
            let hosted = host_model(&mut g, spec)?;
            lanes.add_lane(
                spec.name.clone(),
                BucketKey::new(&spec.name, &hosted.signature, &hosted.sample_in_shape),
                spec.batch,
                hosted.in_elems,
            );
            infos.insert(spec.name.clone(), hosted);
        }
        g.finalize()?;
        for info in infos.values_mut() {
            info.resolve_output(&g)?;
        }
        let metas: HashMap<String, ModelIoMeta> =
            infos.iter().map(|(name, info)| (name.clone(), info.io_meta())).collect();
        let session = Arc::new(Session::new(g, config.session)?);
        let lanes = Arc::new(lanes);

        let depth = config.pipeline_depth.max(1);
        let slots = Arc::new(Slots::new(depth));
        let (tx, submit_rx) = mpsc::channel::<Msg>();
        let (inflight_tx, inflight_rx) = mpsc::sync_channel::<InFlight>(depth);
        let inflight_rx = Arc::new(Mutex::new(inflight_rx));
        let stats = Arc::new(Mutex::new(StatsInner { latency: Histogram::new() }));
        let counters = Arc::new(ServeCounters::new());

        // Prewarm every model's execution plan. Honest caveat: for
        // single-device-tail bundle graphs (one placed op fed by
        // structural ops, e.g. the MNIST demo) the steady-state request
        // path is `run_async`'s tail fast path, which never consults the
        // plan cache — the cached plans serve the synchronous fallback,
        // i.e. every multi-op bundle. The prewarm is one cheap compile per
        // model at startup and puts a compile-time figure in the report.
        // Warmed in name order: compile-time folding issues real (routed)
        // dispatches, so a deterministic order keeps multi-agent runs
        // reproducible.
        let mut warm_order: Vec<&HostedModel> = infos.values().collect();
        warm_order.sort_by(|a, b| a.name.cmp(&b.name));
        for info in warm_order {
            let zero = Tensor::zeros(&info.full_in_shape, DType::F32);
            let fetches = [info.out_name.as_str()];
            let us = session.warm_plan(&[(info.x_name.as_str(), zero)], &fetches)?;
            counters.on_plan_compile(us);
        }

        let batcher = {
            let session = Arc::clone(&session);
            let counters = Arc::clone(&counters);
            let lanes = Arc::clone(&lanes);
            let slots = Arc::clone(&slots);
            std::thread::Builder::new()
                .name("serve-batcher".into())
                .spawn(move || {
                    batcher_loop(
                        submit_rx,
                        inflight_tx,
                        session,
                        counters,
                        lanes,
                        infos,
                        slots,
                    )
                })
                .map_err(|e| HsaError::Runtime(format!("spawn batcher: {e}")))?
        };
        let completers = (0..depth)
            .map(|i| {
                let rx = Arc::clone(&inflight_rx);
                let stats = Arc::clone(&stats);
                let counters = Arc::clone(&counters);
                let session = Arc::clone(&session);
                let lanes = Arc::clone(&lanes);
                let slots = Arc::clone(&slots);
                std::thread::Builder::new()
                    .name(format!("serve-completer-{i}"))
                    .spawn(move || {
                        completer_loop(rx, stats, counters, session, lanes, slots)
                    })
                    .map_err(|e| HsaError::Runtime(format!("spawn completer: {e}")))
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(AsyncInferenceServer {
            tx,
            lanes,
            batcher: Some(batcher),
            completers,
            session,
            stats,
            counters,
            metas,
        })
    }

    /// Per-sample input/output meta of a served model (how many f32s a
    /// request must carry and a reply row will hold).
    pub fn model_meta(&self, model: &str) -> Option<&ModelIoMeta> {
        self.metas.get(model)
    }

    /// Names of every hosted model, sorted — the stable iteration order
    /// the HTTP listing and metrics endpoints rely on.
    pub fn models(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.metas.keys().map(String::as_str).collect();
        names.sort_unstable();
        names
    }

    /// Point-in-time pipeline counters (includes the live in-flight gauge
    /// that [`AsyncInferenceServer::report`] does not carry).
    pub fn counters(&self) -> crate::metrics::counters::CounterSnapshot {
        self.counters.snapshot()
    }

    /// The hosting session — chaos/bench harnesses reach the shard router
    /// and pool agents (fault injection, health probes) through this.
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// Submit one flattened input sample to `model`; blocks until its
    /// output row is ready.
    pub fn infer(&self, model: &str, sample: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.infer_async(model, sample)?;
        rx.recv().map_err(|_| HsaError::Runtime("server dropped request".into()))?
    }

    /// Non-blocking submit: returns a receiver that yields the flattened
    /// output row whenever the request's batch retires (completion order,
    /// not submission order).
    ///
    /// This is the *copy-through* convenience path: the owned `sample` is
    /// copied into the lane staging buffer (and the copy is recorded in
    /// the bytes-copied counter). Wire handlers that can decode in place
    /// use [`AsyncInferenceServer::infer_async_with`] instead.
    pub fn infer_async(
        &self,
        model: &str,
        sample: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let Some(meta) = self.metas.get(model) else {
            let known: Vec<&str> = self.metas.keys().map(String::as_str).collect();
            return Err(HsaError::Runtime(format!(
                "unknown model '{model}' (serving: {known:?})"
            )));
        };
        if sample.len() != meta.in_elems {
            return Err(HsaError::Runtime(format!(
                "model '{model}': input sample must be {} f32 values (shape {:?}), got {}",
                meta.in_elems,
                meta.sample_in_shape,
                sample.len()
            )));
        }
        self.counters
            .on_bytes_copied((sample.len() * std::mem::size_of::<f32>()) as u64);
        self.infer_async_with(model, move |w| {
            w.extend_from_slice(&sample);
            Ok(())
        })
    }

    /// Zero-copy submit: `fill` receives a [`TensorWriter`] positioned at
    /// the tail of `model`'s lane staging buffer — the very allocation
    /// that becomes the dispatched batch tensor — and must write exactly
    /// the model's per-sample element count. On a fill error the lane
    /// rolls back and the error string is surfaced verbatim, so wire
    /// decoders can report protocol problems through it.
    ///
    /// If the lane's flush has already begun, the row still rides the
    /// outgoing batch (a *late join*) rather than waiting a full cycle.
    pub fn infer_async_with(
        &self,
        model: &str,
        fill: impl FnOnce(&mut TensorWriter<'_>) -> std::result::Result<(), String>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        self.infer_async_spanned(model, SpanCtx::disabled(), fill)
    }

    /// [`AsyncInferenceServer::infer_async_with`] carrying a request span:
    /// the batcher, router and completer record `batch_wait`,
    /// `batch_assembly`, `route`, `reconfig_stall` and `kernel_exec`
    /// stages onto it as the request moves through the pipeline. The
    /// caller keeps its own clone of the span — the breakdown is complete
    /// by the time the reply receiver yields.
    pub fn infer_async_spanned(
        &self,
        model: &str,
        span: SpanCtx,
        fill: impl FnOnce(&mut TensorWriter<'_>) -> std::result::Result<(), String>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        if !self.metas.contains_key(model) {
            let known: Vec<&str> = self.metas.keys().map(String::as_str).collect();
            return Err(HsaError::Runtime(format!(
                "unknown model '{model}' (serving: {known:?})"
            )));
        }
        let (reply, rx) = mpsc::sync_channel(1);
        let now = Instant::now();
        let late_marker = span.clone();
        let receipt = self
            .lanes
            .submit(model, now, Request { enqueued: now, span, reply }, fill)
            .map_err(HsaError::Runtime)?;
        self.counters.on_submit();
        if receipt.late_join {
            self.counters.on_late_joins(1);
            late_marker.annotate("late_join");
        }
        self.tx
            .send(Msg::Wake)
            .map_err(|_| HsaError::Runtime("server stopped".into()))?;
        Ok(rx)
    }

    pub fn report(&self) -> AsyncServeReport {
        let c = self.counters.snapshot();
        let s = self.stats.lock().unwrap();
        AsyncServeReport {
            requests: c.submitted,
            completed: c.completed,
            failed: c.failed,
            batches: c.batches,
            mean_batch_fill: c.mean_batch_fill(),
            batch_fill_ratio: c.batch_fill_ratio(),
            late_joins: c.late_joins,
            bytes_copied: c.bytes_copied,
            max_inflight: c.max_inflight,
            latency_us_p50: s.latency.quantile(0.50),
            latency_us_p99: s.latency.quantile(0.99),
            latency_us_mean: s.latency.mean(),
            // Sourced from the session (not the counters) so steady-state
            // cache-miss compiles are included, matching the sync server.
            plan_compile_us: self.session.plan_cache_stats().compile_us_total,
            reconfig: self.session.reconfig_stats(),
            pool: self.session.shard_stats(),
        }
    }

    /// Drain the pipeline (queued lanes flush, in-flight batches retire,
    /// replies deliver), then stop every thread and shut the session down.
    pub fn stop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        // The batcher dropped its in-flight sender: completers finish the
        // remaining batches and exit on the closed channel.
        for c in self.completers.drain(..) {
            let _ = c.join();
        }
        self.session.shutdown();
    }
}

impl Drop for AsyncInferenceServer {
    fn drop(&mut self) {
        self.stop();
    }
}

#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    rx: mpsc::Receiver<Msg>,
    inflight_tx: mpsc::SyncSender<InFlight>,
    session: Arc<Session>,
    counters: Arc<ServeCounters>,
    lanes: Arc<LaneSet<Request>>,
    infos: HashMap<String, HostedModel>,
    slots: Arc<Slots>,
) {
    loop {
        let msg = match lanes.next_deadline() {
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => Msg::Stop,
            },
            Some(left) => match rx.recv_timeout(left.max(Duration::from_micros(50))) {
                Ok(m) => m,
                Err(mpsc::RecvTimeoutError::Timeout) => Msg::Wake, // deadline tick
                Err(mpsc::RecvTimeoutError::Disconnected) => Msg::Stop,
            },
        };
        match msg {
            Msg::Wake => {
                flush_ready(&lanes, &infos, &session, &counters, &inflight_tx, &slots);
            }
            Msg::Stop => {
                for batch in lanes.drain() {
                    slots.acquire();
                    dispatch(batch, &infos, &session, &counters, &inflight_tx, &slots);
                }
                // Lanes are empty now; clear any outstanding demand hints.
                publish_demand(&lanes, &infos, &session);
                break;
            }
        }
    }
    // inflight_tx drops here; completers drain and exit.
}

/// Flush every due lane. Demand hints are published before the flush (so
/// the policy sees what is about to be dispatched while the dispatches
/// reconfigure) and re-published after it — the second pass reports the
/// drained lanes as 0, clearing stale hints so an idle role does not stay
/// artificially protected forever.
///
/// Per lane the order is: mark closing → acquire a pipeline slot → seal.
/// The acquire is the backpressure point, and because the lane is sealed
/// *after* it, every row admitted while the pipeline was full rides this
/// very batch (late joins) instead of waiting out another flush cycle.
fn flush_ready(
    lanes: &LaneSet<Request>,
    infos: &HashMap<String, HostedModel>,
    session: &Arc<Session>,
    counters: &Arc<ServeCounters>,
    inflight_tx: &mpsc::SyncSender<InFlight>,
    slots: &Slots,
) {
    publish_demand(lanes, infos, session);
    let mut flushed = false;
    while let Some(idx) = lanes.ready() {
        lanes.begin_close(idx);
        slots.acquire();
        match lanes.take(idx) {
            Some(batch) => {
                dispatch(batch, infos, session, counters, inflight_tx, slots);
                flushed = true;
            }
            None => slots.release(),
        }
    }
    if flushed {
        publish_demand(lanes, infos, session);
    }
}

/// Aggregate lane depths per kernel and hand them to the FPGA policy. A
/// model's queued requests count toward *every* kernel in its fetch cone
/// (each is dispatched once per batch); the hint no-ops for kernels with
/// no FPGA implementation.
fn publish_demand(
    lanes: &LaneSet<Request>,
    infos: &HashMap<String, HostedModel>,
    session: &Session,
) {
    // Ordered map: hints reach the policies and the shard router in a
    // deterministic (name-sorted) order, so multi-agent placement — which
    // reads the demand table — is reproducible for a given request trace.
    let mut per_kernel: std::collections::BTreeMap<&str, u64> =
        std::collections::BTreeMap::new();
    for (model, queued) in lanes.queued_by_model() {
        if let Some(info) = infos.get(&model) {
            for kernel in &info.kernels {
                *per_kernel.entry(kernel.as_str()).or_insert(0) += queued as u64;
            }
        }
    }
    for (kernel, queued) in per_kernel {
        session.hint_demand(kernel, queued);
    }
    // With prefetch enabled, the freshly published queue depths double as
    // a prefetch signal: start background loads for the hottest roles so
    // the batches now waiting in the lanes dispatch onto warm regions.
    session.prefetch_hot();
}

/// Seal one taken batch into its tensor and push it down the pipeline.
/// Holds the pipeline slot the caller acquired: on success its ownership
/// transfers to the completer that retires the batch; every failure path
/// releases it here.
fn dispatch(
    batch: TakenBatch<Request>,
    infos: &HashMap<String, HostedModel>,
    session: &Arc<Session>,
    counters: &Arc<ServeCounters>,
    inflight_tx: &mpsc::SyncSender<InFlight>,
    slots: &Slots,
) {
    let TakenBatch { lane, model, capacity, items, mut data, bytes_copied, taken_at, .. } =
        batch;
    // Overflow tails moved back to staging are real copies: surface them.
    counters.on_bytes_copied(bytes_copied);
    // Each member's batch_wait is its own arrival → the batch seal; the
    // arrival instants are consumed here, so this is the last place the
    // per-request queue wait can be attributed.
    let reqs: Vec<Request> = items
        .into_iter()
        .map(|(r, arrived)| {
            r.span.record_stage(
                Stage::BatchWait,
                taken_at.saturating_duration_since(arrived).as_micros() as u64,
            );
            r
        })
        .collect();
    let traced = reqs.iter().any(|r| r.span.enabled());
    let info = match infos.get(&model) {
        Some(i) => i,
        None => {
            slots.release();
            fail_all(reqs, "model vanished", counters);
            return;
        }
    };
    // Pad the final partial batch to the compiled batch dimension. The
    // rows themselves were decoded straight into `data` by the
    // submitters' TensorWriters — this is the first and only time the
    // batch's memory is touched by the serving pipeline.
    let assembly_start = Instant::now();
    data.resize(capacity * info.in_elems, 0.0);
    let x = match Tensor::from_f32(&info.full_in_shape, data) {
        Ok(t) => t,
        Err(e) => {
            slots.release();
            fail_all(reqs, &e.to_string(), counters);
            return;
        }
    };
    let assembly_us = assembly_start.elapsed().as_micros() as u64;
    for r in &reqs {
        r.span.record_stage(Stage::BatchAssembly, assembly_us);
    }
    let stall_us_base = if traced { session.reconfig_stats().stall_us } else { 0 };
    let route_start = Instant::now();
    match session.run_async(&[(info.x_name.as_str(), x.clone())], &[info.out_name.as_str()])
    {
        Ok(pending) => {
            let route_us = route_start.elapsed().as_micros() as u64;
            let route_slot = pending.route_slot();
            for r in &reqs {
                r.span.record_stage(Stage::Route, route_us);
                if r.span.enabled() {
                    if let Some(slot) = route_slot {
                        r.span.annotate(format!("route -> fpga agent {slot}"));
                    }
                }
            }
            counters.on_batch_dispatch(reqs.len() as u64, capacity as u64);
            // The slot semaphore admits at most `depth` batches past this
            // point, so the send never blocks (channel capacity == depth).
            if let Err(mpsc::SendError(inf)) = inflight_tx.send(InFlight {
                reqs,
                pending,
                out_elems: info.out_elems,
                x,
                x_name: info.x_name.clone(),
                out_name: info.out_name.clone(),
                lane,
                dispatched_at: Instant::now(),
                stall_us_base,
            }) {
                // Completers are gone (server tearing down mid-dispatch).
                slots.release();
                counters.on_batch_complete(0, inf.reqs.len() as u64);
                fail_requests(inf.reqs, "server stopped");
            }
        }
        Err(e) => {
            slots.release();
            fail_all(reqs, &e.to_string(), counters);
        }
    }
}

/// Reject a batch that never entered the pipeline: counts only failures,
/// leaving the batch/fill/in-flight gauges untouched.
fn fail_all(reqs: Vec<Request>, msg: &str, counters: &Arc<ServeCounters>) {
    counters.on_failed(reqs.len() as u64);
    fail_requests(reqs, msg);
}

fn fail_requests(reqs: Vec<Request>, msg: &str) {
    for r in reqs {
        let _ = r.reply.send(Err(HsaError::Runtime(msg.to_string())));
    }
}

/// Wait out one dispatched batch, retrying it on an alternate agent when
/// the one it landed on dies mid-flight. The completion signal is probed
/// in health-policy slices; between slices the router health-checks the
/// pool, so a wedged agent is quarantined long before the full dispatch
/// timeout. A dispatch caught on a quarantined agent is abandoned (its
/// signal + route guard parked as a router zombie, keeping the agent's
/// load gauge truthful until the stall resolves) and re-dispatched — the
/// router's eligibility mask steers the retry to a healthy agent. Bounded
/// by the health policy's retry budget and the overall dispatch deadline.
fn wait_with_retry(
    session: &Session,
    mut pending: PendingRun,
    x: &Tensor,
    x_name: &str,
    out_name: &str,
) -> Result<Vec<Tensor>> {
    let deadline = Instant::now() + crate::hsa::runtime::DISPATCH_TIMEOUT;
    let router = session.router();
    let policy = router.health_policy().clone();
    let mut attempts: u32 = 0;
    loop {
        let mut wedged = false;
        if let Some(sig) = pending.signal() {
            loop {
                if sig.wait_eq(0, Some(policy.probe_interval)).is_ok() {
                    break;
                }
                router.check_health();
                if pending.route_slot().is_some_and(|s| router.is_quarantined(s))
                    && attempts < policy.max_retries
                    && Instant::now() < deadline
                {
                    wedged = true;
                    break;
                }
                if Instant::now() >= deadline {
                    return Err(HsaError::SignalTimeout(
                        crate::hsa::runtime::DISPATCH_TIMEOUT,
                    ));
                }
            }
        }
        if wedged {
            if let Some(slot) = pending.route_slot() {
                router.note_retry(slot);
            }
            if let Some((sig, Some(guard))) = pending.abandon_for_retry() {
                router.park_zombie(sig, guard);
            }
        } else {
            // Signal retired (or the run completed synchronously).
            match pending.wait(Some(Duration::from_millis(50))) {
                Ok(outs) => return Ok(outs),
                Err(e) => {
                    let retryable = e.indicates_agent_down()
                        && attempts < policy.max_retries
                        && Instant::now() < deadline;
                    if !retryable {
                        return Err(e);
                    }
                    // The agent reported itself down. The sync-fallback
                    // path does not know its slot, so attribute by name.
                    if let Some(name) = e.agent_down_name() {
                        if let Some(slot) = router.quarantine_named(name) {
                            router.note_retry(slot);
                        }
                    }
                }
            }
        }
        attempts += 1;
        pending = session.run_async(&[(x_name, x.clone())], &[out_name])?;
    }
}

fn completer_loop(
    rx: Arc<Mutex<mpsc::Receiver<InFlight>>>,
    stats: Arc<Mutex<StatsInner>>,
    counters: Arc<ServeCounters>,
    session: Arc<Session>,
    lanes: Arc<LaneSet<Request>>,
    slots: Arc<Slots>,
) {
    loop {
        // Hold the receiver lock only for the handoff: while this thread
        // waits on a completion signal, peers pick up other batches — this
        // is what makes delivery completion-ordered.
        let inf = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(inf) => inf,
                Err(_) => break,
            }
        };
        let InFlight {
            reqs,
            pending,
            out_elems,
            x,
            x_name,
            out_name,
            lane,
            dispatched_at,
            stall_us_base,
        } = inf;
        let n = reqs.len();
        match wait_with_retry(&session, pending, &x, &x_name, &out_name).and_then(|outs| {
            outs[0].as_f32().map(|v| v.to_vec()).map_err(HsaError::from)
        }) {
            Ok(rows) => {
                // Attribute the dispatch→retire window to the batch's
                // spans: the whole window is kernel_exec, and the pool's
                // stall-total delta over it is the (overlapping) ICAP
                // reconfiguration share. Always emitted — a clean hit
                // shows reconfig_stall = 0 rather than no span at all.
                if reqs.iter().any(|r| r.span.enabled()) {
                    let kernel_us = dispatched_at.elapsed().as_micros() as u64;
                    let stall_us = session
                        .reconfig_stats()
                        .stall_us
                        .saturating_sub(stall_us_base);
                    for r in &reqs {
                        r.span.record_stage(Stage::ReconfigStall, stall_us.min(kernel_us));
                        r.span.record_stage(Stage::KernelExec, kernel_us);
                    }
                }
                // Account the batch *before* delivering replies, so a
                // caller who reads `report()` right after its reply
                // arrives sees itself counted.
                {
                    let mut s = stats.lock().unwrap();
                    for r in &reqs {
                        s.latency.record(r.enqueued.elapsed().as_micros() as u64);
                    }
                }
                counters.on_batch_complete(n as u64, 0);
                for (i, r) in reqs.into_iter().enumerate() {
                    let row = rows[i * out_elems..(i + 1) * out_elems].to_vec();
                    let _ = r.reply.send(Ok(row));
                }
            }
            Err(e) => {
                counters.on_batch_complete(0, n as u64);
                fail_requests(reqs, &e.to_string());
            }
        }
        // The batch retired: if nothing else still references the input
        // tensor's storage, hand the allocation back to its lane so the
        // next batch decodes into warm memory instead of a fresh alloc.
        if let Some(buf) = x.try_take_f32() {
            lanes.recycle(lane, buf);
        }
        // Decay queued-demand hints now that a batch retired, so roles
        // that were hot a thousand batches ago stop outranking the roles
        // the current traffic actually needs.
        session.note_batch_retired();
        slots.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::server::{InferenceServer, ServerConfig};

    fn policy(max_batch: usize, delay_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay: Duration::from_millis(delay_ms) }
    }

    fn single_model(max_batch: usize, delay_ms: u64, depth: usize) -> AsyncInferenceServer {
        AsyncInferenceServer::start(AsyncServerConfig {
            models: vec![ModelSpec::new("mnist", policy(max_batch, delay_ms))],
            session: SessionOptions {
                dispatch_workers: 2,
                ..SessionOptions::native_only()
            },
            pipeline_depth: depth,
        })
        .expect("server")
    }

    #[test]
    fn deadline_flush_serves_single_request() {
        let mut srv = single_model(8, 5, 2);
        let logits = srv.infer("mnist", vec![0.5; 784]).unwrap();
        assert_eq!(logits.len(), 10);
        let rep = srv.report();
        assert_eq!(rep.requests, 1);
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.batches, 1, "partial batch flushed by deadline");
        assert!((rep.batch_fill_ratio - 1.0 / 8.0).abs() < 1e-9, "{rep:?}");
        assert!(
            rep.plan_compile_us > 0,
            "startup prewarm must surface plan compile time: {rep:?}"
        );
        srv.stop();
    }

    #[test]
    fn capacity_flush_batches_without_waiting_for_deadline() {
        // Deadline far out: only the size trigger can flush.
        let mut srv = single_model(8, 5_000, 4);
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..16)
            .map(|i| srv.infer_async("mnist", vec![i as f32 / 16.0; 784]).unwrap())
            .collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().unwrap().len(), 10);
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "size-triggered flush must not wait out the 5 s deadline"
        );
        let rep = srv.report();
        assert_eq!(rep.requests, 16);
        assert_eq!(rep.batches, 2, "16 requests = two full batches of 8");
        assert!((rep.mean_batch_fill - 8.0).abs() < 1e-9, "{rep:?}");
        assert!((rep.batch_fill_ratio - 1.0).abs() < 1e-9, "{rep:?}");
        srv.stop();
    }

    #[test]
    fn copy_through_submit_records_bytes_copied() {
        let mut srv = single_model(8, 2, 2);
        srv.infer("mnist", vec![0.25; 784]).unwrap();
        let rep = srv.report();
        assert!(
            rep.bytes_copied >= 784 * 4,
            "owned-Vec submit must surface its copy: {rep:?}"
        );
        srv.stop();
    }

    #[test]
    fn zero_copy_submit_writes_in_place() {
        let mut srv = single_model(4, 2, 2);
        let rx = srv
            .infer_async_with("mnist", |w| {
                assert_eq!(w.expected(), 784);
                for i in 0..784 {
                    w.push(i as f32 / 784.0);
                }
                Ok(())
            })
            .unwrap();
        assert_eq!(rx.recv().unwrap().unwrap().len(), 10);
        let rep = srv.report();
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.bytes_copied, 0, "in-place decode must not copy: {rep:?}");
        // Wrong arity rolls back and surfaces the writer error.
        let err = srv
            .infer_async_with("mnist", |w| {
                w.push(1.0);
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("784"), "{err}");
        // Unknown models are still named with the serving list.
        let err = srv.infer_async_with("nope", |_| Ok(())).unwrap_err();
        assert!(err.to_string().contains("serving"), "{err}");
        srv.stop();
    }

    #[test]
    fn out_of_order_completion_delivers_to_correct_callers() {
        // Two models sharing weights: "slow" pads every batch to 32 images
        // of compute, "fast" to 1 — so a fast batch dispatched *after* a
        // slow one retires *before* it, and replies must still land on
        // the right callers.
        let mut srv = AsyncInferenceServer::start(AsyncServerConfig {
            models: vec![
                ModelSpec::new("slow", policy(32, 1)),
                ModelSpec::new("fast", policy(1, 1)),
            ],
            session: SessionOptions {
                dispatch_workers: 4,
                ..SessionOptions::native_only()
            },
            pipeline_depth: 4,
        })
        .unwrap();

        // Reference logits from the synchronous server (identical
        // deterministic weights in every PJRT-free session).
        let mut reference = InferenceServer::start(ServerConfig {
            batch: policy(4, 2),
            session: SessionOptions::native_only(),
            ..ServerConfig::default()
        })
        .unwrap();
        let images: Vec<Vec<f32>> =
            (0..6).map(|i| vec![0.1 * (i + 1) as f32; 784]).collect();
        let expected: Vec<Vec<f32>> =
            images.iter().map(|im| reference.infer(im.clone()).unwrap()).collect();

        // Interleave: slow model first, then a burst on the fast lane.
        let slow_rx = srv.infer_async("slow", images[0].clone()).unwrap();
        let fast_rxs: Vec<_> = images[1..]
            .iter()
            .map(|im| srv.infer_async("fast", im.clone()).unwrap())
            .collect();
        for (rx, want) in fast_rxs.into_iter().zip(&expected[1..]) {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(&got, want, "fast-lane reply crossed callers");
        }
        let got = slow_rx.recv().unwrap().unwrap();
        assert_eq!(&got, &expected[0], "slow-lane reply crossed callers");
        srv.stop();
        reference.stop();
    }

    #[test]
    fn pipeline_keeps_multiple_batches_in_flight() {
        let mut srv = single_model(1, 1, 4);
        let rxs: Vec<_> = (0..12)
            .map(|i| srv.infer_async("mnist", vec![i as f32 / 12.0; 784]).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let rep = srv.report();
        assert_eq!(rep.completed, 12);
        assert_eq!(rep.batches, 12);
        assert!(
            rep.max_inflight >= 2,
            "batch-1 burst should overlap dispatches: {rep:?}"
        );
        srv.stop();
    }

    #[test]
    fn unknown_model_rejected_and_bad_sample_rejected() {
        let mut srv = single_model(4, 2, 2);
        assert!(srv.infer("nope", vec![0.0; 784]).is_err());
        let err = srv.infer_async("mnist", vec![0.0; 100]).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("mnist") && msg.contains("784") && msg.contains("100"),
            "error must name the model and expected vs got sizes: {msg}"
        );
        srv.stop();
    }

    #[test]
    fn serves_two_bundles_with_different_input_shapes() {
        use crate::tf::model::{Model, ModelBundle};
        let tiny = ModelBundle::tiny_fc_demo(4, 16, 4);
        let mut srv = AsyncInferenceServer::start(AsyncServerConfig {
            models: vec![
                ModelSpec::new("mnist", policy(2, 2)),
                ModelSpec::from_bundle("tiny", tiny.clone(), policy(2, 2)),
            ],
            session: SessionOptions {
                dispatch_workers: 2,
                ..SessionOptions::native_only()
            },
            pipeline_depth: 2,
        })
        .unwrap();

        let meta = srv.model_meta("tiny").unwrap().clone();
        assert_eq!((meta.in_elems, meta.out_elems), (16, 4));
        assert_eq!(meta.sample_in_shape, vec![16]);
        assert_eq!(srv.model_meta("mnist").unwrap().in_elems, 784);

        let logits = srv.infer("mnist", vec![0.1; 784]).unwrap();
        assert_eq!(logits.len(), 10);
        let sample: Vec<f32> = (0..16).map(|i| i as f32 * 0.1 - 0.8).collect();
        let row = srv.infer("tiny", sample.clone()).unwrap();
        assert_eq!(row.len(), 4);
        srv.stop();

        // The served row must equal a direct Model invocation of the same
        // bundle (row-independent FC: padding rows cannot bleed in).
        let model = Model::from_bundle(tiny, SessionOptions::native_only()).unwrap();
        let mut data = vec![0f32; 4 * 16];
        data[..16].copy_from_slice(&sample);
        let x = Tensor::from_f32(&[4, 16], data).unwrap();
        let want = model.invoke("serve", &[("x", x)]).unwrap();
        assert_eq!(&want[0].as_f32().unwrap()[..4], row.as_slice());
        model.shutdown();
    }

    #[test]
    fn pooled_server_shards_batches_and_reports_per_agent() {
        use crate::sharding::ShardStrategy;
        let mut srv = AsyncInferenceServer::start(AsyncServerConfig {
            models: vec![ModelSpec::new("mnist", policy(1, 1))],
            session: SessionOptions {
                fpga_pool: 2,
                shard_strategy: ShardStrategy::RoundRobin,
                dispatch_workers: 1,
                ..SessionOptions::native_only()
            },
            pipeline_depth: 4,
        })
        .unwrap();
        // Batch 1 → every request is its own dispatch; round robin puts
        // half on each agent.
        let rxs: Vec<_> = (0..8)
            .map(|i| srv.infer_async("mnist", vec![i as f32 / 8.0; 784]).unwrap())
            .collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().unwrap().len(), 10);
        }
        let rep = srv.report();
        assert_eq!(rep.completed, 8);
        assert_eq!(rep.pool.len(), 2, "one report row per pool agent");
        let (a, b) = (rep.pool[0].dispatches, rep.pool[1].dispatches);
        assert_eq!(a + b, rep.reconfig.dispatches, "rollup covers the pool");
        assert!(a >= 1 && b >= 1, "both agents served traffic: {a}/{b}");
        // Replies all delivered, so nothing may still be in flight.
        assert_eq!(rep.pool.iter().map(|p| p.inflight).sum::<u64>(), 0);
        // Pooled outputs equal the single-agent server's for the same
        // input (identical deterministic weights everywhere).
        let mut single = single_model(1, 1, 2);
        let want = single.infer("mnist", vec![0.25; 784]).unwrap();
        let got = srv.infer("mnist", vec![0.25; 784]).unwrap();
        assert_eq!(want, got, "pool-2 logits diverged from single agent");
        single.stop();
        srv.stop();
    }

    #[test]
    fn stop_drains_queued_requests() {
        let mut srv = single_model(32, 10_000, 2);
        // Deadline far out and batch far from full: only stop() flushes.
        let rxs: Vec<_> = (0..3)
            .map(|i| srv.infer_async("mnist", vec![i as f32; 784]).unwrap())
            .collect();
        srv.stop();
        for rx in rxs {
            let logits = rx.recv().unwrap().unwrap();
            assert_eq!(logits.len(), 10);
        }
    }
}
