//! Dynamic batching: collect requests until the batch is full or the
//! oldest request has waited `max_delay` (vLLM-router-style policy,
//! simplified for a single model).

use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests per batch (the model's compiled batch dim).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before the batch is
    /// closed even if not full.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_delay: Duration::from_millis(5) }
    }
}

/// An accumulating batch of items with arrival times.
#[derive(Debug)]
pub struct Batch<T> {
    items: Vec<T>,
    oldest: Option<Instant>,
    policy: BatchPolicy,
}

impl<T> Batch<T> {
    pub fn new(policy: BatchPolicy) -> Batch<T> {
        Batch { items: Vec::with_capacity(policy.max_batch), oldest: None, policy }
    }

    /// Add an item; returns true if the batch is now full.
    pub fn push(&mut self, item: T) -> bool {
        if self.items.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.items.push(item);
        self.items.len() >= self.policy.max_batch
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Whether the deadline policy says to close the batch now.
    pub fn deadline_expired(&self) -> bool {
        match self.oldest {
            Some(t) => !self.items.is_empty() && t.elapsed() >= self.policy.max_delay,
            None => false,
        }
    }

    /// Remaining time until the deadline (None if empty).
    pub fn time_left(&self) -> Option<Duration> {
        self.oldest
            .map(|t| self.policy.max_delay.saturating_sub(t.elapsed()))
    }

    /// Close the batch, taking its items.
    pub fn take(&mut self) -> Vec<T> {
        self.oldest = None;
        std::mem::take(&mut self.items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay: Duration::from_millis(ms) }
    }

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batch::new(policy(3, 1000));
        assert!(!b.push(1));
        assert!(!b.push(2));
        assert!(b.push(3), "third item fills the batch");
        assert_eq!(b.take(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_fires_for_partial_batch() {
        let mut b = Batch::new(policy(10, 10));
        b.push(1);
        assert!(!b.deadline_expired());
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.deadline_expired());
    }

    #[test]
    fn empty_batch_never_expires() {
        let b: Batch<u32> = Batch::new(policy(10, 0));
        assert!(!b.deadline_expired());
        assert!(b.time_left().is_none());
    }

    #[test]
    fn take_resets_deadline() {
        let mut b = Batch::new(policy(10, 5));
        b.push(1);
        std::thread::sleep(Duration::from_millis(8));
        assert!(b.deadline_expired());
        let _ = b.take();
        assert!(!b.deadline_expired());
        b.push(2);
        assert!(!b.deadline_expired(), "fresh deadline for the new batch");
    }
}
