//! Adaptive micro-batching: collect requests until the batch is full
//! (size-triggered flush) or the oldest request has waited `max_delay`
//! (deadline-triggered flush).
//!
//! Three layers:
//!
//! * [`Batch`] — one accumulating batch with its arrival clock; the
//!   single-model building block used by the synchronous server.
//! * [`Batcher`] — a set of independent per-model *lanes*, each a
//!   [`Batch`] with its own [`BatchPolicy`].
//! * [`LaneSet`] — the *continuous* batcher behind the async pipeline:
//!   shape-bucketed lanes (keyed by [`BucketKey`]) whose staging buffers
//!   are written in place by submitters through a [`TensorWriter`], and
//!   which keep admitting same-bucket requests while a flush is already
//!   under way (the "late join" window). The serving loop sleeps until
//!   [`LaneSet::next_deadline`], closes whatever [`LaneSet::ready`] hands
//!   back, and takes the batch at the last possible moment — every row
//!   that arrived in between rides the in-flight batch instead of
//!   waiting a full flush cycle.
//!
//! Deadlines arm from each request's *arrival* time, never from push
//! time: a request that sat out a backpressure stall does not get its
//! wait silently restarted (see [`Batch::push_at`] and
//! [`LaneSet::take`]'s re-arm from the oldest remaining waiter).

use crate::hsa::error::{HsaError, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests per batch (the model's compiled batch dim).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before the batch is
    /// closed even if not full.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_delay: Duration::from_millis(5) }
    }
}

/// An accumulating batch of items with arrival times.
#[derive(Debug)]
pub struct Batch<T> {
    items: Vec<T>,
    oldest: Option<Instant>,
    policy: BatchPolicy,
}

impl<T> Batch<T> {
    pub fn new(policy: BatchPolicy) -> Batch<T> {
        Batch { items: Vec::with_capacity(policy.max_batch), oldest: None, policy }
    }

    /// Add an item that arrived now; returns true if the batch is full.
    pub fn push(&mut self, item: T) -> bool {
        self.push_at(item, Instant::now())
    }

    /// Add an item that arrived at `arrived` — possibly in the past, e.g.
    /// it waited in a submit queue while the pipeline was backpressured.
    /// The lane deadline arms from the *oldest arrival*, not from push
    /// time, so a backpressure stall cannot silently re-arm the deadline
    /// and extend tail latency. Returns true if the batch is now full.
    pub fn push_at(&mut self, item: T, arrived: Instant) -> bool {
        self.oldest = Some(match self.oldest {
            Some(o) => o.min(arrived),
            None => arrived,
        });
        self.items.push(item);
        self.items.len() >= self.policy.max_batch
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Whether the size trigger has fired (batch reached `max_batch`).
    pub fn is_full(&self) -> bool {
        self.items.len() >= self.policy.max_batch
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Whether the deadline policy says to close the batch now.
    pub fn deadline_expired(&self) -> bool {
        match self.oldest {
            Some(t) => !self.items.is_empty() && t.elapsed() >= self.policy.max_delay,
            None => false,
        }
    }

    /// Remaining time until the deadline (None if empty).
    pub fn time_left(&self) -> Option<Duration> {
        self.oldest
            .map(|t| self.policy.max_delay.saturating_sub(t.elapsed()))
    }

    /// Close the batch, taking its items.
    pub fn take(&mut self) -> Vec<T> {
        self.oldest = None;
        std::mem::take(&mut self.items)
    }
}

struct Lane<T> {
    model: String,
    batch: Batch<T>,
}

/// Per-model adaptive micro-batcher: one [`Batch`] lane per model, each
/// with its own size and deadline policy.
///
/// # Example
///
/// ```
/// use std::time::Duration;
/// use tf_fpga::serve::{BatchPolicy, Batcher};
///
/// let mut b: Batcher<u32> = Batcher::new();
/// b.add_model("mnist", BatchPolicy { max_batch: 2, max_delay: Duration::from_millis(5) });
///
/// assert!(!b.push("mnist", 7).unwrap());
/// assert!(b.push("mnist", 8).unwrap()); // size trigger: lane is full
///
/// let (model, items) = b.ready().expect("full lane flushes");
/// assert_eq!((model.as_str(), items.as_slice()), ("mnist", &[7, 8][..]));
/// assert!(b.ready().is_none(), "nothing left to flush");
/// ```
pub struct Batcher<T> {
    lanes: Vec<Lane<T>>,
    /// Rotating scan start so one hot lane cannot starve the others.
    cursor: usize,
}

impl<T> Default for Batcher<T> {
    fn default() -> Self {
        Batcher::new()
    }
}

impl<T> Batcher<T> {
    pub fn new() -> Batcher<T> {
        Batcher { lanes: Vec::new(), cursor: 0 }
    }

    /// Register a model lane. Adding the same model twice replaces its
    /// policy (and drops anything queued — call before serving starts).
    pub fn add_model(&mut self, model: impl Into<String>, policy: BatchPolicy) {
        let model = model.into();
        if let Some(lane) = self.lanes.iter_mut().find(|l| l.model == model) {
            lane.batch = Batch::new(policy);
        } else {
            self.lanes.push(Lane { model, batch: Batch::new(policy) });
        }
    }

    pub fn models(&self) -> Vec<&str> {
        self.lanes.iter().map(|l| l.model.as_str()).collect()
    }

    /// Queue a request into its model's lane; returns true if the lane is
    /// now full (caller should flush via [`Batcher::ready`]).
    pub fn push(&mut self, model: &str, item: T) -> Result<bool> {
        let lane = self
            .lanes
            .iter_mut()
            .find(|l| l.model == model)
            .ok_or_else(|| HsaError::Runtime(format!("unknown model '{model}'")))?;
        Ok(lane.batch.push(item))
    }

    /// Next lane due for dispatch — size-triggered (full) lanes first,
    /// then deadline-expired ones. Returns the model name and its drained
    /// items; `None` when nothing is due yet.
    pub fn ready(&mut self) -> Option<(String, Vec<T>)> {
        let n = self.lanes.len();
        if n == 0 {
            return None;
        }
        for pass in [true, false] {
            for off in 0..n {
                let i = (self.cursor + off) % n;
                let lane = &mut self.lanes[i];
                let due = if pass { lane.batch.is_full() } else { lane.batch.deadline_expired() };
                if due {
                    self.cursor = (i + 1) % n;
                    return Some((lane.model.clone(), lane.batch.take()));
                }
            }
        }
        None
    }

    /// Flush every non-empty lane regardless of triggers (shutdown path).
    pub fn drain(&mut self) -> Vec<(String, Vec<T>)> {
        self.lanes
            .iter_mut()
            .filter(|l| !l.batch.is_empty())
            .map(|l| (l.model.clone(), l.batch.take()))
            .collect()
    }

    /// Time until the earliest lane deadline (None when all lanes are
    /// empty) — how long the serving loop may sleep.
    pub fn next_deadline(&self) -> Option<Duration> {
        self.lanes.iter().filter_map(|l| l.batch.time_left()).min()
    }

    /// Requests currently queued for `model` (0 for unknown models).
    pub fn queued(&self, model: &str) -> usize {
        self.lanes
            .iter()
            .find(|l| l.model == model)
            .map(|l| l.batch.len())
            .unwrap_or(0)
    }

    pub fn total_queued(&self) -> usize {
        self.lanes.iter().map(|l| l.batch.len()).sum()
    }

    /// Per-model queue depths — the demand hints for the eviction policy.
    pub fn queued_by_model(&self) -> Vec<(String, usize)> {
        self.lanes.iter().map(|l| (l.model.clone(), l.batch.len())).collect()
    }
}

// ---------------------------------------------------------------------------
// Continuous shape-bucketed batching
// ---------------------------------------------------------------------------

/// The identity of a continuous batch lane: requests that agree on both
/// components share one lane and batch together along dim 0.
///
/// * `signature` — the *model-qualified* served signature (e.g.
///   `"mnist/serve"`). Qualifying by model name guarantees two different
///   models never merge into one batch even when their tensor geometry
///   matches; a future model serving several signatures with the same
///   per-sample geometry still gets one lane per signature.
/// * `sample_shape` — the input shape *minus dim 0* (the batch dim), so
///   `[1, 28, 28]` for an MNIST image lane. Two requests bucket together
///   exactly when their per-sample tensors are layout-compatible.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BucketKey {
    /// Model-qualified signature name, `"{model}/{signature}"`.
    pub signature: String,
    /// Per-sample input shape (input shape with the batch dim stripped).
    pub sample_shape: Vec<usize>,
}

impl BucketKey {
    /// Build the key for `model` serving `signature` with per-sample
    /// input shape `sample_shape`.
    pub fn new(model: &str, signature: &str, sample_shape: &[usize]) -> BucketKey {
        BucketKey {
            signature: format!("{model}/{signature}"),
            sample_shape: sample_shape.to_vec(),
        }
    }
}

/// In-place sink for one decoded tensor row.
///
/// A submitter obtains a `TensorWriter` positioned at the tail of its
/// lane's staging buffer (the very `Vec<f32>` that becomes the dispatched
/// batch tensor) and decodes its request body straight into it — binary
/// wire payloads, base64 tiers and JSON number arrays all land in the
/// batch allocation with **no intermediate per-sample `Vec<f32>`**. If
/// decoding fails or writes the wrong number of elements, the lane rolls
/// the buffer back to where the row began and the lane is untouched.
#[derive(Debug)]
pub struct TensorWriter<'a> {
    dst: &'a mut Vec<f32>,
    start: usize,
    expected: usize,
}

#[cfg(test)]
impl<'a> TensorWriter<'a> {
    /// Test-only constructor over a plain `Vec` (used by the wire-format
    /// unit tests; production writers are only handed out by a lane).
    pub(crate) fn for_tests(dst: &'a mut Vec<f32>, expected: usize) -> TensorWriter<'a> {
        let start = dst.len();
        TensorWriter { dst, start, expected }
    }
}

impl TensorWriter<'_> {
    /// Append one element of the row.
    pub fn push(&mut self, v: f32) {
        self.dst.push(v);
    }

    /// Append a run of elements (the copy-through path for callers that
    /// already own a decoded buffer).
    pub fn extend_from_slice(&mut self, vs: &[f32]) {
        self.dst.extend_from_slice(vs);
    }

    /// Elements written so far for this row.
    pub fn written(&self) -> usize {
        self.dst.len() - self.start
    }

    /// Elements the row must contain in total (the lane's per-sample
    /// element count).
    pub fn expected(&self) -> usize {
        self.expected
    }
}

/// Outcome of a successful [`LaneSet::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubmitReceipt {
    /// The lane reached its compiled capacity with this row — the caller
    /// should wake the flush loop.
    pub became_full: bool,
    /// The row joined a lane whose flush had already begun; it rides the
    /// in-flight batch instead of waiting a full cycle.
    pub late_join: bool,
}

/// One flushed batch handed from [`LaneSet::take`] to the dispatcher.
#[derive(Debug)]
pub struct TakenBatch<T> {
    /// Index of the lane this batch came from (for buffer recycling).
    pub lane: usize,
    /// The lane's model name.
    pub model: String,
    /// The lane's compiled batch capacity (fill-ratio denominator).
    pub capacity: usize,
    /// Items with their arrival instants, in admission order.
    pub items: Vec<(T, Instant)>,
    /// The staging buffer: `items.len() * in_elems` f32 values, written
    /// in place by the submitters' [`TensorWriter`]s. The dispatcher pads
    /// it to `capacity * in_elems` and wraps it into the batch tensor —
    /// no further copies.
    pub data: Vec<f32>,
    /// Rows that were admitted after the flush began.
    pub late_joins: u64,
    /// Bytes moved to carve an over-full lane's tail back into staging
    /// (only non-zero under overload, when arrivals outran the flusher).
    pub bytes_copied: u64,
    /// When the batch was sealed — the end of every member's `batch_wait`
    /// window (each row's is `taken_at - arrived`) and the start of batch
    /// assembly.
    pub taken_at: Instant,
}

struct LaneInner<T> {
    items: Vec<(T, Instant)>,
    data: Vec<f32>,
    oldest: Option<Instant>,
    /// A flush has begun (the dispatcher is acquiring a pipeline slot);
    /// rows admitted now are late joins and still ride this batch.
    closing: bool,
    late_joins: u64,
    /// Retired staging buffers handed back via [`LaneSet::recycle`].
    spare: Vec<Vec<f32>>,
}

struct ContinuousLane<T> {
    model: String,
    key: BucketKey,
    policy: BatchPolicy,
    in_elems: usize,
    inner: Mutex<LaneInner<T>>,
}

/// The continuous batcher: shape-bucketed lanes whose staging buffers are
/// written in place by concurrent submitters, flushed by a single serving
/// loop. Unlike [`Batcher`], a lane keeps admitting rows *while its flush
/// is in progress* — the taking of the batch is deferred to the moment
/// the pipeline actually accepts it, so arrivals during a backpressure
/// stall ride the outgoing batch ("late joins") instead of waiting out
/// another whole flush cycle.
pub struct LaneSet<T> {
    lanes: Vec<ContinuousLane<T>>,
    /// Rotating scan start so one hot lane cannot starve the others.
    cursor: AtomicUsize,
}

impl<T> Default for LaneSet<T> {
    fn default() -> Self {
        LaneSet::new()
    }
}

impl<T> LaneSet<T> {
    pub fn new() -> LaneSet<T> {
        LaneSet { lanes: Vec::new(), cursor: AtomicUsize::new(0) }
    }

    /// Register a lane for `model` under bucket `key`; `in_elems` is the
    /// per-sample element count every row must write. Returns the lane
    /// index. Call before serving starts (lanes are fixed thereafter —
    /// that is what lets submitters share `&LaneSet` without an outer
    /// lock).
    pub fn add_lane(
        &mut self,
        model: impl Into<String>,
        key: BucketKey,
        policy: BatchPolicy,
        in_elems: usize,
    ) -> usize {
        let model = model.into();
        self.lanes.push(ContinuousLane {
            model,
            key,
            policy,
            in_elems,
            inner: Mutex::new(LaneInner {
                items: Vec::with_capacity(policy.max_batch),
                data: Vec::with_capacity(policy.max_batch * in_elems),
                oldest: None,
                closing: false,
                late_joins: 0,
                spare: Vec::new(),
            }),
        });
        self.lanes.len() - 1
    }

    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// Index of the lane serving `model` (today: one lane per model; a
    /// multi-signature model would search by [`BucketKey`] instead).
    pub fn lane_for(&self, model: &str) -> Option<usize> {
        self.lanes.iter().position(|l| l.model == model)
    }

    /// The bucket key of lane `idx`.
    pub fn key(&self, idx: usize) -> &BucketKey {
        &self.lanes[idx].key
    }

    /// Admit one row into `model`'s lane. `fill` receives a
    /// [`TensorWriter`] positioned at the staging buffer's tail and must
    /// write exactly the lane's per-sample element count; on any error
    /// the buffer is rolled back and the lane is untouched. `arrived` is
    /// the request's true arrival instant — deadlines arm from it, so a
    /// row delayed upstream keeps its age.
    pub fn submit(
        &self,
        model: &str,
        arrived: Instant,
        item: T,
        fill: impl FnOnce(&mut TensorWriter<'_>) -> std::result::Result<(), String>,
    ) -> std::result::Result<SubmitReceipt, String> {
        let idx = self
            .lane_for(model)
            .ok_or_else(|| format!("unknown model '{model}'"))?;
        let lane = &self.lanes[idx];
        let mut inner = lane.inner.lock().unwrap();
        let start = inner.data.len();
        let mut w = TensorWriter { dst: &mut inner.data, start, expected: lane.in_elems };
        let outcome = fill(&mut w).and_then(|()| {
            if w.written() == lane.in_elems {
                Ok(())
            } else {
                Err(format!(
                    "input row must be {} f32 values, wrote {}",
                    lane.in_elems,
                    w.written()
                ))
            }
        });
        if let Err(e) = outcome {
            inner.data.truncate(start);
            return Err(e);
        }
        inner.items.push((item, arrived));
        inner.oldest = Some(match inner.oldest {
            Some(o) => o.min(arrived),
            None => arrived,
        });
        let late_join = inner.closing;
        if late_join {
            inner.late_joins += 1;
        }
        Ok(SubmitReceipt {
            became_full: inner.items.len() >= lane.policy.max_batch,
            late_join,
        })
    }

    /// Next lane due for dispatch — size-triggered (full) lanes first,
    /// then deadline-expired ones, scanning from a rotating cursor.
    /// Returns the lane index; `None` when nothing is due yet.
    pub fn ready(&self) -> Option<usize> {
        let n = self.lanes.len();
        if n == 0 {
            return None;
        }
        let cursor = self.cursor.load(Ordering::Relaxed);
        for pass in [true, false] {
            for off in 0..n {
                let i = (cursor + off) % n;
                let lane = &self.lanes[i];
                let inner = lane.inner.lock().unwrap();
                if inner.closing {
                    continue;
                }
                let due = if pass {
                    inner.items.len() >= lane.policy.max_batch
                } else {
                    match inner.oldest {
                        Some(t) => {
                            !inner.items.is_empty()
                                && t.elapsed() >= lane.policy.max_delay
                        }
                        None => false,
                    }
                };
                if due {
                    self.cursor.store((i + 1) % n, Ordering::Relaxed);
                    return Some(i);
                }
            }
        }
        None
    }

    /// Mark lane `idx` as flushing: from now until [`LaneSet::take`],
    /// admitted rows count as late joins (and still ride the batch).
    pub fn begin_close(&self, idx: usize) {
        self.lanes[idx].inner.lock().unwrap().closing = true;
    }

    /// Seal and take up to `max_batch` rows from lane `idx` — the last
    /// moment of the late-join window. An over-full lane's tail stays
    /// queued with its arrival times intact, and the deadline re-arms
    /// from the **oldest remaining waiter's arrival** (not from now), so
    /// rows left behind by a backpressured flush keep their age instead
    /// of silently waiting another full `max_delay`.
    pub fn take(&self, idx: usize) -> Option<TakenBatch<T>> {
        let lane = &self.lanes[idx];
        let mut inner = lane.inner.lock().unwrap();
        inner.closing = false;
        if inner.items.is_empty() {
            inner.late_joins = 0;
            return None;
        }
        let cap = lane.policy.max_batch;
        let mut items = std::mem::take(&mut inner.items);
        let spare = inner
            .spare
            .pop()
            .unwrap_or_else(|| Vec::with_capacity(cap * lane.in_elems));
        let mut data = std::mem::replace(&mut inner.data, spare);
        let mut bytes_copied = 0u64;
        if items.len() > cap {
            let tail = items.split_off(cap);
            let tail_data = &data[cap * lane.in_elems..];
            bytes_copied = (tail_data.len() * std::mem::size_of::<f32>()) as u64;
            inner.data.extend_from_slice(tail_data);
            data.truncate(cap * lane.in_elems);
            inner.items = tail;
        }
        // Flush-deadline drift fix: re-arm from the oldest waiter left
        // behind, not from the wall clock.
        inner.oldest = inner.items.first().map(|(_, arrived)| *arrived);
        let late_joins = std::mem::take(&mut inner.late_joins);
        Some(TakenBatch {
            lane: idx,
            model: lane.model.clone(),
            capacity: cap,
            items,
            data,
            late_joins,
            bytes_copied,
            taken_at: Instant::now(),
        })
    }

    /// Hand a retired staging buffer back to lane `idx` for reuse (the
    /// dispatcher recovers it from the batch tensor once the batch
    /// retires). Keeps at most a couple spares per lane.
    pub fn recycle(&self, idx: usize, mut buf: Vec<f32>) {
        buf.clear();
        let mut inner = self.lanes[idx].inner.lock().unwrap();
        if inner.spare.len() < 2 {
            inner.spare.push(buf);
        }
    }

    /// Take every queued batch regardless of triggers (shutdown path).
    pub fn drain(&self) -> Vec<TakenBatch<T>> {
        let mut out = Vec::new();
        for idx in 0..self.lanes.len() {
            while let Some(b) = self.take(idx) {
                out.push(b);
            }
        }
        out
    }

    /// Time until the earliest lane deadline (None when all lanes are
    /// empty) — how long the serving loop may sleep.
    pub fn next_deadline(&self) -> Option<Duration> {
        self.lanes
            .iter()
            .filter_map(|l| {
                let inner = l.inner.lock().unwrap();
                inner
                    .oldest
                    .map(|t| l.policy.max_delay.saturating_sub(t.elapsed()))
            })
            .min()
    }

    /// Rows currently queued across all lanes.
    pub fn total_queued(&self) -> usize {
        self.lanes.iter().map(|l| l.inner.lock().unwrap().items.len()).sum()
    }

    /// Per-model queue depths — the demand hints for the eviction policy.
    pub fn queued_by_model(&self) -> Vec<(String, usize)> {
        self.lanes
            .iter()
            .map(|l| (l.model.clone(), l.inner.lock().unwrap().items.len()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay: Duration::from_millis(ms) }
    }

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batch::new(policy(3, 1000));
        assert!(!b.push(1));
        assert!(!b.push(2));
        assert!(b.push(3), "third item fills the batch");
        assert_eq!(b.take(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_fires_for_partial_batch() {
        let mut b = Batch::new(policy(10, 10));
        b.push(1);
        assert!(!b.deadline_expired());
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.deadline_expired());
    }

    #[test]
    fn empty_batch_never_expires() {
        let b: Batch<u32> = Batch::new(policy(10, 0));
        assert!(!b.deadline_expired());
        assert!(b.time_left().is_none());
    }

    #[test]
    fn take_resets_deadline() {
        let mut b = Batch::new(policy(10, 5));
        b.push(1);
        std::thread::sleep(Duration::from_millis(8));
        assert!(b.deadline_expired());
        let _ = b.take();
        assert!(!b.deadline_expired());
        b.push(2);
        assert!(!b.deadline_expired(), "fresh deadline for the new batch");
    }

    #[test]
    fn push_at_arms_deadline_from_arrival_not_push_time() {
        // Regression: an item that arrived before a backpressure stall
        // must not have its deadline silently re-armed when it is finally
        // pushed — the batch is already overdue.
        let mut b = Batch::new(policy(10, 50));
        let arrived = Instant::now() - Duration::from_millis(100);
        b.push_at(1, arrived);
        assert!(
            b.deadline_expired(),
            "deadline arms from the 100 ms-old arrival, not from now"
        );
        // A second, younger item does not un-expire the batch.
        b.push_at(2, Instant::now());
        assert!(b.deadline_expired());
    }

    #[test]
    fn batcher_flushes_full_lane_first() {
        let mut b: Batcher<u32> = Batcher::new();
        b.add_model("a", policy(2, 1000));
        b.add_model("b", policy(4, 1000));
        b.push("b", 10).unwrap();
        assert!(!b.push("a", 1).unwrap());
        assert!(b.push("a", 2).unwrap(), "lane a fills");
        let (model, items) = b.ready().unwrap();
        assert_eq!((model.as_str(), items), ("a", vec![1, 2]));
        assert!(b.ready().is_none(), "lane b neither full nor expired");
        assert_eq!(b.queued("b"), 1);
    }

    #[test]
    fn batcher_deadline_flushes_partial_lane() {
        let mut b: Batcher<u32> = Batcher::new();
        b.add_model("a", policy(8, 5));
        b.push("a", 1).unwrap();
        assert!(b.ready().is_none());
        std::thread::sleep(Duration::from_millis(10));
        let (model, items) = b.ready().unwrap();
        assert_eq!((model.as_str(), items), ("a", vec![1]));
    }

    #[test]
    fn batcher_rejects_unknown_model() {
        let mut b: Batcher<u32> = Batcher::new();
        b.add_model("a", policy(2, 10));
        assert!(b.push("nope", 1).is_err());
        assert_eq!(b.queued("nope"), 0);
    }

    #[test]
    fn batcher_next_deadline_tracks_oldest_lane() {
        let mut b: Batcher<u32> = Batcher::new();
        b.add_model("slow", policy(8, 1000));
        b.add_model("fast", policy(8, 5));
        assert!(b.next_deadline().is_none(), "all lanes empty");
        b.push("slow", 1).unwrap();
        b.push("fast", 2).unwrap();
        let left = b.next_deadline().unwrap();
        assert!(left <= Duration::from_millis(5), "fast lane dominates: {left:?}");
    }

    #[test]
    fn batcher_drain_empties_every_lane() {
        let mut b: Batcher<u32> = Batcher::new();
        b.add_model("a", policy(8, 1000));
        b.add_model("b", policy(8, 1000));
        b.push("a", 1).unwrap();
        b.push("b", 2).unwrap();
        b.push("b", 3).unwrap();
        let mut flushed = b.drain();
        flushed.sort_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(flushed, vec![("a".into(), vec![1]), ("b".into(), vec![2, 3])]);
        assert_eq!(b.total_queued(), 0);
    }

    // --- continuous lanes -------------------------------------------------

    fn tiny_lanes(max_batch: usize, ms: u64, in_elems: usize) -> LaneSet<u32> {
        let mut lanes = LaneSet::new();
        lanes.add_lane(
            "m",
            BucketKey::new("m", "serve", &[in_elems]),
            policy(max_batch, ms),
            in_elems,
        );
        lanes
    }

    fn put(lanes: &LaneSet<u32>, tag: u32, row: &[f32]) -> SubmitReceipt {
        lanes
            .submit("m", Instant::now(), tag, |w| {
                w.extend_from_slice(row);
                Ok(())
            })
            .unwrap()
    }

    #[test]
    fn bucket_key_separates_models_and_shapes() {
        let a = BucketKey::new("mnist", "serve", &[1, 28, 28]);
        let b = BucketKey::new("tiny", "serve", &[1, 28, 28]);
        let c = BucketKey::new("mnist", "serve", &[784]);
        assert_eq!(a, BucketKey::new("mnist", "serve", &[1, 28, 28]));
        assert_ne!(a, b, "same geometry, different model: distinct buckets");
        assert_ne!(a, c, "same model, different per-sample shape");
        assert_eq!(a.signature, "mnist/serve");
        assert_eq!(a.sample_shape, vec![1, 28, 28]);
    }

    #[test]
    fn laneset_rows_land_in_staging_in_order() {
        let lanes = tiny_lanes(4, 1000, 2);
        assert!(!put(&lanes, 1, &[1.0, 2.0]).became_full);
        assert!(!put(&lanes, 2, &[3.0, 4.0]).became_full);
        let r = put(&lanes, 3, &[5.0, 6.0]);
        assert!(!r.became_full && !r.late_join);
        assert_eq!(lanes.total_queued(), 3);
        assert!(lanes.ready().is_none(), "not full, deadline far out");
        let b = lanes.take(0).unwrap();
        assert_eq!(b.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(b.items.iter().map(|(t, _)| *t).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!((b.capacity, b.late_joins, b.bytes_copied), (4, 0, 0));
    }

    #[test]
    fn laneset_submit_rolls_back_bad_rows() {
        let lanes = tiny_lanes(4, 1000, 3);
        let err = lanes
            .submit("m", Instant::now(), 7u32, |w| {
                w.push(1.0); // one of three
                Ok(())
            })
            .unwrap_err();
        assert!(err.contains('3') && err.contains('1'), "{err}");
        let err = lanes
            .submit("m", Instant::now(), 8u32, |w| {
                w.push(1.0);
                Err("decode failed".into())
            })
            .unwrap_err();
        assert_eq!(err, "decode failed");
        assert_eq!(lanes.total_queued(), 0, "failed rows leave no residue");
        // The staging buffer rolled back: a good row lands at offset 0.
        put(&lanes, 9, &[1.0, 2.0, 3.0]);
        let b = lanes.take(0).unwrap();
        assert_eq!(b.data, vec![1.0, 2.0, 3.0]);
        assert!(lanes.submit("nope", Instant::now(), 0, |_| Ok(())).is_err());
    }

    #[test]
    fn laneset_full_lane_is_ready_and_take_caps_at_capacity() {
        let lanes = tiny_lanes(2, 10_000, 1);
        put(&lanes, 1, &[1.0]);
        assert!(lanes.ready().is_none());
        assert!(put(&lanes, 2, &[2.0]).became_full);
        // Overflow past capacity queues for the next batch.
        put(&lanes, 3, &[3.0]);
        assert_eq!(lanes.ready(), Some(0));
        let b = lanes.take(0).unwrap();
        assert_eq!(b.data, vec![1.0, 2.0]);
        assert_eq!(b.items.len(), 2);
        assert_eq!(b.bytes_copied, 4, "one f32 tail row moved back to staging");
        assert_eq!(lanes.total_queued(), 1, "tail stays queued");
        let b2 = lanes.take(0).unwrap();
        assert_eq!(b2.data, vec![3.0]);
    }

    #[test]
    fn laneset_late_joins_ride_the_closing_batch() {
        let lanes = tiny_lanes(8, 10_000, 1);
        put(&lanes, 1, &[1.0]);
        lanes.begin_close(0);
        let r = put(&lanes, 2, &[2.0]);
        assert!(r.late_join, "row admitted mid-flush is a late join");
        assert!(lanes.ready().is_none(), "closing lane is not re-offered");
        let b = lanes.take(0).unwrap();
        assert_eq!(b.data, vec![1.0, 2.0], "late joiner rides the batch");
        assert_eq!(b.late_joins, 1);
        // The window closed with the take.
        put(&lanes, 3, &[3.0]);
        let b2 = lanes.take(0).unwrap();
        assert_eq!(b2.late_joins, 0);
    }

    #[test]
    fn laneset_deadline_rearms_from_oldest_waiter() {
        // Regression for the flush-deadline drift: rows left behind by a
        // backpressured flush keep their original arrival age.
        let lanes = tiny_lanes(2, 50, 1);
        let old = Instant::now() - Duration::from_millis(100);
        for tag in 0..3u32 {
            lanes
                .submit("m", old, tag, |w| {
                    w.push(tag as f32);
                    Ok(())
                })
                .unwrap();
        }
        let b = lanes.take(0).unwrap();
        assert_eq!(b.items.len(), 2);
        // The tail row arrived 100 ms ago with a 50 ms deadline: the lane
        // must be immediately due again, not re-armed for another 50 ms.
        assert_eq!(lanes.next_deadline(), Some(Duration::ZERO));
        assert_eq!(lanes.ready(), Some(0), "aged tail flushes without extra wait");
    }

    #[test]
    fn laneset_recycled_buffers_are_clean() {
        let lanes = tiny_lanes(2, 1000, 1);
        put(&lanes, 1, &[1.5]);
        let b = lanes.take(0).unwrap();
        lanes.recycle(0, b.data);
        put(&lanes, 2, &[2.5]);
        let b2 = lanes.take(0).unwrap();
        assert_eq!(b2.data, vec![2.5], "recycled buffer holds no stale rows");
    }

    #[test]
    fn laneset_drain_empties_every_lane() {
        let mut lanes: LaneSet<u32> = LaneSet::new();
        lanes.add_lane("a", BucketKey::new("a", "serve", &[1]), policy(2, 1000), 1);
        lanes.add_lane("b", BucketKey::new("b", "serve", &[1]), policy(2, 1000), 1);
        for (model, tag) in [("a", 1u32), ("b", 2), ("b", 3), ("b", 4)] {
            lanes
                .submit(model, Instant::now(), tag, |w| {
                    w.push(tag as f32);
                    Ok(())
                })
                .unwrap();
        }
        let batches = lanes.drain();
        assert_eq!(batches.len(), 3, "a×1, b at capacity 2 drains in two takes");
        assert_eq!(lanes.total_queued(), 0);
        assert_eq!(lanes.next_deadline(), None);
    }

    #[test]
    fn laneset_queue_depths_by_model() {
        let mut lanes: LaneSet<u32> = LaneSet::new();
        lanes.add_lane("a", BucketKey::new("a", "serve", &[1]), policy(4, 1000), 1);
        lanes.add_lane("b", BucketKey::new("b", "serve", &[1]), policy(4, 1000), 1);
        lanes
            .submit("a", Instant::now(), 1, |w| {
                w.push(0.0);
                Ok(())
            })
            .unwrap();
        assert_eq!(
            lanes.queued_by_model(),
            vec![("a".to_string(), 1), ("b".to_string(), 0)]
        );
        assert_eq!(lanes.lane_for("b"), Some(1));
        assert_eq!(lanes.key(0).signature, "a/serve");
        assert_eq!(lanes.lane_count(), 2);
    }
}
