//! Adaptive micro-batching: collect requests until the batch is full
//! (size-triggered flush) or the oldest request has waited `max_delay`
//! (deadline-triggered flush).
//!
//! Two layers:
//!
//! * [`Batch`] — one accumulating batch with its arrival clock; the
//!   single-model building block.
//! * [`Batcher`] — a set of independent per-model *lanes*, each a
//!   [`Batch`] with its own [`BatchPolicy`]. The serving loop pushes
//!   requests into lanes, sleeps until [`Batcher::next_deadline`], and
//!   flushes whatever [`Batcher::ready`] hands back. Lane queue depths
//!   ([`Batcher::queued_by_model`]) double as the demand hints fed to the
//!   queue-aware eviction policy.

use crate::hsa::error::{HsaError, Result};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests per batch (the model's compiled batch dim).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before the batch is
    /// closed even if not full.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_delay: Duration::from_millis(5) }
    }
}

/// An accumulating batch of items with arrival times.
#[derive(Debug)]
pub struct Batch<T> {
    items: Vec<T>,
    oldest: Option<Instant>,
    policy: BatchPolicy,
}

impl<T> Batch<T> {
    pub fn new(policy: BatchPolicy) -> Batch<T> {
        Batch { items: Vec::with_capacity(policy.max_batch), oldest: None, policy }
    }

    /// Add an item; returns true if the batch is now full.
    pub fn push(&mut self, item: T) -> bool {
        if self.items.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.items.push(item);
        self.items.len() >= self.policy.max_batch
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Whether the size trigger has fired (batch reached `max_batch`).
    pub fn is_full(&self) -> bool {
        self.items.len() >= self.policy.max_batch
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Whether the deadline policy says to close the batch now.
    pub fn deadline_expired(&self) -> bool {
        match self.oldest {
            Some(t) => !self.items.is_empty() && t.elapsed() >= self.policy.max_delay,
            None => false,
        }
    }

    /// Remaining time until the deadline (None if empty).
    pub fn time_left(&self) -> Option<Duration> {
        self.oldest
            .map(|t| self.policy.max_delay.saturating_sub(t.elapsed()))
    }

    /// Close the batch, taking its items.
    pub fn take(&mut self) -> Vec<T> {
        self.oldest = None;
        std::mem::take(&mut self.items)
    }
}

struct Lane<T> {
    model: String,
    batch: Batch<T>,
}

/// Per-model adaptive micro-batcher: one [`Batch`] lane per model, each
/// with its own size and deadline policy.
///
/// # Example
///
/// ```
/// use std::time::Duration;
/// use tf_fpga::serve::{BatchPolicy, Batcher};
///
/// let mut b: Batcher<u32> = Batcher::new();
/// b.add_model("mnist", BatchPolicy { max_batch: 2, max_delay: Duration::from_millis(5) });
///
/// assert!(!b.push("mnist", 7).unwrap());
/// assert!(b.push("mnist", 8).unwrap()); // size trigger: lane is full
///
/// let (model, items) = b.ready().expect("full lane flushes");
/// assert_eq!((model.as_str(), items.as_slice()), ("mnist", &[7, 8][..]));
/// assert!(b.ready().is_none(), "nothing left to flush");
/// ```
pub struct Batcher<T> {
    lanes: Vec<Lane<T>>,
    /// Rotating scan start so one hot lane cannot starve the others.
    cursor: usize,
}

impl<T> Default for Batcher<T> {
    fn default() -> Self {
        Batcher::new()
    }
}

impl<T> Batcher<T> {
    pub fn new() -> Batcher<T> {
        Batcher { lanes: Vec::new(), cursor: 0 }
    }

    /// Register a model lane. Adding the same model twice replaces its
    /// policy (and drops anything queued — call before serving starts).
    pub fn add_model(&mut self, model: impl Into<String>, policy: BatchPolicy) {
        let model = model.into();
        if let Some(lane) = self.lanes.iter_mut().find(|l| l.model == model) {
            lane.batch = Batch::new(policy);
        } else {
            self.lanes.push(Lane { model, batch: Batch::new(policy) });
        }
    }

    pub fn models(&self) -> Vec<&str> {
        self.lanes.iter().map(|l| l.model.as_str()).collect()
    }

    /// Queue a request into its model's lane; returns true if the lane is
    /// now full (caller should flush via [`Batcher::ready`]).
    pub fn push(&mut self, model: &str, item: T) -> Result<bool> {
        let lane = self
            .lanes
            .iter_mut()
            .find(|l| l.model == model)
            .ok_or_else(|| HsaError::Runtime(format!("unknown model '{model}'")))?;
        Ok(lane.batch.push(item))
    }

    /// Next lane due for dispatch — size-triggered (full) lanes first,
    /// then deadline-expired ones. Returns the model name and its drained
    /// items; `None` when nothing is due yet.
    pub fn ready(&mut self) -> Option<(String, Vec<T>)> {
        let n = self.lanes.len();
        if n == 0 {
            return None;
        }
        for pass in [true, false] {
            for off in 0..n {
                let i = (self.cursor + off) % n;
                let lane = &mut self.lanes[i];
                let due = if pass { lane.batch.is_full() } else { lane.batch.deadline_expired() };
                if due {
                    self.cursor = (i + 1) % n;
                    return Some((lane.model.clone(), lane.batch.take()));
                }
            }
        }
        None
    }

    /// Flush every non-empty lane regardless of triggers (shutdown path).
    pub fn drain(&mut self) -> Vec<(String, Vec<T>)> {
        self.lanes
            .iter_mut()
            .filter(|l| !l.batch.is_empty())
            .map(|l| (l.model.clone(), l.batch.take()))
            .collect()
    }

    /// Time until the earliest lane deadline (None when all lanes are
    /// empty) — how long the serving loop may sleep.
    pub fn next_deadline(&self) -> Option<Duration> {
        self.lanes.iter().filter_map(|l| l.batch.time_left()).min()
    }

    /// Requests currently queued for `model` (0 for unknown models).
    pub fn queued(&self, model: &str) -> usize {
        self.lanes
            .iter()
            .find(|l| l.model == model)
            .map(|l| l.batch.len())
            .unwrap_or(0)
    }

    pub fn total_queued(&self) -> usize {
        self.lanes.iter().map(|l| l.batch.len()).sum()
    }

    /// Per-model queue depths — the demand hints for the eviction policy.
    pub fn queued_by_model(&self) -> Vec<(String, usize)> {
        self.lanes.iter().map(|l| (l.model.clone(), l.batch.len())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay: Duration::from_millis(ms) }
    }

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batch::new(policy(3, 1000));
        assert!(!b.push(1));
        assert!(!b.push(2));
        assert!(b.push(3), "third item fills the batch");
        assert_eq!(b.take(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_fires_for_partial_batch() {
        let mut b = Batch::new(policy(10, 10));
        b.push(1);
        assert!(!b.deadline_expired());
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.deadline_expired());
    }

    #[test]
    fn empty_batch_never_expires() {
        let b: Batch<u32> = Batch::new(policy(10, 0));
        assert!(!b.deadline_expired());
        assert!(b.time_left().is_none());
    }

    #[test]
    fn take_resets_deadline() {
        let mut b = Batch::new(policy(10, 5));
        b.push(1);
        std::thread::sleep(Duration::from_millis(8));
        assert!(b.deadline_expired());
        let _ = b.take();
        assert!(!b.deadline_expired());
        b.push(2);
        assert!(!b.deadline_expired(), "fresh deadline for the new batch");
    }

    #[test]
    fn batcher_flushes_full_lane_first() {
        let mut b: Batcher<u32> = Batcher::new();
        b.add_model("a", policy(2, 1000));
        b.add_model("b", policy(4, 1000));
        b.push("b", 10).unwrap();
        assert!(!b.push("a", 1).unwrap());
        assert!(b.push("a", 2).unwrap(), "lane a fills");
        let (model, items) = b.ready().unwrap();
        assert_eq!((model.as_str(), items), ("a", vec![1, 2]));
        assert!(b.ready().is_none(), "lane b neither full nor expired");
        assert_eq!(b.queued("b"), 1);
    }

    #[test]
    fn batcher_deadline_flushes_partial_lane() {
        let mut b: Batcher<u32> = Batcher::new();
        b.add_model("a", policy(8, 5));
        b.push("a", 1).unwrap();
        assert!(b.ready().is_none());
        std::thread::sleep(Duration::from_millis(10));
        let (model, items) = b.ready().unwrap();
        assert_eq!((model.as_str(), items), ("a", vec![1]));
    }

    #[test]
    fn batcher_rejects_unknown_model() {
        let mut b: Batcher<u32> = Batcher::new();
        b.add_model("a", policy(2, 10));
        assert!(b.push("nope", 1).is_err());
        assert_eq!(b.queued("nope"), 0);
    }

    #[test]
    fn batcher_next_deadline_tracks_oldest_lane() {
        let mut b: Batcher<u32> = Batcher::new();
        b.add_model("slow", policy(8, 1000));
        b.add_model("fast", policy(8, 5));
        assert!(b.next_deadline().is_none(), "all lanes empty");
        b.push("slow", 1).unwrap();
        b.push("fast", 2).unwrap();
        let left = b.next_deadline().unwrap();
        assert!(left <= Duration::from_millis(5), "fast lane dominates: {left:?}");
    }

    #[test]
    fn batcher_drain_empties_every_lane() {
        let mut b: Batcher<u32> = Batcher::new();
        b.add_model("a", policy(8, 1000));
        b.add_model("b", policy(8, 1000));
        b.push("a", 1).unwrap();
        b.push("b", 2).unwrap();
        b.push("b", 3).unwrap();
        let mut flushed = b.drain();
        flushed.sort_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(flushed, vec![("a".into(), vec![1]), ("b".into(), vec![2, 3])]);
        assert_eq!(b.total_queued(), 0);
    }
}
