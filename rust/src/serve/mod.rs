//! Inference serving on top of the session — the "mobile inference
//! service" the paper's introduction motivates (continuous camera/sensor
//! frames with pre/post-processing sharing the FPGA), grown into a
//! production-shaped request path.
//!
//! Three pieces:
//!
//! * [`batcher`] — adaptive micro-batching: a single-model [`Batch`] with
//!   size- and deadline-triggered flush, and the per-model multi-lane
//!   [`Batcher`] on top of it.
//! * [`server`] — [`InferenceServer`], the *synchronous* reference
//!   pipeline: one batcher thread forms a batch, runs it to completion,
//!   delivers, repeats. Simple, strictly ordered, and the baseline the
//!   `serving_throughput` bench measures against.
//! * [`async_server`] — [`AsyncInferenceServer`], the *asynchronous*
//!   pipeline: batches are dispatched with `Session::run_async` and
//!   retired by a completer pool, so forming batch *n+1*, executing batch
//!   *n* and delivering batch *n-1* all overlap, across several models
//!   and PR regions at once. Queue depths feed the `queue-aware` eviction
//!   policy as demand hints.
//!
//! Start with [`AsyncInferenceServer::start`] for throughput;
//! [`InferenceServer::start`] remains the minimal single-model path.

pub mod async_server;
pub mod batcher;
pub mod server;

pub use async_server::{
    AsyncInferenceServer, AsyncServeReport, AsyncServerConfig, ModelSpec,
};
pub use batcher::{Batch, BatchPolicy, Batcher};
pub use server::{InferenceServer, ServeReport, ServerConfig};
