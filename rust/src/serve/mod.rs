//! Inference serving on top of the session — the "mobile inference
//! service" the paper's introduction motivates (continuous camera/sensor
//! frames with pre/post-processing sharing the FPGA), grown into a
//! production-shaped request path.
//!
//! Four pieces:
//!
//! * [`batcher`] — adaptive micro-batching: a single-model [`Batch`] with
//!   size- and deadline-triggered flush, the per-model multi-lane
//!   [`Batcher`] on top of it, and the *continuous* [`LaneSet`] — shape-
//!   bucketed lanes (keyed by [`BucketKey`]) whose staging buffers are
//!   written in place through a [`TensorWriter`] and which keep admitting
//!   rows while their flush is already under way (late joins).
//! * [`hosted`] — bundle hosting: a [`ModelSpec`] names a loaded
//!   [`crate::tf::model::ModelBundle`] plus its batching policy; the
//!   bundle's graph is merged into the shared serving session and batched
//!   generically along dimension 0 of its input endpoint — models with
//!   different input shapes serve side by side.
//! * [`server`] — [`InferenceServer`], the *synchronous* reference
//!   pipeline: one batcher thread forms a batch, runs it to completion,
//!   delivers, repeats. Simple, strictly ordered, and the baseline the
//!   `serving_throughput` bench measures against.
//! * [`async_server`] — [`AsyncInferenceServer`], the *asynchronous*
//!   pipeline: batches are dispatched with `Session::run_async` and
//!   retired by a completer pool, so forming batch *n+1*, executing batch
//!   *n* and delivering batch *n-1* all overlap, across several models
//!   and PR regions at once. Queue depths feed the `queue-aware` eviction
//!   policy as demand hints.
//!
//! Start with [`AsyncInferenceServer::start`] for throughput;
//! [`InferenceServer::start`] remains the minimal single-model path.

pub mod async_server;
pub mod batcher;
pub mod hosted;
pub mod server;

pub use async_server::{AsyncInferenceServer, AsyncServeReport, AsyncServerConfig};
pub use batcher::{
    Batch, BatchPolicy, Batcher, BucketKey, LaneSet, SubmitReceipt, TakenBatch,
    TensorWriter,
};
pub use hosted::{ModelIoMeta, ModelSpec};
pub use server::{InferenceServer, ServeReport, ServerConfig};
