//! Inference serving on top of the session: a request queue, a dynamic
//! batcher, and worker threads — the "mobile inference service" the
//! paper's introduction motivates (continuous camera/sensor frames with
//! pre/post-processing sharing the FPGA).

pub mod batcher;
pub mod server;

pub use batcher::{Batch, BatchPolicy};
pub use server::{InferenceServer, ServeReport, ServerConfig};
