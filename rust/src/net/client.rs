//! A small blocking HTTP/1.1 client for loopback use: the integration
//! tests and the `http_serving` bench drive the frontend through it, and
//! it doubles as a reference for the wire protocol. Keep-alive by
//! default, with one transparent reconnect when a reused connection turns
//! out to be stale (server recycled it on idle timeout or drain).

use crate::net::wire;
use crate::util::json::{Json, JsonError};
use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

/// A parsed HTTP response with the body left as raw bytes — what the
/// binary tensor endpoints return. Header names are lowercased.
#[derive(Debug)]
pub struct RawResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl RawResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// The server-assigned (or echoed) `X-Request-Id`.
    pub fn request_id(&self) -> Option<&str> {
        self.header("x-request-id")
    }

    fn closes(&self) -> bool {
        self.header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }
}

/// A parsed HTTP response. Header names are lowercased.
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json, JsonError> {
        Json::parse(&self.body)
    }

    /// The server-assigned (or echoed) `X-Request-Id`.
    pub fn request_id(&self) -> Option<&str> {
        self.header("x-request-id")
    }

    /// The `X-Timing` stage breakdown (requires sending
    /// `X-Debug-Timing: 1`): `(stage, microseconds)` pairs in the
    /// server's `stage=us;...;total=us` order, `total` included as its
    /// own pair.
    pub fn timing(&self) -> Option<Vec<(String, u64)>> {
        let raw = self.header("x-timing")?;
        Some(
            raw.split(';')
                .filter_map(|kv| {
                    let (k, v) = kv.split_once('=')?;
                    Some((k.to_string(), v.parse().ok()?))
                })
                .collect(),
        )
    }

    fn from_raw(raw: RawResponse) -> io::Result<HttpResponse> {
        let body = String::from_utf8(raw.body).map_err(|_| bad_data("non-UTF-8 body"))?;
        Ok(HttpResponse { status: raw.status, headers: raw.headers, body })
    }

    fn closes(&self) -> bool {
        self.header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }
}

/// A keep-alive connection to one server address.
pub struct NetClient {
    addr: SocketAddr,
    stream: Option<BufReader<TcpStream>>,
    read_timeout: Duration,
}

impl NetClient {
    /// Resolve `addr` and open the first connection eagerly, so a
    /// missing/refusing server fails here rather than on first use.
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<NetClient> {
        let addr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "no address"))?;
        let mut client =
            NetClient { addr, stream: None, read_timeout: Duration::from_secs(30) };
        client.reconnect()?;
        Ok(client)
    }

    /// Cap on how long a single response read may block (default 30 s —
    /// a hang-guard for tests, not a request deadline).
    pub fn set_read_timeout(&mut self, timeout: Duration) {
        self.read_timeout = timeout;
    }

    fn reconnect(&mut self) -> io::Result<()> {
        let stream = TcpStream::connect(self.addr)?;
        stream.set_read_timeout(Some(self.read_timeout))?;
        stream.set_nodelay(true)?;
        self.stream = Some(BufReader::new(stream));
        Ok(())
    }

    /// Issue one request. Reuses the held connection when possible; if a
    /// *reused* connection turns out to be dead (stale keep-alive the
    /// server recycled: EOF/reset/broken pipe before a response), retries
    /// exactly once on a fresh one. Response-read *timeouts* are NOT
    /// retried — the request may be admitted and executing, and a resend
    /// would double-dispatch it. A failure on a fresh connection (server
    /// down, refused while draining) propagates.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: Option<&str>,
    ) -> io::Result<HttpResponse> {
        let raw = self.request_bytes(method, path, headers, body.map(|b| b.as_bytes()))?;
        HttpResponse::from_raw(raw)
    }

    /// [`NetClient::request`] without the UTF-8 assumption on either
    /// side: the byte path the binary tensor endpoints ride.
    pub fn request_bytes(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: Option<&[u8]>,
    ) -> io::Result<RawResponse> {
        let reused = self.stream.is_some();
        match self.try_request(method, path, headers, body) {
            Ok(resp) => Ok(resp),
            Err(e) if reused && stale_keep_alive(&e) => {
                self.reconnect().map_err(|_| e)?;
                self.try_request(method, path, headers, body)
            }
            Err(e) => Err(e),
        }
    }

    fn try_request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: Option<&[u8]>,
    ) -> io::Result<RawResponse> {
        if self.stream.is_none() {
            self.reconnect()?;
        }
        let result = (|| {
            let reader = self.stream.as_mut().expect("just connected");
            let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {}\r\n", self.addr);
            for (name, value) in headers {
                head.push_str(&format!("{name}: {value}\r\n"));
            }
            let body = body.unwrap_or(&[]);
            head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
            {
                let stream = reader.get_mut();
                stream.write_all(head.as_bytes())?;
                stream.write_all(body)?;
                stream.flush()?;
            }
            read_response_bytes(reader)
        })();
        match result {
            Ok(resp) => {
                if resp.closes() {
                    self.stream = None;
                }
                Ok(resp)
            }
            Err(e) => {
                // Never reuse a connection in an unknown protocol state.
                self.stream = None;
                Err(e)
            }
        }
    }

    pub fn get(&mut self, path: &str) -> io::Result<HttpResponse> {
        self.request("GET", path, &[], None)
    }

    /// `POST /v1/models/{model}:predict` with `{"instances": [...]}`.
    /// Samples are written with exact f32 round-trip, so a bit-identical
    /// tensor reaches the server.
    pub fn predict(
        &mut self,
        model: &str,
        samples: &[&[f32]],
        headers: &[(&str, &str)],
    ) -> io::Result<HttpResponse> {
        let body = predict_body(samples);
        self.request("POST", &format!("/v1/models/{model}:predict"), headers, Some(&body))
    }

    /// `POST /v1/models/{model}:predict-bin` with a binary tensor body
    /// (`sample_shape` is one sample's shape, batch dim excluded); the
    /// reply body is the mirrored binary encoding — decode it with
    /// [`decode_predictions_bin`].
    pub fn predict_bin(
        &mut self,
        model: &str,
        sample_shape: &[usize],
        samples: &[&[f32]],
        headers: &[(&str, &str)],
    ) -> io::Result<RawResponse> {
        let body = wire::encode_rows(sample_shape, samples);
        self.request_bytes(
            "POST",
            &format!("/v1/models/{model}:predict-bin"),
            headers,
            Some(&body),
        )
    }
}

/// Build an `{"instances": [...]}` predict body from flat samples.
pub fn predict_body(samples: &[&[f32]]) -> String {
    let instances: Vec<Json> = samples
        .iter()
        .map(|s| Json::Arr(s.iter().map(|&v| Json::from_f32(v)).collect()))
        .collect();
    let mut top = std::collections::BTreeMap::new();
    top.insert("instances".to_string(), Json::Arr(instances));
    Json::Obj(top).to_string()
}

/// Decode a 200 `:predict-bin` response (a binary tensor body) into rows
/// of f32 — bit-exact by construction, the payload *is* the raw bits.
pub fn decode_predictions_bin(resp: &RawResponse) -> Result<Vec<Vec<f32>>, String> {
    let h = wire::decode_header(&resp.body)?;
    let payload = h.payload(&resp.body);
    let row_bytes = h.row_bytes();
    Ok((0..h.rows)
        .map(|i| {
            payload[i * row_bytes..(i + 1) * row_bytes]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        })
        .collect())
}

/// Decode a 200 predict response into rows of f32 (exact bits, thanks to
/// the round-trip number format on both sides).
pub fn decode_predictions(resp: &HttpResponse) -> Result<Vec<Vec<f32>>, String> {
    let doc = resp.json().map_err(|e| e.to_string())?;
    let Some(rows) = doc.get("predictions").as_arr() else {
        return Err(format!("no \"predictions\" in {}", resp.body));
    };
    rows.iter()
        .map(|row| {
            row.as_arr()
                .ok_or_else(|| "prediction row is not an array".to_string())?
                .iter()
                .map(|v| v.as_f32().ok_or_else(|| "non-numeric prediction".to_string()))
                .collect()
        })
        .collect()
}

/// One-shot request on a fresh connection (`Connection: close`): used
/// where connection reuse would hide what is being tested (e.g. "are new
/// connections refused during drain?").
pub fn one_shot(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: Option<&str>,
) -> io::Result<HttpResponse> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n");
    for (name, value) in headers {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    let body = body.unwrap_or("");
    head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    read_response(&mut BufReader::new(stream))
}

/// Failure shapes a recycled keep-alive connection produces when the
/// server closed it while we were idle — safe to retry because the new
/// request cannot have been admitted. Timeouts and protocol errors are
/// excluded: those can follow a fully-sent request.
fn stale_keep_alive(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::UnexpectedEof
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::BrokenPipe
    )
}

fn bad_data(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

fn read_response<S: Read>(reader: &mut BufReader<S>) -> io::Result<HttpResponse> {
    HttpResponse::from_raw(read_response_bytes(reader)?)
}

fn read_response_bytes<S: Read>(reader: &mut BufReader<S>) -> io::Result<RawResponse> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "connection closed"));
    }
    let line = line.trim_end();
    // "HTTP/1.1 200 OK" — the reason phrase may contain spaces.
    let mut parts = line.splitn(3, ' ');
    let (Some(version), Some(code)) = (parts.next(), parts.next()) else {
        return Err(bad_data(format!("malformed status line '{line}'")));
    };
    if !version.starts_with("HTTP/1.") {
        return Err(bad_data(format!("not an HTTP response: '{line}'")));
    }
    let status: u16 = code
        .parse()
        .map_err(|_| bad_data(format!("bad status code in '{line}'")))?;

    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof in headers"));
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }
    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse().ok())
        .ok_or_else(|| bad_data("response without Content-Length"))?;
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(RawResponse { status, headers, body })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_body_round_trips_f32_bits() {
        let samples: Vec<f32> = vec![0.1, -0.0, 1.0 / 3.0, f32::MIN_POSITIVE];
        let body = predict_body(&[&samples]);
        let doc = Json::parse(&body).unwrap();
        let row = doc.get("instances").idx(0).as_arr().unwrap();
        for (want, got) in samples.iter().zip(row) {
            assert_eq!(want.to_bits(), got.as_f32().unwrap().to_bits());
        }
    }

    #[test]
    fn binary_response_bodies_survive_the_byte_path() {
        let row: Vec<f32> = vec![0.0, -0.0, 1.0e-40, 3.5];
        let payload = wire::encode_rows(&[4], &[row.as_slice()]);
        let mut doc = format!(
            "HTTP/1.1 200 OK\r\nContent-Type: application/x-tf-fpga-tensor\r\n\
             Content-Length: {}\r\n\r\n",
            payload.len()
        )
        .into_bytes();
        doc.extend_from_slice(&payload);
        let resp = read_response_bytes(&mut BufReader::new(doc.as_slice())).unwrap();
        assert_eq!(resp.status, 200);
        let got = decode_predictions_bin(&resp).unwrap();
        assert_eq!(got.len(), 1);
        for (g, w) in got[0].iter().zip(&row) {
            assert_eq!(g.to_bits(), w.to_bits(), "binary body must be bit-exact");
        }
    }

    #[test]
    fn timing_header_parses_into_stage_pairs() {
        let resp = HttpResponse {
            status: 200,
            headers: vec![
                ("x-request-id".to_string(), "r-0000002a".to_string()),
                (
                    "x-timing".to_string(),
                    "admission_wait=120;batch_wait=950;kernel_exec=80;total=1400".to_string(),
                ),
            ],
            body: String::new(),
        };
        assert_eq!(resp.request_id(), Some("r-0000002a"));
        let timing = resp.timing().unwrap();
        assert_eq!(timing[0], ("admission_wait".to_string(), 120));
        assert_eq!(timing.last().unwrap(), &("total".to_string(), 1400));
        assert_eq!(timing.len(), 4);

        let bare = HttpResponse { status: 200, headers: vec![], body: String::new() };
        assert!(bare.timing().is_none());
        assert!(bare.request_id().is_none());
    }

    #[test]
    fn parses_response_with_spaced_reason_phrase() {
        let doc = "HTTP/1.1 429 Too Many Requests\r\nRetry-After: 2\r\n\
                   Content-Length: 2\r\nConnection: close\r\n\r\n{}";
        let resp = read_response(&mut BufReader::new(doc.as_bytes())).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("2"));
        assert_eq!(resp.body, "{}");
        assert!(resp.closes());
    }
}
