//! Frontend counters and the Prometheus text exposition for
//! `GET /metrics`.
//!
//! [`NetCounters`] accounts what happens at the HTTP boundary
//! (connections, responses by status code, sheds by reason, deadline
//! cancellations); [`render`] merges a snapshot of those with the serving
//! pipeline's [`CounterSnapshot`], the per-agent [`ShardAgentReport`]
//! rows, and the per-stage request-latency [`Histogram`]s into the
//! Prometheus text format (version 0.0.4 — `# HELP`/`# TYPE` preambles,
//! `name{labels} value` samples; stage latencies use the native
//! histogram exposition: cumulative `_bucket{le=...}` plus `_sum` and
//! `_count`).

use crate::metrics::counters::CounterSnapshot;
use crate::metrics::histogram::Histogram;
use crate::sharding::ShardAgentReport;
use crate::trace::Stage;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Atomic accounting of the HTTP boundary, shared across worker threads.
#[derive(Debug, Default)]
pub struct NetCounters {
    connections: AtomicU64,
    refused_draining: AtomicU64,
    shed_pending: AtomicU64,
    shed_tenant: AtomicU64,
    shed_backlog: AtomicU64,
    deadline_expired: AtomicU64,
    /// Responses by status code; a `Mutex<BTreeMap>` is plenty at HTTP
    /// request rates and keeps the exposition order deterministic.
    responses: Mutex<BTreeMap<u16, u64>>,
}

/// Point-in-time copy of [`NetCounters`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetSnapshot {
    pub connections: u64,
    pub refused_draining: u64,
    pub shed_pending: u64,
    pub shed_tenant: u64,
    pub shed_backlog: u64,
    pub deadline_expired: u64,
    pub responses: BTreeMap<u16, u64>,
}

impl NetSnapshot {
    /// Total responses carrying `status`.
    pub fn responses_with(&self, status: u16) -> u64 {
        self.responses.get(&status).copied().unwrap_or(0)
    }
}

impl NetCounters {
    pub fn new() -> NetCounters {
        NetCounters::default()
    }

    pub fn on_connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_refused_draining(&self) {
        self.refused_draining.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_shed_pending(&self) {
        self.shed_pending.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_shed_tenant(&self) {
        self.shed_tenant.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection was refused because the bounded worker backlog was
    /// full — overload shed before any request parsing.
    pub fn on_shed_backlog(&self) {
        self.shed_backlog.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_deadline_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_response(&self, status: u16) {
        *self.responses.lock().unwrap().entry(status).or_insert(0) += 1;
    }

    pub fn snapshot(&self) -> NetSnapshot {
        NetSnapshot {
            connections: self.connections.load(Ordering::Relaxed),
            refused_draining: self.refused_draining.load(Ordering::Relaxed),
            shed_pending: self.shed_pending.load(Ordering::Relaxed),
            shed_tenant: self.shed_tenant.load(Ordering::Relaxed),
            shed_backlog: self.shed_backlog.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            responses: self.responses.lock().unwrap().clone(),
        }
    }
}

fn metric(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

/// Render the full `/metrics` document: HTTP-boundary counters, serving
/// pipeline counters, one labelled sample per pool agent, the per-stage
/// request-latency histograms, and the flight recorder's drop counter.
pub fn render(
    net: &NetSnapshot,
    serve: &CounterSnapshot,
    pool: &[ShardAgentReport],
    draining: bool,
    stages: &[(Stage, Histogram)],
    trace_dropped: u64,
) -> String {
    let mut out = String::with_capacity(2048);

    metric(&mut out, "tf_fpga_http_connections_total", "counter", "Accepted TCP connections.");
    let _ = writeln!(out, "tf_fpga_http_connections_total {}", net.connections);
    metric(
        &mut out,
        "tf_fpga_http_responses_total",
        "counter",
        "HTTP responses by status code.",
    );
    for (code, n) in &net.responses {
        let _ = writeln!(out, "tf_fpga_http_responses_total{{code=\"{code}\"}} {n}");
    }
    metric(
        &mut out,
        "tf_fpga_http_shed_total",
        "counter",
        "Requests shed by admission control, by reason.",
    );
    let _ = writeln!(out, "tf_fpga_http_shed_total{{reason=\"pending\"}} {}", net.shed_pending);
    let _ = writeln!(out, "tf_fpga_http_shed_total{{reason=\"tenant\"}} {}", net.shed_tenant);
    let _ = writeln!(out, "tf_fpga_http_shed_total{{reason=\"backlog\"}} {}", net.shed_backlog);
    let _ = writeln!(
        out,
        "tf_fpga_http_shed_total{{reason=\"draining\"}} {}",
        net.refused_draining
    );
    metric(
        &mut out,
        "tf_fpga_http_deadline_expired_total",
        "counter",
        "Requests cancelled before dispatch because their deadline had passed.",
    );
    let _ = writeln!(out, "tf_fpga_http_deadline_expired_total {}", net.deadline_expired);
    metric(&mut out, "tf_fpga_http_draining", "gauge", "1 while the server drains for shutdown.");
    let _ = writeln!(out, "tf_fpga_http_draining {}", u8::from(draining));

    metric(
        &mut out,
        "tf_fpga_serve_requests_total",
        "counter",
        "Requests submitted into the serving pipeline.",
    );
    let _ = writeln!(out, "tf_fpga_serve_requests_total {}", serve.submitted);
    metric(&mut out, "tf_fpga_serve_completed_total", "counter", "Requests answered successfully.");
    let _ = writeln!(out, "tf_fpga_serve_completed_total {}", serve.completed);
    metric(&mut out, "tf_fpga_serve_failed_total", "counter", "Requests that failed in the pipeline.");
    let _ = writeln!(out, "tf_fpga_serve_failed_total {}", serve.failed);
    metric(&mut out, "tf_fpga_serve_batches_total", "counter", "Micro-batches dispatched.");
    let _ = writeln!(out, "tf_fpga_serve_batches_total {}", serve.batches);
    metric(
        &mut out,
        "tf_fpga_serve_inflight_batches",
        "gauge",
        "Batches dispatched but not yet retired.",
    );
    let _ = writeln!(out, "tf_fpga_serve_inflight_batches {}", serve.inflight);
    metric(
        &mut out,
        "tf_fpga_serve_late_joins_total",
        "counter",
        "Requests admitted into a batch whose flush had already begun.",
    );
    let _ = writeln!(out, "tf_fpga_serve_late_joins_total {}", serve.late_joins);
    metric(
        &mut out,
        "tf_fpga_serve_bytes_copied_total",
        "counter",
        "Bytes that took an extra host-memory copy on the ingestion path.",
    );
    let _ = writeln!(out, "tf_fpga_serve_bytes_copied_total {}", serve.bytes_copied);
    metric(
        &mut out,
        "tf_fpga_serve_batch_fill_ratio",
        "gauge",
        "Fraction of dispatched batch capacity carrying real requests.",
    );
    let _ = writeln!(out, "tf_fpga_serve_batch_fill_ratio {}", serve.batch_fill_ratio());

    metric(
        &mut out,
        "tf_fpga_agent_dispatches_total",
        "counter",
        "Kernel dispatches routed to each FPGA agent.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_agent_dispatches_total{{agent=\"{}\"}} {}",
            shard.agent, shard.dispatches
        );
    }
    metric(&mut out, "tf_fpga_agent_inflight", "gauge", "Dispatches in flight per agent.");
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_agent_inflight{{agent=\"{}\"}} {}",
            shard.agent, shard.inflight
        );
    }
    metric(
        &mut out,
        "tf_fpga_agent_reconfig_misses_total",
        "counter",
        "Partial reconfigurations (role-residency misses) per agent.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_agent_reconfig_misses_total{{agent=\"{}\"}} {}",
            shard.agent, shard.reconfig.misses
        );
    }
    metric(
        &mut out,
        "tf_fpga_agent_reconfig_us_total",
        "counter",
        "Modeled reconfiguration time per agent, microseconds.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_agent_reconfig_us_total{{agent=\"{}\"}} {}",
            shard.agent, shard.reconfig.reconfig_us_total
        );
    }
    metric(
        &mut out,
        "tf_fpga_reconfig_prefetch_hits_total",
        "counter",
        "Dispatches that found their role already loaded (or loading) by the prefetch scheduler.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_reconfig_prefetch_hits_total{{agent=\"{}\"}} {}",
            shard.agent, shard.reconfig.prefetch_hits
        );
    }
    metric(
        &mut out,
        "tf_fpga_reconfig_prefetch_wasted_total",
        "counter",
        "Prefetched roles evicted before any dispatch used them.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_reconfig_prefetch_wasted_total{{agent=\"{}\"}} {}",
            shard.agent, shard.reconfig.prefetch_wasted
        );
    }
    metric(
        &mut out,
        "tf_fpga_reconfig_stall_us_total",
        "counter",
        "Modeled microseconds dispatches spent waiting on ICAP transfers.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_reconfig_stall_us_total{{agent=\"{}\"}} {}",
            shard.agent, shard.reconfig.stall_us
        );
    }
    metric(
        &mut out,
        "tf_fpga_reconfig_overlapped_us_total",
        "counter",
        "Modeled ICAP transfer microseconds hidden behind compute by prefetching.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_reconfig_overlapped_us_total{{agent=\"{}\"}} {}",
            shard.agent, shard.reconfig.overlapped_us
        );
    }
    metric(
        &mut out,
        "tf_fpga_agent_quarantined",
        "gauge",
        "1 while the agent is quarantined (excluded from routing).",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_agent_quarantined{{agent=\"{}\"}} {}",
            shard.agent,
            u8::from(shard.quarantined)
        );
    }
    metric(
        &mut out,
        "tf_fpga_agent_quarantines_total",
        "counter",
        "Times the agent entered quarantine.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_agent_quarantines_total{{agent=\"{}\"}} {}",
            shard.agent, shard.quarantines
        );
    }
    metric(
        &mut out,
        "tf_fpga_agent_readmissions_total",
        "counter",
        "Times the agent was re-admitted to routing after quarantine.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_agent_readmissions_total{{agent=\"{}\"}} {}",
            shard.agent, shard.readmissions
        );
    }
    metric(
        &mut out,
        "tf_fpga_agent_retries_total",
        "counter",
        "Dispatches abandoned on the agent and retried on an alternate.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_agent_retries_total{{agent=\"{}\"}} {}",
            shard.agent, shard.retries
        );
    }
    metric(
        &mut out,
        "tf_fpga_agent_oldest_inflight_us",
        "gauge",
        "Age of the agent's oldest still-executing dispatch, microseconds.",
    );
    for shard in pool {
        let _ = writeln!(
            out,
            "tf_fpga_agent_oldest_inflight_us{{agent=\"{}\"}} {}",
            shard.agent, shard.oldest_inflight_us
        );
    }

    // Per-stage request latency: the log2 ring of [`Histogram`] maps to
    // cumulative Prometheus buckets with `le = 2^(i+1)` (every value in
    // bucket `i` is `< 2^(i+1)`). Buckets past the highest occupied one
    // are elided — `+Inf` always closes the series.
    metric(
        &mut out,
        "tf_fpga_stage_latency_us",
        "histogram",
        "Per-request pipeline stage latency, microseconds.",
    );
    for (stage, hist) in stages {
        let name = stage.name();
        let counts = hist.bucket_counts();
        let mut cum = 0u64;
        if let Some(hi) = counts.iter().rposition(|&c| c > 0) {
            for (i, &c) in counts.iter().enumerate().take(hi + 1) {
                cum += c;
                let le = 1u128 << (i + 1);
                let _ = writeln!(
                    out,
                    "tf_fpga_stage_latency_us_bucket{{stage=\"{name}\",le=\"{le}\"}} {cum}"
                );
            }
        }
        let _ = writeln!(
            out,
            "tf_fpga_stage_latency_us_bucket{{stage=\"{name}\",le=\"+Inf\"}} {}",
            hist.count()
        );
        let _ = writeln!(out, "tf_fpga_stage_latency_us_sum{{stage=\"{name}\"}} {}", hist.sum());
        let _ = writeln!(out, "tf_fpga_stage_latency_us_count{{stage=\"{name}\"}} {}", hist.count());
    }

    metric(
        &mut out,
        "tf_fpga_trace_events_dropped_total",
        "counter",
        "Trace events evicted from the flight-recorder ring since start.",
    );
    let _ = writeln!(out, "tf_fpga_trace_events_dropped_total {trace_dropped}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reconfig::manager::ReconfigStats;

    #[test]
    fn counters_snapshot_round_trip() {
        let c = NetCounters::new();
        c.on_connection();
        c.on_connection();
        c.on_response(200);
        c.on_response(200);
        c.on_response(429);
        c.on_shed_pending();
        c.on_shed_tenant();
        c.on_shed_backlog();
        c.on_deadline_expired();
        c.on_refused_draining();
        let s = c.snapshot();
        assert_eq!(s.connections, 2);
        assert_eq!(s.responses_with(200), 2);
        assert_eq!(s.responses_with(429), 1);
        assert_eq!(s.responses_with(500), 0);
        assert_eq!(
            (s.shed_pending, s.shed_tenant, s.shed_backlog, s.deadline_expired, s.refused_draining),
            (1, 1, 1, 1, 1)
        );
    }

    #[test]
    fn render_exposes_request_shed_and_per_agent_counters() {
        let c = NetCounters::new();
        c.on_response(200);
        c.on_response(429);
        c.on_shed_pending();
        let serve = CounterSnapshot {
            submitted: 7,
            completed: 6,
            failed: 1,
            batches: 3,
            fill_sum: 6,
            fill_capacity: 12,
            late_joins: 2,
            bytes_copied: 128,
            ..Default::default()
        };
        let pool = vec![
            ShardAgentReport {
                agent: "ultra96-pl-0".into(),
                dispatches: 5,
                inflight: 1,
                max_inflight: 2,
                reconfig: ReconfigStats {
                    misses: 2,
                    reconfig_us_total: 9000,
                    prefetch_hits: 3,
                    prefetch_wasted: 1,
                    stall_us: 7000,
                    overlapped_us: 2000,
                    ..Default::default()
                },
                quarantined: false,
                quarantines: 0,
                readmissions: 0,
                retries: 0,
                alive: true,
                heartbeat_age_us: Some(120),
                oldest_inflight_us: 0,
            },
            ShardAgentReport {
                agent: "ultra96-pl-1".into(),
                dispatches: 4,
                inflight: 0,
                max_inflight: 1,
                reconfig: ReconfigStats::default(),
                quarantined: true,
                quarantines: 2,
                readmissions: 1,
                retries: 3,
                alive: false,
                heartbeat_age_us: None,
                oldest_inflight_us: 4200,
            },
        ];
        let mut admission = Histogram::new();
        admission.record(3); // bucket 1 (le 4)
        admission.record(5); // bucket 2 (le 8)
        admission.record(6); // bucket 2 (le 8)
        let stages = vec![(Stage::AdmissionWait, admission), (Stage::KernelExec, Histogram::new())];
        let text = render(&c.snapshot(), &serve, &pool, true, &stages, 17);
        for needle in [
            "tf_fpga_http_responses_total{code=\"200\"} 1",
            "tf_fpga_http_responses_total{code=\"429\"} 1",
            "tf_fpga_http_shed_total{reason=\"pending\"} 1",
            "tf_fpga_http_shed_total{reason=\"tenant\"} 0",
            "tf_fpga_http_shed_total{reason=\"backlog\"} 0",
            "tf_fpga_http_draining 1",
            "tf_fpga_serve_requests_total 7",
            "tf_fpga_serve_completed_total 6",
            "tf_fpga_serve_late_joins_total 2",
            "tf_fpga_serve_bytes_copied_total 128",
            "tf_fpga_serve_batch_fill_ratio 0.5",
            "tf_fpga_agent_dispatches_total{agent=\"ultra96-pl-0\"} 5",
            "tf_fpga_agent_dispatches_total{agent=\"ultra96-pl-1\"} 4",
            "tf_fpga_agent_reconfig_misses_total{agent=\"ultra96-pl-0\"} 2",
            "tf_fpga_reconfig_prefetch_hits_total{agent=\"ultra96-pl-0\"} 3",
            "tf_fpga_reconfig_prefetch_wasted_total{agent=\"ultra96-pl-0\"} 1",
            "tf_fpga_reconfig_stall_us_total{agent=\"ultra96-pl-0\"} 7000",
            "tf_fpga_reconfig_overlapped_us_total{agent=\"ultra96-pl-0\"} 2000",
            "tf_fpga_reconfig_prefetch_hits_total{agent=\"ultra96-pl-1\"} 0",
            "tf_fpga_agent_quarantined{agent=\"ultra96-pl-0\"} 0",
            "tf_fpga_agent_quarantined{agent=\"ultra96-pl-1\"} 1",
            "tf_fpga_agent_quarantines_total{agent=\"ultra96-pl-1\"} 2",
            "tf_fpga_agent_readmissions_total{agent=\"ultra96-pl-1\"} 1",
            "tf_fpga_agent_retries_total{agent=\"ultra96-pl-1\"} 3",
            "tf_fpga_agent_oldest_inflight_us{agent=\"ultra96-pl-1\"} 4200",
            "# TYPE tf_fpga_http_responses_total counter",
            "# TYPE tf_fpga_stage_latency_us histogram",
            "tf_fpga_stage_latency_us_bucket{stage=\"admission_wait\",le=\"2\"} 0",
            "tf_fpga_stage_latency_us_bucket{stage=\"admission_wait\",le=\"4\"} 1",
            "tf_fpga_stage_latency_us_bucket{stage=\"admission_wait\",le=\"8\"} 3",
            "tf_fpga_stage_latency_us_bucket{stage=\"admission_wait\",le=\"+Inf\"} 3",
            "tf_fpga_stage_latency_us_sum{stage=\"admission_wait\"} 14",
            "tf_fpga_stage_latency_us_count{stage=\"admission_wait\"} 3",
            "tf_fpga_stage_latency_us_bucket{stage=\"kernel_exec\",le=\"+Inf\"} 0",
            "tf_fpga_stage_latency_us_count{stage=\"kernel_exec\"} 0",
            "tf_fpga_trace_events_dropped_total 17",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Cumulative buckets never decrease and the elision stops at the
        // highest occupied bucket: no admission_wait bucket past le="8"
        // other than +Inf.
        assert!(!text.contains("stage=\"admission_wait\",le=\"16\""), "{text}");
    }
}
