//! Admission control for the HTTP frontend: who gets past the front door
//! before any tensor work happens.
//!
//! Three independent gates, applied in order by the server:
//!
//! 1. **Per-tenant rate limiting** ([`RateLimiter`]) — one token bucket
//!    per `X-Tenant` value, refilled at a configured requests-per-second
//!    rate up to a burst capacity. Buckets are integer-arithmetic over an
//!    injected [`Clock`], so behaviour is deterministic under test and a
//!    rejected request gets an honest `Retry-After`.
//! 2. **Bounded pending gate** ([`PendingGate`]) — a high-water mark on
//!    requests admitted but not yet answered. Past it the server sheds
//!    load with `429` instead of queueing without bound; the RAII
//!    [`PendingPermit`] guarantees the gauge retires even on error paths.
//! 3. **Deadlines** ([`Deadline`]) — an `X-Deadline-Ms` budget checked
//!    after admission and *before dispatch*: a request that already blew
//!    its budget while queueing is cancelled without ever touching the
//!    inference pipeline.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Monotonic time source in microseconds. Injected so the token buckets
/// (and their tests) are pure functions of the observed call sequence
/// rather than of wall-clock scheduling jitter.
pub trait Clock: Send + Sync {
    fn now_micros(&self) -> u64;
}

/// The production clock: microseconds since construction, monotonic.
#[derive(Debug)]
pub struct SystemClock {
    origin: Instant,
}

impl SystemClock {
    pub fn new() -> SystemClock {
        SystemClock { origin: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        SystemClock::new()
    }
}

impl Clock for SystemClock {
    fn now_micros(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }
}

/// A hand-cranked clock for deterministic tests.
#[derive(Debug, Default)]
pub struct ManualClock(AtomicU64);

impl ManualClock {
    pub fn new(start_micros: u64) -> ManualClock {
        ManualClock(AtomicU64::new(start_micros))
    }

    pub fn advance_micros(&self, d: u64) {
        self.0.fetch_add(d, Ordering::SeqCst);
    }

    pub fn advance_ms(&self, ms: u64) {
        self.advance_micros(ms * 1_000);
    }
}

impl Clock for ManualClock {
    fn now_micros(&self) -> u64 {
        self.0.load(Ordering::SeqCst)
    }
}

/// One token, scaled: buckets count micro-tokens so refill math stays in
/// integers (`elapsed_micros × rps` micro-tokens accrue per elapsed µs).
const TOKEN: u64 = 1_000_000;

/// Past this many tracked tenants, `try_acquire` sweeps out buckets that
/// have refilled to capacity — a full bucket is indistinguishable from a
/// fresh one, so eviction is semantically lossless. Bounds the memory an
/// attacker can pin with random `X-Tenant` values to roughly the request
/// rate × one refill interval.
const TENANT_SWEEP_THRESHOLD: usize = 8 * 1024;

#[derive(Debug)]
struct Bucket {
    /// Micro-tokens currently available, ≤ `burst * TOKEN`.
    tokens: u64,
    /// Clock reading at the last refill.
    last: u64,
}

/// Deterministic per-tenant token buckets: `rps` sustained requests per
/// second per tenant, bursts up to `burst`. Tenants are fully independent
/// — one tenant flooding cannot consume another's tokens, which is what
/// makes per-tenant throughput fair under overload.
pub struct RateLimiter {
    rps: u64,
    burst: u64,
    clock: Arc<dyn Clock>,
    buckets: Mutex<BTreeMap<String, Bucket>>,
}

impl RateLimiter {
    /// `rps` is clamped to ≥ 1 (a zero rate means "don't build a
    /// limiter", not "reject everyone"); `burst` to ≥ 1 so a fresh tenant
    /// can always issue at least one request.
    pub fn new(rps: u64, burst: u64, clock: Arc<dyn Clock>) -> RateLimiter {
        RateLimiter {
            rps: rps.max(1),
            burst: burst.max(1),
            clock,
            buckets: Mutex::new(BTreeMap::new()),
        }
    }

    /// Take one token from `tenant`'s bucket. `Err(secs)` is the
    /// whole-second `Retry-After` a shed response should carry (≥ 1).
    pub fn try_acquire(&self, tenant: &str) -> Result<(), u64> {
        // `n = 1` always fits the (≥ 1) burst, so `Err(None)` cannot
        // occur; the fallback is unreachable.
        self.try_acquire_n(tenant, 1).map_err(|e| e.unwrap_or(1))
    }

    /// Take `n` tokens atomically — all or nothing, so a too-big batch
    /// cannot drain the bucket and starve the tenant's other requests.
    /// `Err(None)` means `n` exceeds the burst capacity and can *never*
    /// succeed (the caller should reject, not retry); `Err(Some(secs))`
    /// is the honest `Retry-After` for the full `n`-token deficit.
    pub fn try_acquire_n(&self, tenant: &str, n: u64) -> Result<(), Option<u64>> {
        if n == 0 {
            return Ok(());
        }
        if n > self.burst {
            return Err(None);
        }
        let now = self.clock.now_micros();
        let cap = self.burst * TOKEN;
        let mut buckets = self.buckets.lock().unwrap();
        if buckets.len() > TENANT_SWEEP_THRESHOLD {
            // Drop effectively-fresh buckets so attacker-chosen tenant
            // names cannot grow the map forever. O(n), amortized by the
            // threshold.
            let rps = self.rps;
            buckets.retain(|_, b| {
                let elapsed = now.saturating_sub(b.last);
                let refill = (elapsed as u128 * rps as u128).min(cap as u128) as u64;
                b.tokens.saturating_add(refill) < cap
            });
        }
        let b = buckets
            .entry(tenant.to_string())
            .or_insert(Bucket { tokens: cap, last: now });
        let elapsed = now.saturating_sub(b.last);
        b.last = now;
        let refill = (elapsed as u128 * self.rps as u128).min(cap as u128) as u64;
        b.tokens = b.tokens.saturating_add(refill).min(cap);
        let need = n * TOKEN;
        if b.tokens >= need {
            b.tokens -= need;
            Ok(())
        } else {
            let deficit = need - b.tokens;
            let wait_micros = (deficit + self.rps - 1) / self.rps;
            Err(Some(((wait_micros + TOKEN - 1) / TOKEN).max(1)))
        }
    }

    /// Tenants seen so far (metrics/debugging).
    pub fn tenants(&self) -> usize {
        self.buckets.lock().unwrap().len()
    }
}

/// Bounded count of admitted-but-unanswered requests. `try_acquire`
/// returns `None` once `max` are pending — the caller sheds with `429`.
#[derive(Debug)]
pub struct PendingGate {
    current: Arc<AtomicU64>,
    max: u64,
}

impl PendingGate {
    pub fn new(max: u64) -> PendingGate {
        PendingGate { current: Arc::new(AtomicU64::new(0)), max: max.max(1) }
    }

    pub fn try_acquire(&self) -> Option<PendingPermit> {
        let now = self.current.fetch_add(1, Ordering::AcqRel) + 1;
        if now > self.max {
            self.current.fetch_sub(1, Ordering::AcqRel);
            None
        } else {
            Some(PendingPermit { current: Arc::clone(&self.current) })
        }
    }

    /// [`PendingGate::try_acquire`] annotated onto a request span: the
    /// gate's admission decision — the pending level at entry, or the
    /// rejection — lands on the request's trace track. Observational:
    /// admission itself is identical to the unspanned call.
    pub fn try_acquire_spanned(
        &self,
        span: &crate::trace::SpanCtx,
    ) -> Option<PendingPermit> {
        let permit = self.try_acquire();
        if span.enabled() {
            match &permit {
                Some(_) => span.annotate(format!(
                    "admitted (pending {}/{})",
                    self.pending(),
                    self.max
                )),
                None => span.annotate(format!("shed: pending gate full ({})", self.max)),
            }
        }
        permit
    }

    /// Requests currently holding a permit. May transiently read up to
    /// one above `max` per concurrent caller: `try_acquire` increments
    /// optimistically and undoes on rejection, so treat this as a
    /// diagnostic gauge, not an invariant.
    pub fn pending(&self) -> u64 {
        self.current.load(Ordering::Acquire)
    }

    pub fn max(&self) -> u64 {
        self.max
    }
}

/// RAII admission: dropping the permit retires the request from the
/// pending gauge, whatever path (success, error, panic unwind) it exits
/// through.
#[derive(Debug)]
pub struct PendingPermit {
    current: Arc<AtomicU64>,
}

impl Drop for PendingPermit {
    fn drop(&mut self) {
        self.current.fetch_sub(1, Ordering::AcqRel);
    }
}

/// An absolute per-request deadline on the injected clock.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    at_micros: u64,
}

impl Deadline {
    /// `budget_ms` from now. Saturates: an absurd client-supplied budget
    /// means "effectively no deadline", never an overflow.
    pub fn after_ms(clock: &dyn Clock, budget_ms: u64) -> Deadline {
        Deadline {
            at_micros: clock.now_micros().saturating_add(budget_ms.saturating_mul(1_000)),
        }
    }

    pub fn expired(&self, clock: &dyn Clock) -> bool {
        clock.now_micros() >= self.at_micros
    }

    /// Time left, zero once expired — shaped for `recv_timeout`.
    pub fn remaining(&self, clock: &dyn Clock) -> Duration {
        Duration::from_micros(self.at_micros.saturating_sub(clock.now_micros()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limiter(rps: u64, burst: u64) -> (Arc<ManualClock>, RateLimiter) {
        let clock = Arc::new(ManualClock::new(0));
        let l = RateLimiter::new(rps, burst, Arc::clone(&clock) as Arc<dyn Clock>);
        (clock, l)
    }

    #[test]
    fn bucket_allows_burst_then_refills_at_rps() {
        let (clock, l) = limiter(1, 2);
        assert!(l.try_acquire("a").is_ok());
        assert!(l.try_acquire("a").is_ok(), "burst of 2");
        assert_eq!(l.try_acquire("a"), Err(1), "bucket empty: retry in 1s");
        clock.advance_ms(999);
        assert!(l.try_acquire("a").is_err(), "999 ms < one token at 1 rps");
        clock.advance_ms(1);
        assert!(l.try_acquire("a").is_ok(), "exactly one token accrued");
        assert!(l.try_acquire("a").is_err(), "and only one");
    }

    #[test]
    fn tenants_have_independent_buckets() {
        let (_clock, l) = limiter(1, 1);
        assert!(l.try_acquire("a").is_ok());
        assert!(l.try_acquire("a").is_err(), "a exhausted");
        assert!(l.try_acquire("b").is_ok(), "b unaffected by a's flood");
        assert!(l.try_acquire("c").is_ok());
        assert_eq!(l.tenants(), 3);
    }

    #[test]
    fn sustained_fairness_two_tenants_equal_rates() {
        // Both tenants hammer for 10 simulated seconds; each gets exactly
        // burst + rps*10 through — deterministic, clock-injected fairness.
        let (clock, l) = limiter(3, 3);
        let (mut a_ok, mut b_ok) = (0, 0);
        for _ in 0..100 {
            for _ in 0..5 {
                if l.try_acquire("a").is_ok() {
                    a_ok += 1;
                }
                if l.try_acquire("b").is_ok() {
                    b_ok += 1;
                }
            }
            clock.advance_ms(100);
        }
        assert_eq!(a_ok, b_ok, "identical offered load, identical quota");
        // 3 burst + 3/s * 10 s (the final refills land within the loop).
        assert!((30..=33).contains(&a_ok), "≈ burst + rps·t, got {a_ok}");
    }

    #[test]
    fn retry_after_reflects_the_deficit() {
        let (clock, l) = limiter(2, 1);
        assert!(l.try_acquire("a").is_ok());
        // 2 rps → half a second to the next token → rounds up to 1 s.
        assert_eq!(l.try_acquire("a"), Err(1));
        let (_c2, slow) = limiter(1, 1);
        assert!(slow.try_acquire("a").is_ok());
        assert_eq!(slow.try_acquire("a"), Err(1));
        drop(clock);
    }

    #[test]
    fn refill_caps_at_burst() {
        let (clock, l) = limiter(10, 2);
        assert!(l.try_acquire("a").is_ok());
        clock.advance_ms(60_000); // a minute idle: still only burst=2 stored
        assert!(l.try_acquire("a").is_ok());
        assert!(l.try_acquire("a").is_ok());
        assert!(l.try_acquire("a").is_err(), "idle time does not stockpile");
    }

    #[test]
    fn bulk_acquire_is_atomic_and_never_partially_drains() {
        let (clock, l) = limiter(2, 4);
        // 3 of 4 available after one single acquire.
        assert!(l.try_acquire("a").is_ok());
        // Asking for 4 fails — and must leave the 3 tokens untouched.
        assert_eq!(l.try_acquire_n("a", 4), Err(Some(1)), "deficit 1 token at 2 rps");
        assert!(l.try_acquire_n("a", 3).is_ok(), "nothing was drained by the failure");
        // More than burst can never succeed: permanent refusal, not retry.
        assert_eq!(l.try_acquire_n("a", 5), Err(None));
        // After the advised wait, the retryable batch fits.
        clock.advance_ms(2_000);
        assert!(l.try_acquire_n("a", 4).is_ok());
        // Zero is a no-op.
        assert!(l.try_acquire_n("a", 0).is_ok());
    }

    #[test]
    fn refilled_tenant_buckets_are_swept_past_the_threshold() {
        let (clock, l) = limiter(1, 1);
        // An attacker churns unique tenant names; each bucket is drained
        // (tokens < cap) so the sweep keeps them at first.
        for i in 0..=TENANT_SWEEP_THRESHOLD {
            assert!(l.try_acquire(&format!("t{i}")).is_ok());
        }
        assert_eq!(l.tenants(), TENANT_SWEEP_THRESHOLD + 1);
        // Once they refill to capacity they are indistinguishable from
        // fresh buckets; the next over-threshold acquire sweeps them.
        clock.advance_ms(2_000);
        assert!(l.try_acquire("fresh").is_ok());
        assert!(
            l.tenants() <= 2,
            "full buckets evicted, got {} tracked tenants",
            l.tenants()
        );
        // The surviving (current) tenant still has its real state.
        assert!(l.try_acquire("fresh").is_err(), "fresh already spent its burst");
    }

    #[test]
    fn zero_config_is_clamped_not_divide_by_zero() {
        let (_clock, l) = limiter(0, 0);
        assert!(l.try_acquire("a").is_ok(), "clamped to 1 rps / burst 1");
        assert!(l.try_acquire("a").is_err());
    }

    #[test]
    fn gate_admits_to_max_and_permit_drop_releases() {
        let gate = PendingGate::new(2);
        let p1 = gate.try_acquire().expect("1st");
        let _p2 = gate.try_acquire().expect("2nd");
        assert!(gate.try_acquire().is_none(), "gate full");
        assert_eq!(gate.pending(), 2);
        drop(p1);
        assert_eq!(gate.pending(), 1);
        assert!(gate.try_acquire().is_some(), "freed slot re-admits");
    }

    #[test]
    fn gate_never_exceeds_max_under_contention() {
        use std::sync::atomic::AtomicU64;
        let gate = Arc::new(PendingGate::new(4));
        // Count *held permits* directly: `pending()` may transiently
        // overshoot while a rejected try_acquire sits between its
        // optimistic increment and the undo, so sampling it here would
        // be racy by construction.
        let held = Arc::new(AtomicU64::new(0));
        let peak = Arc::new(AtomicU64::new(0));
        let admitted = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let gate = Arc::clone(&gate);
                let held = Arc::clone(&held);
                let peak = Arc::clone(&peak);
                let admitted = Arc::clone(&admitted);
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        if let Some(_permit) = gate.try_acquire() {
                            admitted.fetch_add(1, Ordering::Relaxed);
                            let now = held.fetch_add(1, Ordering::SeqCst) + 1;
                            peak.fetch_max(now, Ordering::SeqCst);
                            std::thread::yield_now();
                            held.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 4, "held permits never passed max");
        assert!(admitted.load(Ordering::Relaxed) > 0);
        assert_eq!(gate.pending(), 0, "every permit retired");
    }

    #[test]
    fn deadline_expiry_and_remaining() {
        let clock = ManualClock::new(0);
        let d = Deadline::after_ms(&clock, 10);
        assert!(!d.expired(&clock));
        assert_eq!(d.remaining(&clock), Duration::from_millis(10));
        clock.advance_ms(4);
        assert_eq!(d.remaining(&clock), Duration::from_millis(6));
        clock.advance_ms(6);
        assert!(d.expired(&clock), "exactly at the deadline counts as expired");
        assert_eq!(d.remaining(&clock), Duration::ZERO);
        let zero = Deadline::after_ms(&clock, 0);
        assert!(zero.expired(&clock), "a zero budget is expired on arrival");
    }
}
