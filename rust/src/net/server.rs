//! The HTTP serving frontend: a std-only HTTP/1.1 server over
//! [`TcpListener`] fronting an [`AsyncInferenceServer`].
//!
//! ```text
//! TcpListener ──▶ accept thread ──▶ connection queue ──▶ worker pool
//!                 (refuses new                           (keep-alive loop:
//!                  connections                            parse → admit →
//!                  while draining)                        predict → reply)
//!
//! admission, per request:   rate limit (X-Tenant bucket)
//!                         → pending gate (429 + Retry-After past high water)
//!                         → deadline (X-Deadline-Ms; cancel before dispatch)
//!                         → AsyncInferenceServer::infer_async → reply row
//! ```
//!
//! Routes:
//!
//! * `POST /v1/models/{name}:predict` — JSON body with either
//!   `{"instances": [<sample>, ...]}` or a single named endpoint feed
//!   `{"inputs": {"<endpoint>": <sample>}}`; samples are (arbitrarily
//!   nested) arrays flattened row-major and validated against the model's
//!   [`ModelIoMeta`]. Replies `{"model": ..., "predictions": [<row>, ...]}`
//!   with bit-exact f32 round-trip (the JSON writer prints shortest
//!   round-trip forms). Two faster tiers ride the same route:
//!   `{"instances_b64": "<base64 of raw LE f32 rows>"}` (replying
//!   `"predictions_b64"`), and a full binary tensor body selected by
//!   `Content-Type: application/x-tf-fpga-tensor` (see [`crate::net::wire`]).
//! * `POST /v1/models/{name}:predict-bin` — the binary tensor body without
//!   needing the content type; the reply mirrors the request's encoding.
//! * `GET /v1/models` — hosted models with signature and I/O meta.
//! * `GET /healthz` — liveness (`"ok"`, or `"draining"` during shutdown).
//! * `GET /metrics` — Prometheus text (see [`crate::net::prom`]).
//! * `GET /v1/debug/trace?last_ms=N` — flight-recorder dump: Chrome-trace
//!   JSON for the last `N` milliseconds (whole ring when omitted), ready
//!   for Perfetto / `chrome://tracing`.
//!
//! Every predict request is traced end to end: the server mints (or
//! honors, via the `X-Request-Id` header) a request id, threads a
//! [`SpanCtx`] through admission → batching → routing → kernel retire,
//! and echoes the id back on the reply. `X-Debug-Timing: 1` opts the
//! reply into an `X-Timing` header with the per-stage breakdown in
//! microseconds; requests slower than `HttpServerConfig::slow_request`
//! log the same breakdown to stderr. Per-stage latencies also feed the
//! Prometheus histograms on `/metrics`.
//!
//! [`HttpServer::shutdown`] drains gracefully: stop accepting, let every
//! admitted request finish and flush its reply, then stop the inference
//! pipeline.

use crate::hsa::error::{HsaError, Result};
use crate::net::admission::{Clock, Deadline, PendingGate, RateLimiter, SystemClock};
use crate::net::http::{self, HttpError, Request, Response};
use crate::net::prom::{self, NetCounters};
use crate::net::wire;
use crate::metrics::StageHistograms;
use crate::serve::async_server::AsyncInferenceServer;
use crate::serve::batcher::TensorWriter;
use crate::serve::hosted::ModelIoMeta;
use crate::trace::{SpanCtx, Stage, TraceRecorder};
use crate::util::b64;
use crate::util::json::{Json, JsonErrorKind, JsonLimits};
use std::borrow::Cow;
use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Frontend configuration. The admission knobs mirror the CLI:
/// `--max-pending` bounds admitted-but-unanswered requests, and
/// `--tenant-rps` (0 = unlimited) rate-limits per `X-Tenant` value.
pub struct HttpServerConfig {
    /// Bind address; port 0 picks a free port (see
    /// [`HttpServer::local_addr`]).
    pub addr: String,
    /// Connection-handling worker threads. Each serves one (keep-alive)
    /// connection at a time, so this is also the concurrent-*request*
    /// budget — the pending gate can only fill past `max_pending` when
    /// `workers > max_pending`. Size it above `max_pending` (the
    /// integration tests do) when the gate should be the first shedding
    /// layer; otherwise the bounded connection backlog sheds first.
    pub workers: usize,
    /// Pending-gate high-water mark: requests admitted past the rate
    /// limiter but not yet answered. Above it, `429` + `Retry-After`.
    /// Connections beyond what the workers and this gate can absorb land
    /// in a bounded backlog (`workers + max_pending` deep); past *that*
    /// the accept loop sheds `429` immediately, so overload never grows
    /// memory or fd counts without bound.
    pub max_pending: usize,
    /// Sustained per-tenant requests/second (token-bucket refill rate);
    /// 0 disables per-tenant limiting.
    pub tenant_rps: u64,
    /// Token-bucket burst capacity; 0 means "same as `tenant_rps`".
    pub tenant_burst: u64,
    /// Request-body cap, enforced on `Content-Length` before reading.
    pub max_body_bytes: usize,
    /// JSON nesting cap for request bodies (defense against `[[[[...`).
    pub max_json_depth: usize,
    /// Idle keep-alive read timeout before a worker recycles the
    /// connection.
    pub keep_alive: Duration,
    /// Wall-clock allowance for reading one whole request once its first
    /// bytes arrive; a slow-trickle client gets `408` instead of pinning
    /// a worker (see `net::http::read_request`).
    pub request_read_budget: Duration,
    /// Time source for rate limiting and deadlines; swap in a manual
    /// clock for deterministic tests.
    pub clock: Arc<dyn Clock>,
    /// Flight recorder for request spans and the `/v1/debug/trace`
    /// endpoint. `None` (the default) shares the session's recorder when
    /// the pipeline has one, else spins up a fresh bounded ring — the
    /// recorder is always on.
    pub trace: Option<TraceRecorder>,
    /// Requests slower than this log their full span breakdown to
    /// stderr. `Duration::ZERO` disables the slow log.
    pub slow_request: Duration,
    /// Per-request span tracing (on by default). Off, requests still get
    /// ids but record no stage spans — the knob the `http_serving` bench
    /// uses to price the tracing path, and an escape hatch if it ever
    /// shows up in a profile.
    pub trace_requests: bool,
}

impl Default for HttpServerConfig {
    fn default() -> HttpServerConfig {
        HttpServerConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 4,
            max_pending: 64,
            tenant_rps: 0,
            tenant_burst: 0,
            max_body_bytes: 1 << 20,
            max_json_depth: 32,
            keep_alive: Duration::from_secs(5),
            request_read_budget: Duration::from_secs(10),
            clock: Arc::new(SystemClock::new()),
            trace: None,
            slow_request: Duration::from_secs(1),
            trace_requests: true,
        }
    }
}

/// Cap on `instances` per predict request: admission is per-request, so
/// without a bound one permit/token would admit an arbitrary amount of
/// work. Batch bigger workloads across requests.
pub const MAX_INSTANCES_PER_REQUEST: usize = 64;

struct Shared {
    srv: AsyncInferenceServer,
    gate: PendingGate,
    limiter: Option<RateLimiter>,
    net: NetCounters,
    draining: AtomicBool,
    clock: Arc<dyn Clock>,
    max_body: usize,
    read_budget: Duration,
    json_limits: JsonLimits,
    /// Always-on flight recorder; request spans and pipeline events land
    /// here, `/v1/debug/trace` reads it back out.
    trace: TraceRecorder,
    /// Per-stage latency histograms exported on `/metrics`.
    stages: StageHistograms,
    /// Monotonic source for minted request ids.
    req_seq: AtomicU64,
    slow_request: Duration,
    trace_requests: bool,
}

/// A running HTTP frontend. Dropping it (or calling
/// [`HttpServer::shutdown`]) drains gracefully.
pub struct HttpServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `config.addr` and start serving `srv`'s hosted models.
    pub fn start(srv: AsyncInferenceServer, config: HttpServerConfig) -> Result<HttpServer> {
        let listener = TcpListener::bind(&config.addr)
            .map_err(|e| HsaError::Runtime(format!("bind {}: {e}", config.addr)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| HsaError::Runtime(format!("local_addr: {e}")))?;

        let limiter = (config.tenant_rps > 0).then(|| {
            let burst = if config.tenant_burst > 0 { config.tenant_burst } else { config.tenant_rps };
            RateLimiter::new(config.tenant_rps, burst, Arc::clone(&config.clock))
        });
        // One recorder serves both halves: request spans from this
        // frontend and pipeline events (plan replay, router picks,
        // reconfigurations) from the session, so `/v1/debug/trace` shows
        // them on a shared clock.
        let trace = config
            .trace
            .clone()
            .or_else(|| srv.session().trace().cloned())
            .unwrap_or_default();
        let shared = Arc::new(Shared {
            srv,
            gate: PendingGate::new(config.max_pending as u64),
            limiter,
            net: NetCounters::new(),
            draining: AtomicBool::new(false),
            clock: Arc::clone(&config.clock),
            max_body: config.max_body_bytes,
            read_budget: config.request_read_budget,
            json_limits: JsonLimits {
                max_depth: config.max_json_depth,
                max_bytes: config.max_body_bytes,
            },
            trace,
            stages: StageHistograms::new(),
            req_seq: AtomicU64::new(0),
            slow_request: config.slow_request,
            trace_requests: config.trace_requests,
        });

        // Bounded connection backlog: enough for every worker plus a
        // gate's worth of waiters. `try_send` overflow sheds in the
        // accept loop, so a flood cannot queue connections unboundedly.
        let backlog = config.workers.max(1) + config.max_pending;
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(backlog);
        let rx = Arc::new(Mutex::new(rx));
        let keep_alive = config.keep_alive;

        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("http-accept".into())
                .spawn(move || accept_loop(listener, tx, shared))
                .map_err(|e| HsaError::Runtime(format!("spawn accept: {e}")))?
        };
        let workers = (0..config.workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("http-worker-{i}"))
                    .spawn(move || loop {
                        // Hold the receiver lock only for the handoff —
                        // a `while let` scrutinee would keep it (and
                        // serialize the whole pool) through the
                        // connection handling.
                        let next = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match next {
                            Ok(stream) => handle_connection(stream, &shared, keep_alive),
                            Err(_) => break,
                        }
                    })
                    .map_err(|e| HsaError::Runtime(format!("spawn worker: {e}")))
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(HttpServer { addr, shared, accept: Some(accept), workers })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The serving pipeline's aggregate report (same as the in-process
    /// server's).
    pub fn report(&self) -> crate::serve::AsyncServeReport {
        self.shared.srv.report()
    }

    /// Frontend counters (responses by code, sheds, deadline cancels).
    pub fn net_snapshot(&self) -> prom::NetSnapshot {
        self.shared.net.snapshot()
    }

    /// The flight recorder backing request spans and `/v1/debug/trace`.
    pub fn trace(&self) -> &TraceRecorder {
        &self.shared.trace
    }

    /// Per-stage latency histograms (what `/metrics` exports).
    pub fn stage_snapshot(&self) -> Vec<(Stage, crate::metrics::histogram::Histogram)> {
        self.shared.stages.snapshot()
    }

    /// Graceful drain: stop accepting, refuse new connections with `503`,
    /// let every in-flight request complete and flush its reply, then
    /// stop the inference pipeline. Idempotent.
    pub fn shutdown(&mut self) {
        let Some(accept) = self.accept.take() else { return };
        self.shared.draining.store(true, Ordering::SeqCst);
        // Wake the accept loop: it re-checks the flag per connection.
        // Connect via loopback when bound to a wildcard address
        // (connecting to 0.0.0.0 is not universally routable).
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake {
                SocketAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                SocketAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
            });
        }
        if TcpStream::connect_timeout(&wake, Duration::from_secs(1)).is_err() {
            // Could not reach our own listener (local firewalling?):
            // leave the accept/worker threads parked rather than hang
            // this join forever; they die with the process.
            return;
        }
        let _ = accept.join();
        // The accept loop owned the connection sender; with it gone,
        // workers finish every already-accepted connection and exit.
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // All HTTP threads are gone, so ours is the last strong reference
        // (barring a caller-held clone of nothing — Shared never leaks).
        if let Some(shared) = Arc::get_mut(&mut self.shared) {
            shared.srv.stop();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, tx: mpsc::SyncSender<TcpStream>, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        let Ok(mut stream) = stream else { continue };
        if shared.draining.load(Ordering::SeqCst) {
            // Refuse and stop accepting entirely; connections still in the
            // OS backlog get reset when the listener drops below. The
            // shutdown wake-up connects and closes without sending a byte
            // — detect that (peek sees EOF) so it neither pollutes the
            // refused-client metrics nor gets a pointless 503.
            let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
            let mut probe = [0u8; 1];
            let is_wake = matches!(stream.peek(&mut probe), Ok(0));
            if !is_wake {
                shared.net.on_refused_draining();
                shared.net.on_response(503);
                let _ = error_response(503, "draining", "server is draining", vec![])
                    .with_close()
                    .write_to(&mut stream);
                // Best-effort drain of the request the client already
                // sent, so closing does not reset away the 503.
                let _ = std::io::copy(
                    &mut std::io::Read::take(&stream, 64 << 10),
                    &mut std::io::sink(),
                );
            }
            break;
        }
        shared.net.on_connection();
        match tx.try_send(stream) {
            Ok(()) => {}
            Err(mpsc::TrySendError::Full(mut stream)) => {
                // Backlog full: shed here rather than queue without
                // bound. Non-blocking, so the drain wake-up above always
                // gets through too.
                shared.net.on_shed_backlog();
                shared.net.on_response(429);
                let _ = error_response(
                    429,
                    "overloaded",
                    "connection backlog is full",
                    vec![],
                )
                .with_header("Retry-After", "1".to_string())
                .with_close()
                .write_to(&mut stream);
            }
            Err(mpsc::TrySendError::Disconnected(_)) => break,
        }
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared, keep_alive: Duration) {
    let _ = stream.set_read_timeout(Some(keep_alive));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    loop {
        match http::read_request(&mut reader, shared.max_body, shared.read_budget) {
            Err(HttpError::Eof) | Err(HttpError::Io(_)) => break,
            Err(HttpError::Bad { status, msg }) => {
                // Wire-layer rejections carry the same named kinds the
                // body-level checks use, so clients can branch on
                // `error.kind` regardless of which layer refused.
                let kind = match status {
                    413 => "payload_too_large",
                    431 => "headers_too_large",
                    408 => "timeout",
                    _ => "bad_request",
                };
                shared.net.on_response(status);
                let _ = error_response(status, kind, &msg, vec![])
                    .with_close()
                    .write_to(&mut stream);
                break;
            }
            Ok(req) => {
                let mut resp = route(&req, shared);
                resp.close = resp.close
                    || req.wants_close()
                    || shared.draining.load(Ordering::SeqCst);
                shared.net.on_response(resp.status);
                if resp.write_to(&mut stream).is_err() || resp.close {
                    break;
                }
            }
        }
    }
}

fn route(req: &Request, shared: &Shared) -> Response {
    const PREDICT_PREFIX: &str = "/v1/models/";
    const PREDICT_SUFFIX: &str = ":predict";
    const PREDICT_BIN_SUFFIX: &str = ":predict-bin";
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => handle_healthz(shared),
        ("GET", "/metrics") => handle_metrics(shared),
        ("GET", "/v1/models") => handle_models(shared),
        ("GET", "/v1/debug/trace") => handle_debug_trace(req, shared),
        (method, path)
            if path.starts_with(PREDICT_PREFIX)
                && (path.ends_with(PREDICT_SUFFIX) || path.ends_with(PREDICT_BIN_SUFFIX)) =>
        {
            if method != "POST" {
                return error_response(
                    405,
                    "method_not_allowed",
                    &format!("{method} not allowed; predict is POST"),
                    vec![],
                );
            }
            // `:predict-bin` forces the binary tensor body; `:predict`
            // accepts it too when the content type selects it.
            let (model, binary_route) = if path.ends_with(PREDICT_BIN_SUFFIX) {
                (&path[PREDICT_PREFIX.len()..path.len() - PREDICT_BIN_SUFFIX.len()], true)
            } else {
                (&path[PREDICT_PREFIX.len()..path.len() - PREDICT_SUFFIX.len()], false)
            };
            handle_predict(model, req, shared, binary_route)
        }
        ("GET" | "POST", _) => {
            error_response(404, "not_found", &format!("no route for '{}'", req.path), vec![])
        }
        (method, _) => {
            error_response(405, "method_not_allowed", &format!("method {method} not supported"), vec![])
        }
    }
}

fn handle_healthz(shared: &Shared) -> Response {
    let draining = shared.draining.load(Ordering::SeqCst);
    let mut m = BTreeMap::new();
    m.insert(
        "status".to_string(),
        Json::Str(if draining { "draining" } else { "ok" }.to_string()),
    );
    m.insert(
        "models".to_string(),
        Json::Arr(shared.srv.models().iter().map(|n| Json::Str(n.to_string())).collect()),
    );
    Response::json(200, Json::Obj(m).to_string())
}

fn handle_metrics(shared: &Shared) -> Response {
    let report = shared.srv.report();
    let text = prom::render(
        &shared.net.snapshot(),
        &shared.srv.counters(),
        &report.pool,
        shared.draining.load(Ordering::SeqCst),
        &shared.stages.snapshot(),
        shared.trace.dropped(),
    );
    Response::text(200, text)
}

/// `GET /v1/debug/trace?last_ms=N` — dump the flight recorder as
/// Chrome-trace JSON, windowed to the last `N` milliseconds (the whole
/// ring when `last_ms` is omitted).
fn handle_debug_trace(req: &Request, shared: &Shared) -> Response {
    let cutoff_us = match req.query_param("last_ms") {
        None => 0,
        Some(v) => match v.parse::<u64>() {
            Ok(ms) => shared.trace.now_us().saturating_sub(ms.saturating_mul(1000)),
            Err(_) => {
                return error_response(
                    400,
                    "bad_request",
                    &format!("bad last_ms '{v}' (want milliseconds)"),
                    vec![],
                )
            }
        },
    };
    Response::json(200, shared.trace.to_chrome_trace_since(cutoff_us))
}

fn handle_models(shared: &Shared) -> Response {
    let models: Vec<Json> = shared
        .srv
        .models()
        .into_iter()
        .filter_map(|name| shared.srv.model_meta(name).map(|meta| (name, meta)))
        .map(|(name, meta)| {
            let mut m = BTreeMap::new();
            m.insert("name".to_string(), Json::Str(name.to_string()));
            m.insert("signature".to_string(), Json::Str(meta.signature.clone()));
            m.insert("input".to_string(), endpoint_json(&meta.input_name, &meta.sample_in_shape, meta.in_elems));
            m.insert("output".to_string(), endpoint_json(&meta.output_name, &meta.sample_out_shape, meta.out_elems));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("models".to_string(), Json::Arr(models));
    Response::json(200, Json::Obj(top).to_string())
}

fn endpoint_json(name: &str, sample_shape: &[usize], elems: usize) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert(
        "sample_shape".to_string(),
        Json::Arr(sample_shape.iter().map(|&d| Json::from_usize(d)).collect()),
    );
    m.insert("elems".to_string(), Json::from_usize(elems));
    Json::Obj(m)
}

/// Predict entry point: mints the request id, opens the span, runs the
/// actual handler, then stamps observability headers on whatever came
/// back — `X-Request-Id` always, `X-Timing` when the client sent
/// `X-Debug-Timing: 1` — feeds the per-stage histograms, and logs slow
/// requests with their full breakdown.
fn handle_predict(model: &str, req: &Request, shared: &Shared, binary_route: bool) -> Response {
    let started = Instant::now();
    let req_id = request_id(req, shared);
    let span = if shared.trace_requests {
        SpanCtx::new(req_id.clone(), shared.trace.clone())
    } else {
        SpanCtx::disabled()
    };
    let mut resp = predict_inner(model, req, shared, binary_route, &span, started);
    let total_us = started.elapsed().as_micros() as u64;
    shared.stages.record_span(&span);
    if req.header("x-debug-timing").is_some_and(|v| v.trim() == "1") {
        resp = resp.with_header("X-Timing", timing_header(&span, total_us));
    }
    if !shared.slow_request.is_zero() && started.elapsed() >= shared.slow_request {
        eprintln!(
            "[http] slow request {req_id}: model={model} status={} {}",
            resp.status,
            timing_header(&span, total_us),
        );
    }
    resp.with_header("X-Request-Id", req_id)
}

/// The inbound `X-Request-Id` (sanitized to header-safe characters,
/// capped at 64) when the client sent one, else a freshly minted
/// `r-<n>` id unique within this server.
fn request_id(req: &Request, shared: &Shared) -> String {
    if let Some(v) = req.header("x-request-id") {
        let clean: String = v
            .chars()
            .filter(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | ':'))
            .take(64)
            .collect();
        if !clean.is_empty() {
            return clean;
        }
    }
    format!("r-{:08x}", shared.req_seq.fetch_add(1, Ordering::Relaxed) + 1)
}

/// `stage=us;...;total=us` — the `X-Timing` header value. A multi-row
/// request records one entry per row for the batched stages; rows ride
/// the pipeline concurrently, so the wall-clock contribution reported
/// here is the per-stage maximum, not the sum.
fn timing_header(span: &SpanCtx, total_us: u64) -> String {
    use std::fmt::Write;
    let stages = span.stages();
    let mut out = String::new();
    for stage in Stage::ALL {
        let max = stages.iter().filter(|(s, _)| *s == stage).map(|&(_, us)| us).max();
        if let Some(us) = max {
            let _ = write!(out, "{}={us};", stage.name());
        }
    }
    let _ = write!(out, "total={total_us}");
    out
}

fn predict_inner(
    model: &str,
    req: &Request,
    shared: &Shared,
    binary_route: bool,
    span: &SpanCtx,
    started: Instant,
) -> Response {
    let Some(meta) = shared.srv.model_meta(model).cloned() else {
        let served = shared.srv.models();
        return error_response(
            404,
            "unknown_model",
            &format!("unknown model '{model}' (serving: {served:?})"),
            vec![(
                "models",
                Json::Arr(served.iter().map(|n| Json::Str(n.to_string())).collect()),
            )],
        );
    };

    // 1. Per-tenant quota.
    let tenant = req.header("x-tenant").unwrap_or("anonymous").to_string();
    if let Some(limiter) = &shared.limiter {
        if let Err(retry_after) = limiter.try_acquire(&tenant) {
            shared.net.on_shed_tenant();
            return error_response(
                429,
                "rate_limited",
                &format!("tenant '{tenant}' is over its request rate"),
                vec![("tenant", Json::Str(tenant))],
            )
            .with_header("Retry-After", retry_after.to_string());
        }
    }

    // 2. Bounded pending gate — held (RAII) until the reply is formed.
    let Some(_permit) = shared.gate.try_acquire_spanned(span) else {
        shared.net.on_shed_pending();
        return error_response(
            429,
            "overloaded",
            &format!("pending-request limit {} reached", shared.gate.max()),
            vec![],
        )
        .with_header("Retry-After", "1".to_string());
    };

    // 3. Deadline header.
    let deadline = match req.header("x-deadline-ms") {
        None => None,
        Some(v) => match v.parse::<u64>() {
            Ok(ms) => Some(Deadline::after_ms(shared.clock.as_ref(), ms)),
            Err(_) => {
                return error_response(
                    400,
                    "bad_request",
                    &format!("bad X-Deadline-Ms '{v}' (want milliseconds)"),
                    vec![],
                )
            }
        },
    };

    // 4. Body → rows, in whichever of the three encodings the client
    // chose (JSON instances/inputs, base64 raw-f32 tier, binary tensor).
    let binary = binary_route
        || req.header("content-type").is_some_and(|ct| {
            ct.split(';').next().unwrap_or("").trim().eq_ignore_ascii_case(wire::TENSOR_CONTENT_TYPE)
        });
    let mut json_doc = None;
    let parsed = match parse_predict_request(
        model,
        &meta,
        &req.body,
        binary,
        shared.json_limits,
        &mut json_doc,
    ) {
        Ok(p) => p,
        Err(resp) => return *resp,
    };

    // Admission was charged one token on entry; a batched request pays
    // for its remaining instances too — atomically, so a failed batch
    // neither multiplies a tenant's effective rate nor drains its
    // bucket into livelock.
    if parsed.rows > 1 {
        if let Some(limiter) = &shared.limiter {
            match limiter.try_acquire_n(&tenant, parsed.rows as u64 - 1) {
                Ok(()) => {}
                Err(None) => {
                    return error_response(
                        400,
                        "bad_request",
                        &format!(
                            "a batch of {} instances can never fit tenant '{tenant}'s \
                             burst capacity; split it across requests",
                            parsed.rows
                        ),
                        vec![("tenant", Json::Str(tenant))],
                    )
                }
                Err(Some(retry_after)) => {
                    shared.net.on_shed_tenant();
                    return error_response(
                        429,
                        "rate_limited",
                        &format!("tenant '{tenant}' is over its request rate (batched instances)"),
                        vec![("tenant", Json::Str(tenant))],
                    )
                    .with_header("Retry-After", retry_after.to_string());
                }
            }
        }
    }

    // 5. Already past the deadline (queueing, parsing)? Cancel before any
    // dispatch reaches the pipeline.
    if let Some(d) = deadline {
        if d.expired(shared.clock.as_ref()) {
            shared.net.on_deadline_expired();
            return error_response(
                504,
                "deadline_exceeded",
                "deadline expired before dispatch; request cancelled",
                vec![],
            );
        }
    }

    // Everything up to dispatch — rate limiting, the pending gate,
    // deadline parsing, body decode/validation — is the request's
    // admission window.
    span.record_stage(Stage::AdmissionWait, started.elapsed().as_micros() as u64);

    // 6. Dispatch every row straight into its batch lane's staging
    // buffer, then collect replies in order. The binary and base64 tiers
    // copy raw little-endian rows through [`wire::copy_row_into`]; JSON
    // samples flatten their (pre-validated) number tree directly into the
    // lane's writer — neither path builds an intermediate `Vec<f32>`.
    let mut receivers = Vec::with_capacity(parsed.rows);
    match &parsed.body {
        ParsedBody::Json(samples) => {
            for raw in samples {
                match shared.srv.infer_async_spanned(model, span.clone(), |w: &mut TensorWriter<'_>| {
                    flatten_into(raw, w)
                }) {
                    Ok(rx) => receivers.push(rx),
                    // Pre-validated against the meta, so any error here is
                    // a pipeline failure, not a client one.
                    Err(e) => return error_response(500, "internal", &e.to_string(), vec![]),
                }
            }
        }
        ParsedBody::Raw(data) => {
            let row_bytes = meta.in_elems * 4;
            for i in 0..parsed.rows {
                let row = &data[i * row_bytes..(i + 1) * row_bytes];
                match shared.srv.infer_async_spanned(model, span.clone(), |w: &mut TensorWriter<'_>| {
                    wire::copy_row_into(row, w);
                    Ok(())
                }) {
                    Ok(rx) => receivers.push(rx),
                    Err(e) => return error_response(500, "internal", &e.to_string(), vec![]),
                }
            }
        }
    }
    let mut out_rows: Vec<Vec<f32>> = Vec::with_capacity(receivers.len());
    for rx in receivers {
        let reply = match deadline {
            Some(d) => match rx.recv_timeout(d.remaining(shared.clock.as_ref())) {
                Ok(r) => r,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    return error_response(
                        504,
                        "deadline_exceeded",
                        "deadline expired waiting for the batch to retire",
                        vec![],
                    )
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return error_response(500, "internal", "server dropped request", vec![])
                }
            },
            None => match rx.recv() {
                Ok(r) => r,
                Err(_) => {
                    return error_response(500, "internal", "server dropped request", vec![])
                }
            },
        };
        match reply {
            Ok(row) => out_rows.push(row),
            Err(e) => return error_response(500, "internal", &e.to_string(), vec![]),
        }
    }

    // The reply mirrors the request's encoding.
    let ser_start = Instant::now();
    let resp = match parsed.reply {
        ReplyEncoding::Binary => {
            let mut flat = Vec::with_capacity(out_rows.len() * meta.out_elems);
            for r in &out_rows {
                flat.extend_from_slice(r);
            }
            Response::binary(
                200,
                wire::encode_flat(&meta.sample_out_shape, out_rows.len(), &flat),
            )
        }
        ReplyEncoding::B64 => {
            let mut bytes = Vec::with_capacity(out_rows.len() * meta.out_elems * 4);
            for r in &out_rows {
                for v in r {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
            }
            let mut body = BTreeMap::new();
            body.insert("model".to_string(), Json::Str(model.to_string()));
            body.insert("predictions_b64".to_string(), Json::Str(b64::encode(&bytes)));
            body.insert("rows".to_string(), Json::from_usize(out_rows.len()));
            Response::json(200, Json::Obj(body).to_string())
        }
        ReplyEncoding::Json => {
            let rows = out_rows
                .into_iter()
                .map(|r| Json::Arr(r.into_iter().map(Json::from_f32).collect()))
                .collect();
            let mut body = BTreeMap::new();
            body.insert("model".to_string(), Json::Str(model.to_string()));
            body.insert("predictions".to_string(), Json::Arr(rows));
            Response::json(200, Json::Obj(body).to_string())
        }
    };
    span.record_stage(Stage::ReplySerialize, ser_start.elapsed().as_micros() as u64);
    resp
}

/// What a predict body parsed to: how many rows, how to encode the
/// reply, and where the dispatchable row data lives.
struct ParsedPredict<'a> {
    rows: usize,
    reply: ReplyEncoding,
    body: ParsedBody<'a>,
}

/// Reply encoding, mirroring the request's.
enum ReplyEncoding {
    Json,
    B64,
    Binary,
}

enum ParsedBody<'a> {
    /// JSON tier: borrowed, pre-validated samples still in tree form —
    /// flattened straight into the batch lane at dispatch.
    Json(Vec<&'a Json>),
    /// Raw little-endian f32 rows: borrowed in place from a binary body,
    /// or owned when decoded out of the base64 tier.
    Raw(Cow<'a, [u8]>),
}

/// Decode a predict body into dispatch-ready rows, or the exact error
/// response to send. Boxed because the error side is by far the larger.
///
/// Three encodings, chosen by the client:
///
/// * binary (the `:predict-bin` route, or `:predict` with the
///   `application/x-tf-fpga-tensor` content type): a [`wire`] tensor
///   body whose payload rows are borrowed in place — nothing is parsed
///   or copied here;
/// * `{"instances_b64": "<base64>"}`: raw little-endian f32 rows inside
///   the JSON API; the row count follows from the decoded length;
/// * `{"instances": [...]}` / `{"inputs": {...}}`: the JSON tier;
///   samples are only *counted* here (shape validation), then flattened
///   directly into the lane's staging buffer at dispatch.
///
/// `json_doc` is the caller's slot keeping a parsed JSON body alive for
/// the borrows the `Json` variant returns.
fn parse_predict_request<'a>(
    model: &str,
    meta: &ModelIoMeta,
    body: &'a [u8],
    binary: bool,
    limits: JsonLimits,
    json_doc: &'a mut Option<Json>,
) -> std::result::Result<ParsedPredict<'a>, Box<Response>> {
    if binary {
        let h = wire::decode_header(body).map_err(|msg| {
            Box::new(error_response(
                400,
                "bad_request",
                &format!("binary tensor body: {msg}"),
                vec![],
            ))
        })?;
        if h.rows == 0 {
            return Err(Box::new(error_response(
                400,
                "bad_request",
                "binary tensor body has zero rows",
                vec![],
            )));
        }
        if h.rows > MAX_INSTANCES_PER_REQUEST {
            return Err(Box::new(too_many_rows(h.rows)));
        }
        // Lenient on the exact per-sample shape (clients may flatten),
        // strict on the element count the model actually consumes.
        if h.elems_per_row() != meta.in_elems {
            return Err(Box::new(shape_mismatch(model, meta, h.elems_per_row())));
        }
        return Ok(ParsedPredict {
            rows: h.rows,
            reply: ReplyEncoding::Binary,
            body: ParsedBody::Raw(Cow::Borrowed(h.payload(body))),
        });
    }

    let text = std::str::from_utf8(body)
        .map_err(|_| Box::new(error_response(400, "bad_request", "body is not UTF-8", vec![])))?;
    let doc = Json::parse_with_limits(text, limits).map_err(|e| {
        let (status, kind) = match e.kind {
            JsonErrorKind::TooDeep => (400, "too_deep"),
            JsonErrorKind::TooLarge => (413, "payload_too_large"),
            JsonErrorKind::Syntax => (400, "bad_request"),
        };
        Box::new(error_response(status, kind, &e.to_string(), vec![]))
    })?;
    let doc = &*json_doc.insert(doc);

    if let Json::Str(encoded) = doc.get("instances_b64") {
        let data = b64::decode(encoded).map_err(|msg| {
            Box::new(error_response(
                400,
                "bad_request",
                &format!("\"instances_b64\": {msg}"),
                vec![],
            ))
        })?;
        let row_bytes = meta.in_elems * 4;
        if data.is_empty() || data.len() % row_bytes != 0 {
            return Err(Box::new(error_response(
                400,
                "shape_mismatch",
                &format!(
                    "model '{model}' input '{}': \"instances_b64\" decodes to {} bytes, \
                     want a positive multiple of {row_bytes} ({} f32 values per row, \
                     shape {:?})",
                    meta.input_name,
                    data.len(),
                    meta.in_elems,
                    meta.sample_in_shape
                ),
                vec![
                    ("endpoint", Json::Str(meta.input_name.clone())),
                    ("expected_elems", Json::from_usize(meta.in_elems)),
                ],
            )));
        }
        let rows = data.len() / row_bytes;
        if rows > MAX_INSTANCES_PER_REQUEST {
            return Err(Box::new(too_many_rows(rows)));
        }
        return Ok(ParsedPredict {
            rows,
            reply: ReplyEncoding::B64,
            body: ParsedBody::Raw(Cow::Owned(data)),
        });
    }

    let raw_samples: Vec<&Json> = if let Json::Arr(instances) = doc.get("instances") {
        if instances.is_empty() {
            return Err(Box::new(error_response(
                400,
                "bad_request",
                "\"instances\" is empty",
                vec![],
            )));
        }
        if instances.len() > MAX_INSTANCES_PER_REQUEST {
            return Err(Box::new(error_response(
                400,
                "bad_request",
                &format!(
                    "{} instances in one request (limit {MAX_INSTANCES_PER_REQUEST}); \
                     split the batch across requests",
                    instances.len()
                ),
                vec![],
            )));
        }
        instances.iter().collect()
    } else if let Json::Obj(inputs) = doc.get("inputs") {
        // Named endpoint feed: single-input serving signatures take
        // exactly one, and the name must match the signature's endpoint.
        match inputs.iter().collect::<Vec<_>>().as_slice() {
            [(name, sample)] if *name == &meta.input_name => vec![*sample],
            [(name, _)] => {
                return Err(Box::new(error_response(
                    400,
                    "unknown_endpoint",
                    &format!(
                        "model '{model}' signature '{}': no input endpoint '{name}' \
                         (expected '{}')",
                        meta.signature, meta.input_name
                    ),
                    vec![
                        ("endpoint", Json::Str(name.to_string())),
                        ("expected_endpoint", Json::Str(meta.input_name.clone())),
                    ],
                )))
            }
            _ => {
                return Err(Box::new(error_response(
                    400,
                    "bad_request",
                    &format!(
                        "\"inputs\" must feed exactly the endpoint '{}'",
                        meta.input_name
                    ),
                    vec![],
                )))
            }
        }
    } else {
        return Err(Box::new(error_response(
            400,
            "bad_request",
            "body must carry \"instances\": [<sample>, ...], \
             \"instances_b64\": \"<base64>\" or \
             \"inputs\": {\"<endpoint>\": <sample>}",
            vec![],
        )));
    };

    for (i, raw) in raw_samples.iter().enumerate() {
        let n = count_elems(raw).map_err(|msg| {
            Box::new(error_response(
                400,
                "bad_request",
                &format!("sample {i}: {msg}"),
                vec![],
            ))
        })?;
        if n != meta.in_elems {
            return Err(Box::new(shape_mismatch(model, meta, n)));
        }
    }
    Ok(ParsedPredict {
        rows: raw_samples.len(),
        reply: ReplyEncoding::Json,
        body: ParsedBody::Json(raw_samples),
    })
}

/// The structured shape-mismatch error every encoding shares. Same
/// wording the Model facade / serving pipeline uses for mis-sized feeds,
/// plus machine-readable expected-vs-got meta.
fn shape_mismatch(model: &str, meta: &ModelIoMeta, got_elems: usize) -> Response {
    error_response(
        400,
        "shape_mismatch",
        &format!(
            "model '{model}' input '{}': expected {} f32 values (shape {:?}), got {}",
            meta.input_name, meta.in_elems, meta.sample_in_shape, got_elems
        ),
        vec![
            ("endpoint", Json::Str(meta.input_name.clone())),
            (
                "expected_shape",
                Json::Arr(meta.sample_in_shape.iter().map(|&d| Json::from_usize(d)).collect()),
            ),
            ("expected_elems", Json::from_usize(meta.in_elems)),
            ("got_elems", Json::from_usize(got_elems)),
        ],
    )
}

/// The per-request row cap, worded like the JSON tier's `instances` cap.
fn too_many_rows(rows: usize) -> Response {
    error_response(
        400,
        "bad_request",
        &format!(
            "{rows} rows in one request (limit {MAX_INSTANCES_PER_REQUEST}); \
             split the batch across requests"
        ),
        vec![],
    )
}

/// Count the numbers in an arbitrarily nested JSON sample — the
/// validation pass that lets dispatch flatten straight into the batch
/// lane's staging buffer without an intermediate `Vec<f32>`.
fn count_elems(v: &Json) -> std::result::Result<usize, String> {
    match v {
        Json::Num(_) => Ok(1),
        Json::Arr(items) => {
            items.iter().try_fold(0usize, |acc, item| Ok(acc + count_elems(item)?))
        }
        other => Err(format!("expected numbers/arrays, found {other}")),
    }
}

/// Flatten a pre-validated sample row-major into a batch lane's writer.
fn flatten_into(v: &Json, w: &mut TensorWriter<'_>) -> std::result::Result<(), String> {
    match v {
        Json::Num(n) => {
            w.push(*n as f32);
            Ok(())
        }
        Json::Arr(items) => {
            for item in items {
                flatten_into(item, w)?;
            }
            Ok(())
        }
        other => Err(format!("expected numbers/arrays, found {other}")),
    }
}

/// Structured error body:
/// `{"error": {"status": N, "kind": "...", "message": "...", ...extra}}`.
fn error_response(status: u16, kind: &str, message: &str, extra: Vec<(&str, Json)>) -> Response {
    let mut e = BTreeMap::new();
    e.insert("status".to_string(), Json::from_usize(status as usize));
    e.insert("kind".to_string(), Json::Str(kind.to_string()));
    e.insert("message".to_string(), Json::Str(message.to_string()));
    for (k, v) in extra {
        e.insert(k.to_string(), v);
    }
    let mut top = BTreeMap::new();
    top.insert("error".to_string(), Json::Obj(e));
    Response::json(status, Json::Obj(top).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::client::{decode_predictions, decode_predictions_bin, NetClient};
    use crate::serve::batcher::BatchPolicy;
    use crate::serve::hosted::ModelSpec;
    use crate::serve::async_server::AsyncServerConfig;
    use crate::tf::model::ModelBundle;
    use crate::tf::session::SessionOptions;

    fn tiny_server(http: HttpServerConfig) -> HttpServer {
        let srv = AsyncInferenceServer::start(AsyncServerConfig {
            models: vec![ModelSpec::from_bundle(
                "tiny",
                ModelBundle::tiny_fc_demo(4, 16, 4),
                BatchPolicy { max_batch: 2, max_delay: Duration::from_millis(1) },
            )],
            session: SessionOptions { dispatch_workers: 2, ..SessionOptions::native_only() },
            pipeline_depth: 2,
        })
        .expect("inference server");
        HttpServer::start(srv, http).expect("http server")
    }

    #[test]
    fn healthz_models_and_predict_roundtrip() {
        let mut server = tiny_server(HttpServerConfig::default());
        let mut client = NetClient::connect(server.local_addr()).unwrap();

        let health = client.get("/healthz").unwrap();
        assert_eq!(health.status, 200);
        let doc = health.json().unwrap();
        assert_eq!(doc.get("status").as_str(), Some("ok"));
        assert_eq!(doc.get("models").idx(0).as_str(), Some("tiny"));

        let listing = client.get("/v1/models").unwrap();
        assert_eq!(listing.status, 200);
        let doc = listing.json().unwrap();
        let m = doc.get("models").idx(0);
        assert_eq!(m.get("name").as_str(), Some("tiny"));
        assert_eq!(m.get("signature").as_str(), Some("serve"));
        assert_eq!(m.get("input").get("name").as_str(), Some("x"));
        assert_eq!(m.get("input").get("elems").as_usize(), Some(16));
        assert_eq!(m.get("output").get("elems").as_usize(), Some(4));

        let sample: Vec<f32> = (0..16).map(|i| i as f32 * 0.1 - 0.8).collect();
        let resp = client.predict("tiny", &[sample.as_slice()], &[]).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let doc = resp.json().unwrap();
        let row = doc.get("predictions").idx(0).as_arr().unwrap();
        assert_eq!(row.len(), 4);
        drop(client); // free the worker before drain
        server.shutdown();
    }

    #[test]
    fn named_endpoint_feed_and_keep_alive_reuse() {
        let mut server = tiny_server(HttpServerConfig::default());
        let mut client = NetClient::connect(server.local_addr()).unwrap();
        let body = r#"{"inputs": {"x": [0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5]}}"#;
        for _ in 0..3 {
            // Same client object: requests 2 and 3 reuse the connection.
            let resp = client
                .request("POST", "/v1/models/tiny:predict", &[], Some(body))
                .unwrap();
            assert_eq!(resp.status, 200, "{}", resp.body);
        }
        assert_eq!(server.net_snapshot().connections, 1, "keep-alive reused");
        drop(client);
        server.shutdown();
    }

    #[test]
    fn unknown_routes_and_methods() {
        let mut server = tiny_server(HttpServerConfig::default());
        let mut client = NetClient::connect(server.local_addr()).unwrap();
        assert_eq!(client.get("/nope").unwrap().status, 404);
        let r = client.request("GET", "/v1/models/tiny:predict", &[], None).unwrap();
        assert_eq!(r.status, 405, "predict is POST-only");
        let r = client.request("DELETE", "/v1/models", &[], None).unwrap();
        assert_eq!(r.status, 405);
        drop(client);
        server.shutdown();
    }

    #[test]
    fn binary_and_b64_tiers_match_the_json_tier_bitwise() {
        let mut server = tiny_server(HttpServerConfig::default());
        let mut client = NetClient::connect(server.local_addr()).unwrap();
        let sample: Vec<f32> = (0..16).map(|i| i as f32 * 0.37 - 2.5).collect();

        // JSON tier is the reference.
        let resp = client.predict("tiny", &[sample.as_slice()], &[]).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let json_rows = decode_predictions(&resp).unwrap();

        // Binary route, binary reply.
        let resp = client.predict_bin("tiny", &[16], &[sample.as_slice()], &[]).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("content-type"), Some(wire::TENSOR_CONTENT_TYPE));
        let bin_rows = decode_predictions_bin(&resp).unwrap();

        // Same binary body on the plain `:predict` route via content type.
        let body = wire::encode_rows(&[16], &[sample.as_slice()]);
        let resp = client
            .request_bytes(
                "POST",
                "/v1/models/tiny:predict",
                &[("Content-Type", wire::TENSOR_CONTENT_TYPE)],
                Some(&body),
            )
            .unwrap();
        assert_eq!(resp.status, 200);
        let ct_rows = decode_predictions_bin(&resp).unwrap();

        // Base64 tier inside the JSON API, base64 reply.
        let mut raw = Vec::new();
        for v in &sample {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        let body = format!("{{\"instances_b64\": \"{}\"}}", b64::encode(&raw));
        let resp = client.request("POST", "/v1/models/tiny:predict", &[], Some(&body)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let doc = resp.json().unwrap();
        assert_eq!(doc.get("rows").as_usize(), Some(1));
        let bytes = b64::decode(doc.get("predictions_b64").as_str().unwrap()).unwrap();
        let b64_row: Vec<f32> =
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();

        for (got, name) in [(&bin_rows[0], "binary"), (&ct_rows[0], "content-type"), (&b64_row, "b64")] {
            assert_eq!(got.len(), json_rows[0].len(), "{name} row length");
            for (g, w) in got.iter().zip(&json_rows[0]) {
                assert_eq!(g.to_bits(), w.to_bits(), "{name} tier diverged from JSON");
            }
        }
        drop(client);
        server.shutdown();
    }

    #[test]
    fn malformed_binary_bodies_get_structured_errors() {
        let mut server = tiny_server(HttpServerConfig::default());
        let mut client = NetClient::connect(server.local_addr()).unwrap();

        // Bad magic.
        let mut body = wire::encode_rows(&[16], &[&[0.5f32; 16]]);
        body[0] = b'X';
        let resp = client
            .request_bytes("POST", "/v1/models/tiny:predict-bin", &[], Some(&body))
            .unwrap();
        assert_eq!(resp.status, 400);
        let text = String::from_utf8(resp.body).unwrap();
        assert!(text.contains("magic"), "{text}");

        // Wrong per-row element count: the same structured shape_mismatch
        // the JSON tier produces.
        let body = wire::encode_rows(&[3], &[&[0.5f32; 3]]);
        let resp = client
            .request_bytes("POST", "/v1/models/tiny:predict-bin", &[], Some(&body))
            .unwrap();
        assert_eq!(resp.status, 400);
        let doc = Json::parse(&String::from_utf8(resp.body).unwrap()).unwrap();
        let e = doc.get("error");
        assert_eq!(e.get("kind").as_str(), Some("shape_mismatch"));
        assert_eq!(e.get("expected_elems").as_usize(), Some(16));
        assert_eq!(e.get("got_elems").as_usize(), Some(3));
        drop(client);
        server.shutdown();
    }

    #[test]
    fn request_ids_timing_header_and_debug_trace() {
        let mut server = tiny_server(HttpServerConfig::default());
        let mut client = NetClient::connect(server.local_addr()).unwrap();
        let sample: Vec<f32> = (0..16).map(|i| i as f32 * 0.25).collect();

        // Inbound id is honored and echoed; X-Debug-Timing opts into the
        // stage breakdown header.
        let resp = client
            .predict(
                "tiny",
                &[sample.as_slice()],
                &[("X-Request-Id", "abc-123"), ("X-Debug-Timing", "1")],
            )
            .unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        assert_eq!(resp.header("x-request-id"), Some("abc-123"));
        let timing = resp.header("x-timing").expect("X-Timing header").to_string();
        for key in ["admission_wait=", "batch_wait=", "kernel_exec=", "reply_serialize=", "total="] {
            assert!(timing.contains(key), "missing {key} in '{timing}'");
        }

        // No inbound id → a minted one; no X-Debug-Timing → no header.
        let resp = client.predict("tiny", &[sample.as_slice()], &[]).unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.header("x-request-id").unwrap().starts_with("r-"));
        assert!(resp.header("x-timing").is_none());

        // The flight recorder serves the traced request's track.
        let t = client.get("/v1/debug/trace").unwrap();
        assert_eq!(t.status, 200);
        assert!(t.body.contains("req:abc-123"), "{}", t.body);
        Json::parse(&t.body).expect("debug trace is valid JSON");
        assert_eq!(client.get("/v1/debug/trace?last_ms=abc").unwrap().status, 400);

        drop(client);
        server.shutdown();
    }

    #[test]
    fn metrics_counts_responses() {
        let mut server = tiny_server(HttpServerConfig::default());
        let mut client = NetClient::connect(server.local_addr()).unwrap();
        client.get("/healthz").unwrap();
        client.get("/nope").unwrap();
        let m = client.get("/metrics").unwrap();
        assert_eq!(m.status, 200);
        assert!(m.body.contains("tf_fpga_http_responses_total{code=\"200\"} 1"), "{}", m.body);
        assert!(m.body.contains("tf_fpga_http_responses_total{code=\"404\"} 1"), "{}", m.body);
        assert!(m.body.contains("tf_fpga_serve_requests_total 0"), "{}", m.body);
        assert!(m.body.contains("tf_fpga_agent_dispatches_total{agent="), "{}", m.body);
        // Stage histograms and the recorder drop counter are always
        // exposed, even before any predict request.
        assert!(
            m.body.contains("tf_fpga_stage_latency_us_count{stage=\"admission_wait\"} 0"),
            "{}",
            m.body
        );
        assert!(m.body.contains("tf_fpga_trace_events_dropped_total 0"), "{}", m.body);
        drop(client);
        server.shutdown();
    }
}
