//! The binary tensor wire format behind `:predict-bin` and the
//! `application/x-tf-fpga-tensor` content type.
//!
//! Layout (all multi-byte integers little-endian):
//!
//! | offset        | size | field                                        |
//! |---------------|------|----------------------------------------------|
//! | 0             | 4    | magic `"TFT0"`                               |
//! | 4             | 1    | dtype code (`1` = f32, little-endian)        |
//! | 5             | 1    | rank *r* of the per-sample shape (≤ 8)       |
//! | 6             | 2    | reserved, must be zero                       |
//! | 8             | 4    | row count *n* (u32)                          |
//! | 12            | 4·r  | per-sample dims, u32 each                    |
//! | 12 + 4·r      | rest | payload: n · ∏dims f32 values, raw LE bytes  |
//!
//! The dims describe *one sample* (the batch dim is the explicit row
//! count), mirroring the serving bucket key: a request buckets by
//! signature + per-sample shape, and its rows append along dim 0. The
//! payload needs no parsing at all — the HTTP worker copies each row's
//! bytes straight into the batch lane's staging buffer through a
//! [`TensorWriter`], which is the zero-copy path the
//! `tf_fpga_serve_bytes_copied_total` counter proves out.

use crate::serve::batcher::TensorWriter;

/// Content type selecting the binary tensor body on the wire.
pub const TENSOR_CONTENT_TYPE: &str = "application/x-tf-fpga-tensor";

/// Leading magic bytes of every binary tensor body.
pub const MAGIC: &[u8; 4] = b"TFT0";

/// dtype code for little-endian f32 (the only dtype served today).
pub const DTYPE_F32: u8 = 1;

/// Maximum per-sample rank the header can carry.
pub const MAX_RANK: usize = 8;

/// Fixed header bytes before the dims table.
pub const FIXED_HEADER_LEN: usize = 12;

/// A validated binary tensor header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireHeader {
    /// Number of samples (rows along dim 0).
    pub rows: usize,
    /// Per-sample shape (batch dim excluded).
    pub dims: Vec<usize>,
    /// Bytes occupied by the header; the payload starts here.
    pub header_len: usize,
}

impl WireHeader {
    /// Elements in one sample (∏dims; 1 for rank 0).
    pub fn elems_per_row(&self) -> usize {
        self.dims.iter().product()
    }

    /// Payload bytes in one sample row.
    pub fn row_bytes(&self) -> usize {
        self.elems_per_row() * 4
    }

    /// The raw f32 payload following the header.
    pub fn payload<'a>(&self, body: &'a [u8]) -> &'a [u8] {
        &body[self.header_len..]
    }
}

/// Encode `rows` samples of shape `dims` from a flat f32 slice
/// (`flat.len()` must be `rows · ∏dims`).
pub fn encode_flat(dims: &[usize], rows: usize, flat: &[f32]) -> Vec<u8> {
    let per_row: usize = dims.iter().product();
    assert!(dims.len() <= MAX_RANK, "rank {} exceeds {MAX_RANK}", dims.len());
    assert_eq!(flat.len(), rows * per_row, "flat length vs rows×dims");
    let mut out = Vec::with_capacity(FIXED_HEADER_LEN + dims.len() * 4 + flat.len() * 4);
    out.extend_from_slice(MAGIC);
    out.push(DTYPE_F32);
    out.push(dims.len() as u8);
    out.extend_from_slice(&[0, 0]); // reserved
    out.extend_from_slice(&(rows as u32).to_le_bytes());
    for &d in dims {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for &v in flat {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Encode one row per slice (each of length `∏dims`).
pub fn encode_rows(dims: &[usize], rows: &[&[f32]]) -> Vec<u8> {
    let per_row: usize = dims.iter().product();
    let mut flat = Vec::with_capacity(rows.len() * per_row);
    for r in rows {
        assert_eq!(r.len(), per_row, "row length vs ∏dims");
        flat.extend_from_slice(r);
    }
    encode_flat(dims, rows.len(), &flat)
}

/// Validate and decode a binary tensor body's header. Checks magic,
/// dtype, rank bound, reserved bytes and that the payload length is
/// exactly `rows · ∏dims · 4` bytes.
pub fn decode_header(body: &[u8]) -> Result<WireHeader, String> {
    if body.len() < FIXED_HEADER_LEN {
        return Err(format!(
            "binary tensor body too short: {} bytes, need at least {FIXED_HEADER_LEN}",
            body.len()
        ));
    }
    if &body[0..4] != MAGIC {
        return Err("bad magic: binary tensor bodies start with \"TFT0\"".into());
    }
    if body[4] != DTYPE_F32 {
        return Err(format!("unsupported dtype code {} (only 1 = f32)", body[4]));
    }
    let rank = body[5] as usize;
    if rank > MAX_RANK {
        return Err(format!("rank {rank} exceeds the maximum of {MAX_RANK}"));
    }
    if body[6] != 0 || body[7] != 0 {
        return Err("reserved header bytes must be zero".into());
    }
    let rows = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
    let header_len = FIXED_HEADER_LEN + rank * 4;
    if body.len() < header_len {
        return Err(format!(
            "truncated dims table: rank {rank} needs a {header_len}-byte header, got {}",
            body.len()
        ));
    }
    let mut dims = Vec::with_capacity(rank);
    for i in 0..rank {
        let off = FIXED_HEADER_LEN + i * 4;
        dims.push(u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize);
    }
    let per_row: usize = dims
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or("per-sample element count overflows")?;
    let expect = rows
        .checked_mul(per_row)
        .and_then(|e| e.checked_mul(4))
        .ok_or("payload length overflows")?;
    let got = body.len() - header_len;
    if got != expect {
        return Err(format!(
            "payload is {got} bytes but {rows} rows of shape {dims:?} need {expect}"
        ));
    }
    Ok(WireHeader { rows, dims, header_len })
}

/// Copy one row of raw little-endian f32 payload into a lane's
/// [`TensorWriter`] — the binary path's decode step (`row.len()` must be
/// a multiple of 4).
pub fn copy_row_into(row: &[u8], w: &mut TensorWriter<'_>) {
    debug_assert_eq!(row.len() % 4, 0);
    for chunk in row.chunks_exact(4) {
        w.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_bytes_match_documented_offsets() {
        let body = encode_flat(&[1, 28, 28], 2, &vec![0.5f32; 2 * 784]);
        assert_eq!(&body[0..4], b"TFT0", "magic at offset 0");
        assert_eq!(body[4], 1, "dtype code at offset 4");
        assert_eq!(body[5], 3, "rank at offset 5");
        assert_eq!(&body[6..8], &[0, 0], "reserved at offset 6");
        assert_eq!(u32::from_le_bytes(body[8..12].try_into().unwrap()), 2, "rows");
        assert_eq!(u32::from_le_bytes(body[12..16].try_into().unwrap()), 1);
        assert_eq!(u32::from_le_bytes(body[16..20].try_into().unwrap()), 28);
        assert_eq!(u32::from_le_bytes(body[20..24].try_into().unwrap()), 28);
        assert_eq!(body.len(), 24 + 2 * 784 * 4, "payload after the dims table");
    }

    #[test]
    fn round_trip_preserves_bits() {
        let rows: Vec<Vec<f32>> = vec![
            vec![0.0, -0.0, 1.5, f32::MIN_POSITIVE / 2.0],
            vec![-1.0e-40, 3.4e38, -2.5, 42.0],
        ];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let body = encode_rows(&[4], &refs);
        let h = decode_header(&body).unwrap();
        assert_eq!((h.rows, h.dims.as_slice(), h.elems_per_row()), (2, &[4usize][..], 4));
        let payload = h.payload(&body);
        assert_eq!(payload.len(), 2 * h.row_bytes());
        for (i, want) in rows.iter().enumerate() {
            let mut dst = Vec::new();
            let mut w = test_writer(&mut dst, 4);
            copy_row_into(&payload[i * h.row_bytes()..(i + 1) * h.row_bytes()], &mut w);
            for (a, b) in dst.iter().zip(want) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i} not bit-exact");
            }
        }
    }

    // TensorWriter's fields are private to the batcher; go through a lane
    // to obtain one positioned over a plain Vec.
    fn test_writer(dst: &mut Vec<f32>, expected: usize) -> TensorWriter<'_> {
        TensorWriter::for_tests(dst, expected)
    }

    #[test]
    fn rank_zero_is_one_scalar_per_row() {
        let body = encode_flat(&[], 3, &[1.0, 2.0, 3.0]);
        let h = decode_header(&body).unwrap();
        assert_eq!((h.rows, h.elems_per_row(), h.header_len), (3, 1, 12));
    }

    #[test]
    fn malformed_bodies_are_rejected_with_reasons() {
        let good = encode_flat(&[2], 1, &[1.0, 2.0]);
        assert!(decode_header(&good).is_ok());

        assert!(decode_header(&good[..8]).unwrap_err().contains("too short"));

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(decode_header(&bad).unwrap_err().contains("magic"));

        let mut bad = good.clone();
        bad[4] = 9;
        assert!(decode_header(&bad).unwrap_err().contains("dtype"));

        let mut bad = good.clone();
        bad[5] = 9;
        assert!(decode_header(&bad).unwrap_err().contains("rank"));

        let mut bad = good.clone();
        bad[6] = 1;
        assert!(decode_header(&bad).unwrap_err().contains("reserved"));

        let mut truncated = good.clone();
        truncated.truncate(good.len() - 4);
        assert!(decode_header(&truncated).unwrap_err().contains("payload"));

        let mut extra = good.clone();
        extra.extend_from_slice(&[0; 4]);
        assert!(decode_header(&extra).unwrap_err().contains("payload"));

        // Dims table cut off mid-header.
        let short = encode_flat(&[2, 2], 1, &[0.0; 4]);
        assert!(decode_header(&short[..14]).unwrap_err().contains("dims table"));
    }
}
