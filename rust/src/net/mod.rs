//! Network serving frontend: HTTP/1.1 over `std::net`, fronting the
//! async batched inference pipeline with admission control.
//!
//! The paper's thesis is hiding accelerator complexity behind a familiar
//! frontend; this module extends that one layer further out — the FPGA
//! pool, plan compiler and batching pipeline all sit behind a plain JSON
//! HTTP API a `curl` can hit. Std-only by design (no tokio/hyper in the
//! offline vendor set): a blocking accept thread feeds a worker pool,
//! which is the right shape for a backend whose concurrency is bounded by
//! FPGA agents and batch lanes, not by socket counts.
//!
//! Pieces:
//!
//! * [`http`] — minimal HTTP/1.1 wire parsing/writing with hard caps on
//!   head and body size.
//! * [`admission`] — who gets in: deterministic per-tenant token buckets
//!   ([`admission::RateLimiter`]), the bounded pending gate
//!   ([`admission::PendingGate`]) that sheds with `429` + `Retry-After`,
//!   and pre-dispatch [`admission::Deadline`] cancellation, all over an
//!   injected [`admission::Clock`].
//! * [`server`] — [`HttpServer`]: routes (`:predict`, `:predict-bin`,
//!   `/v1/models`, `/healthz`, `/metrics`, `/v1/debug/trace`), structured
//!   JSON error bodies, graceful drain on [`HttpServer::shutdown`].
//!   Every predict request carries an `X-Request-Id` (minted or echoed)
//!   and a [`crate::trace::SpanCtx`] that follows it from accept to
//!   kernel retire; per-stage latencies feed the `/metrics` histograms
//!   and the always-on flight recorder behind `/v1/debug/trace`.
//! * [`wire`] — the binary tensor format (`application/x-tf-fpga-tensor`):
//!   fixed header + raw little-endian f32 payload, decoded straight into
//!   the batch lane's staging buffer. A base64 raw-f32 tier
//!   (`instances_b64`) rides inside the JSON API as the middle ground.
//! * [`prom`] — frontend counters and the Prometheus text rendering.
//! * [`client`] — [`NetClient`], the blocking loopback client the
//!   integration tests and the `http_serving` bench drive the server
//!   with.
//!
//! ```no_run
//! use tf_fpga::net::{HttpServer, HttpServerConfig, NetClient};
//! use tf_fpga::serve::{AsyncInferenceServer, AsyncServerConfig};
//!
//! let srv = AsyncInferenceServer::start(AsyncServerConfig::default()).unwrap();
//! let mut http = HttpServer::start(srv, HttpServerConfig::default()).unwrap();
//! let mut client = NetClient::connect(http.local_addr()).unwrap();
//! let image = vec![0.0f32; 784];
//! let resp = client.predict("mnist", &[image.as_slice()], &[]).unwrap();
//! assert_eq!(resp.status, 200);
//! http.shutdown(); // drain: finish in-flight, refuse new, stop
//! ```

pub mod admission;
pub mod client;
pub mod http;
pub mod prom;
pub mod server;
pub mod wire;

pub use admission::{Clock, Deadline, ManualClock, PendingGate, RateLimiter, SystemClock};
pub use client::{
    decode_predictions, decode_predictions_bin, one_shot, predict_body, HttpResponse, NetClient,
    RawResponse,
};
pub use prom::{NetCounters, NetSnapshot};
pub use server::{HttpServer, HttpServerConfig};
pub use wire::TENSOR_CONTENT_TYPE;
