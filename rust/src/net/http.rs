//! HTTP/1.1 wire handling — request parsing and response writing over any
//! `BufRead`/`Write` pair, no external dependencies.
//!
//! Deliberately small: the serving frontend needs exactly request-line +
//! headers + `Content-Length` bodies (no chunked transfer, no trailers),
//! with hard caps on header and body size so an adversarial peer cannot
//! balloon memory. Everything protocol-level that can go wrong maps to a
//! [`HttpError::Bad`] carrying the status the connection handler should
//! answer with before closing.

use std::fmt::Write as _;
use std::io::{BufRead, Read, Write};
use std::time::{Duration, Instant};

/// Cap on the total bytes of request line + headers. Generous for any
/// real client, tight enough to bound a hostile one.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Reads are chunked this small so the aggregate request deadline is
/// checked often: a slow-trickle client (one byte per read-timeout) can
/// overstay its budget by at most one chunk of per-byte timeouts, not by
/// the whole head/body.
const READ_CHUNK: usize = 256;

/// A parsed HTTP request. Header names are lowercased at parse time;
/// values keep their bytes (trimmed of surrounding whitespace).
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Request target with any `?query` suffix split off.
    pub path: String,
    /// The raw query string (bytes after `?`, without it); empty when the
    /// target carried none.
    pub query: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// `HTTP/1.0` requests (and `Connection: close`) disable keep-alive.
    pub http10: bool,
}

impl Request {
    /// First header value for `name` (case-insensitive lookup — names are
    /// stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// First value of a `k=v` query parameter (no percent-decoding — the
    /// debug endpoints using this take only simple numerics).
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.split('&').find_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            (k == name).then_some(v)
        })
    }

    /// Whether the client asked for the connection to end after this
    /// exchange.
    pub fn wants_close(&self) -> bool {
        self.http10
            || self
                .header("connection")
                .map(|v| v.eq_ignore_ascii_case("close"))
                .unwrap_or(false)
    }
}

/// Why a request could not be read.
#[derive(Debug)]
pub enum HttpError {
    /// Clean end-of-stream before any request byte — the normal way a
    /// keep-alive connection ends.
    Eof,
    /// Transport failure (including read timeouts on idle keep-alive).
    Io(std::io::Error),
    /// Protocol violation: answer with `status`/`msg`, then close.
    Bad { status: u16, msg: String },
}

impl From<std::io::Error> for HttpError {
    fn from(e: std::io::Error) -> HttpError {
        HttpError::Io(e)
    }
}

fn bad(status: u16, msg: impl Into<String>) -> HttpError {
    HttpError::Bad { status, msg: msg.into() }
}

/// The running limits of one request read: a byte budget for the head
/// and a wall-clock deadline armed when the first bytes arrive (so idle
/// keep-alive waits are not charged against it).
struct ReadLimits {
    head_budget: usize,
    read_budget: Duration,
    deadline: Option<Instant>,
}

impl ReadLimits {
    /// Arm the deadline once the request has started flowing.
    fn started(&mut self) {
        if self.deadline.is_none() {
            self.deadline = Some(Instant::now() + self.read_budget);
        }
    }

    fn check(&self) -> Result<(), HttpError> {
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                return Err(bad(
                    408,
                    format!("request not fully read within {:?}", self.read_budget),
                ));
            }
        }
        Ok(())
    }
}

/// Read one line (terminated by `\n`), enforcing the running head-byte
/// budget and the aggregate read deadline. Reads are capped at the
/// remaining budget (a hostile peer cannot balloon memory with a
/// newline-free stream) and chunked at [`READ_CHUNK`] bytes so a
/// trickling peer hits `408` shortly after the budget expires instead of
/// holding a worker for hours. Returns the line without its `\r\n`/`\n`
/// terminator.
fn read_line(r: &mut impl BufRead, limits: &mut ReadLimits) -> Result<String, HttpError> {
    let mut line = String::new();
    loop {
        // Checked before every chunk — including between short complete
        // header lines — so trickling many tiny lines is cut off just
        // like trickling one long one.
        limits.check()?;
        let cap = (limits.head_budget + 1 - line.len()).min(READ_CHUNK);
        let n = r.by_ref().take(cap as u64).read_line(&mut line)?;
        if n == 0 {
            if line.is_empty() {
                return Err(HttpError::Eof);
            }
            return Err(bad(400, "truncated request head"));
        }
        limits.started();
        if line.len() > limits.head_budget {
            return Err(bad(431, format!("request head exceeds {MAX_HEAD_BYTES} bytes")));
        }
        if line.ends_with('\n') {
            break;
        }
    }
    limits.head_budget -= line.len();
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(line)
}

/// Read one full request. `max_body` bounds the `Content-Length` a client
/// may declare; longer bodies are refused with `413` *before* reading
/// them. `read_budget` is the wall-clock allowance for reading the whole
/// request once its first bytes arrive (idle keep-alive waiting is not
/// charged): a slow-trickle client gets `408` at the next [`READ_CHUNK`]
/// boundary past the budget, so it cannot pin a worker indefinitely.
pub fn read_request(
    r: &mut impl BufRead,
    max_body: usize,
    read_budget: Duration,
) -> Result<Request, HttpError> {
    let mut limits =
        ReadLimits { head_budget: MAX_HEAD_BYTES, read_budget, deadline: None };
    let line = read_line(r, &mut limits)?;
    let mut parts = line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || target.is_empty() || version.is_empty() || parts.next().is_some() {
        return Err(bad(400, format!("malformed request line '{line}'")));
    }
    let http10 = match version {
        "HTTP/1.1" => false,
        "HTTP/1.0" => true,
        other => return Err(bad(505, format!("unsupported version '{other}'"))),
    };

    let mut headers = Vec::new();
    loop {
        let line = match read_line(r, &mut limits) {
            // EOF mid-headers is a truncated request, not a clean close.
            Err(HttpError::Eof) => return Err(bad(400, "truncated request head")),
            other => other?,
        };
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(bad(400, format!("malformed header line '{line}'")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    if headers.iter().any(|(k, _)| k == "transfer-encoding") {
        return Err(bad(501, "transfer-encoding is not supported; send Content-Length"));
    }
    let content_length = match headers.iter().find(|(k, _)| k == "content-length") {
        None => 0,
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|_| bad(400, format!("bad Content-Length '{v}'")))?,
    };
    if content_length > max_body {
        // Drain what the client already wrote (bounded to roughly what
        // fits in flight — a trickler must not turn the courtesy drain
        // into a hold) before erroring: closing with unread data in the
        // receive buffer sends a TCP reset that can clobber the 413
        // response.
        let drain = content_length.min(64 << 10) as u64;
        let _ = std::io::copy(&mut r.by_ref().take(drain), &mut std::io::sink());
        return Err(bad(
            413,
            format!("body is {content_length} bytes, limit {max_body}"),
        ));
    }
    // Chunked body read with the same aggregate deadline: the declared
    // length is already bounded, this bounds the *time* a trickler can
    // take delivering it.
    let mut body = vec![0u8; content_length];
    let mut off = 0;
    while off < content_length {
        limits.check()?;
        let end = (off + READ_CHUNK).min(content_length);
        r.read_exact(&mut body[off..end])?;
        limits.started();
        off = end;
    }

    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    Ok(Request { method, path, query, headers, body, http10 })
}

/// An HTTP response about to be written. Always carries an explicit
/// `Content-Length`; `close` controls the `Connection` header (and tells
/// the connection loop to hang up afterwards).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    pub extra_headers: Vec<(String, String)>,
    pub close: bool,
}

impl Response {
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into().into_bytes(),
            extra_headers: Vec::new(),
            close: false,
        }
    }

    /// Binary tensor responses (`application/x-tf-fpga-tensor` bodies,
    /// mirroring a binary request's encoding).
    pub fn binary(status: u16, body: Vec<u8>) -> Response {
        Response {
            status,
            content_type: crate::net::wire::TENSOR_CONTENT_TYPE,
            body,
            extra_headers: Vec::new(),
            close: false,
        }
    }

    /// Prometheus/text responses.
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; version=0.0.4",
            body: body.into().into_bytes(),
            extra_headers: Vec::new(),
            close: false,
        }
    }

    pub fn with_header(mut self, name: impl Into<String>, value: impl Into<String>) -> Response {
        self.extra_headers.push((name.into(), value.into()));
        self
    }

    pub fn with_close(mut self) -> Response {
        self.close = true;
        self
    }

    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        let mut head = String::with_capacity(128);
        let _ = write!(
            head,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len()
        );
        for (name, value) in &self.extra_headers {
            let _ = write!(head, "{name}: {value}\r\n");
        }
        let _ = write!(
            head,
            "Connection: {}\r\n\r\n",
            if self.close { "close" } else { "keep-alive" }
        );
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrase for the handful of statuses the server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(doc: &str) -> Result<Request, HttpError> {
        read_request(&mut Cursor::new(doc.as_bytes()), 1 << 20, Duration::from_secs(5))
    }

    #[test]
    fn parses_request_with_body_and_lowercases_headers() {
        let req = parse(
            "POST /v1/models/mnist:predict?verbose=1 HTTP/1.1\r\n\
             Host: localhost\r\n\
             X-Tenant: alice\r\n\
             Content-Length: 4\r\n\
             \r\nabcd",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/models/mnist:predict", "query split off");
        assert_eq!(req.query, "verbose=1", "query preserved separately");
        assert_eq!(req.query_param("verbose"), Some("1"));
        assert_eq!(req.query_param("missing"), None);
        assert_eq!(req.header("x-tenant"), Some("alice"));
        assert_eq!(req.header("X-TENANT"), Some("alice"));
        assert_eq!(req.body, b"abcd");
        assert!(!req.wants_close());
    }

    #[test]
    fn bare_lf_line_endings_parse_too() {
        let req = parse("GET /healthz HTTP/1.1\nHost: x\n\n").unwrap();
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.body, b"");
    }

    #[test]
    fn connection_close_and_http10_disable_keep_alive() {
        let req = parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(req.wants_close());
        let req = parse("GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(req.wants_close());
    }

    #[test]
    fn clean_eof_is_eof_not_an_error() {
        assert!(matches!(parse(""), Err(HttpError::Eof)));
        // But a truncated head is a 400.
        match parse("GET / HTTP/1.1\r\nHost: x\r\n") {
            Err(HttpError::Bad { status: 400, .. }) => {}
            other => panic!("expected 400, got {other:?}"),
        }
    }

    #[test]
    fn oversized_declared_body_is_413_before_reading() {
        let doc = "POST /x HTTP/1.1\r\nContent-Length: 999\r\n\r\n";
        match read_request(&mut Cursor::new(doc.as_bytes()), 10, Duration::from_secs(5)) {
            Err(HttpError::Bad { status: 413, msg }) => {
                assert!(msg.contains("999") && msg.contains("10"), "{msg}");
            }
            other => panic!("expected 413, got {other:?}"),
        }
    }

    #[test]
    fn exhausted_read_budget_is_408_not_a_pinned_worker() {
        // A zero budget expires the moment the request starts flowing, so
        // the chunked body loop refuses before reading a byte of body —
        // the same check that cuts off a slow-trickle client.
        let doc = format!(
            "POST /x HTTP/1.1\r\nContent-Length: 600\r\n\r\n{}",
            "a".repeat(600)
        );
        match read_request(&mut Cursor::new(doc.as_bytes()), 1 << 20, Duration::ZERO) {
            Err(HttpError::Bad { status: 408, msg }) => {
                assert!(msg.contains("not fully read"), "{msg}");
            }
            other => panic!("expected 408, got {other:?}"),
        }
    }

    #[test]
    fn oversized_head_is_431() {
        let doc = format!(
            "GET / HTTP/1.1\r\nX-Big: {}\r\n\r\n",
            "a".repeat(MAX_HEAD_BYTES)
        );
        match parse(&doc) {
            Err(HttpError::Bad { status: 431, .. }) => {}
            other => panic!("expected 431, got {other:?}"),
        }
    }

    #[test]
    fn garbage_request_lines_and_versions_are_rejected() {
        for doc in [
            "NOT-HTTP\r\n\r\n",
            "GET /\r\n\r\n",
            "GET / HTTP/2.0\r\n\r\n",
            "GET / HTTP/1.1 extra\r\n\r\n",
        ] {
            match parse(doc) {
                Err(HttpError::Bad { .. }) => {}
                other => panic!("{doc:?} should be rejected, got {other:?}"),
            }
        }
        match parse("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n") {
            Err(HttpError::Bad { status: 400, .. }) => {}
            other => panic!("expected 400, got {other:?}"),
        }
    }

    #[test]
    fn transfer_encoding_is_refused() {
        match parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n") {
            Err(HttpError::Bad { status: 501, .. }) => {}
            other => panic!("expected 501, got {other:?}"),
        }
    }

    #[test]
    fn response_writes_status_line_headers_and_body() {
        let mut buf = Vec::new();
        Response::json(429, "{\"e\":1}")
            .with_header("Retry-After", "2")
            .with_close()
            .write_to(&mut buf)
            .unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Content-Length: 7\r\n"), "{text}");
        assert!(text.contains("Retry-After: 2\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"e\":1}"), "{text}");
    }
}
