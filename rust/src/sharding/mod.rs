//! Multi-FPGA sharded dispatch: an agent pool behind one runtime.
//!
//! The paper's device is a *single* reconfigurable FPGA whose PR regions
//! are re-targeted per kernel at runtime. Nothing in that model is
//! inherently single-device: a pool of such agents — each with its own PR
//! regions, ICAP and [`crate::reconfig::manager::ReconfigManager`] — can
//! serve shards of the same traffic, and the scheduling problem moves up
//! one level: *which* agent should a given kernel dispatch land on?
//!
//! Two pieces:
//!
//! * [`FpgaPool`] — constructs N independent
//!   [`FpgaAgent`](crate::fpga::device::FpgaAgent)s and registers
//!   every role bitstream on all of them **under one shared kernel-object
//!   id**, so a compiled [`crate::tf::plan::ExecutionPlan`]'s pre-resolved
//!   `(device, kernel_object)` pairs stay valid on every member of the
//!   pool. Plug it into [`crate::hsa::runtime::HsaRuntimeBuilder::with_fpga_pool`].
//! * [`Router`] — assigns each FPGA dispatch to an agent via a pluggable
//!   [`ShardStrategy`]:
//!   - [`ShardStrategy::RoundRobin`] — cyclic, load-blind;
//!   - [`ShardStrategy::LeastLoaded`] — lowest in-flight counter wins
//!     (ties break to the lowest agent index, so routing is a pure
//!     function of the observed call sequence);
//!   - [`ShardStrategy::KernelAffinity`] — prefer agents already holding
//!     the kernel's bitstream in a PR region (no reconfiguration); place
//!     cold kernels on an agent with a free region first (least-loaded
//!     otherwise), and *replicate* a hot
//!     kernel onto an idle agent when the queued-demand hints
//!     ([`Router::hint_demand`], fed by the serving batcher) say its
//!     resident replicas cannot keep up.
//!
//! Every dispatch returns a [`RouteGuard`] that decrements the chosen
//! agent's in-flight gauge on drop, so load balancing sees completions
//! without any callback plumbing. Per-agent accounting rolls up through
//! [`Router::report`] / [`Router::rollup`].
//!
//! **Fleet resilience.** The router also owns the pool's health state:
//! [`Router::check_health`] probes every agent (liveness + oldest
//! in-flight execution age, see
//! [`FpgaAgent::health`](crate::fpga::device::FpgaAgent::health)) and
//! **quarantines** unresponsive agents — excluded from every strategy's
//! candidate set until a later check re-admits them. Dispatch harvesters
//! (plan replay, the async completer) probe completion signals in
//! [`HealthPolicy::probe_interval`] slices and, when their agent lands in
//! quarantine, park the wedged dispatch as a *zombie* (its [`RouteGuard`]
//! keeps the load gauge truthful until the stall finishes) and retry on
//! an alternate agent, bounded by [`HealthPolicy::max_retries`] and the
//! overall dispatch deadline. With zero quarantined agents the masked
//! candidate sets are identical to the unmasked ones, so healthy-pool
//! routing is bit-for-bit unchanged (property-pinned).

pub mod pool;
pub mod router;

pub use pool::FpgaPool;
pub use router::{
    HealthCheckOutcome, HealthPolicy, RouteGuard, Router, ShardAgentReport,
    ShardStrategy,
};
