//! Replica-aware, load-balanced routing of FPGA dispatches.
//!
//! The router owns one *slot* per pool agent — the agent handle, its AQL
//! queue and a trio of counters (in-flight gauge, total dispatches,
//! in-flight high-water mark). [`Router::route`] picks a slot for a
//! kernel object and returns the slot's queue plus a [`RouteGuard`] whose
//! `Drop` retires the dispatch from the gauge, so callers need no
//! completion callbacks: hold the guard until the kernel's result is
//! harvested and load balancing stays truthful.
//!
//! Strategy selection is **deterministic**: every tie breaks toward the
//! lowest agent index, and the only inputs are the router's own counters,
//! the agents' residency maps and the demand table — all of which are
//! pure functions of the call sequence. Two routers fed the same sequence
//! of `route`/guard-drop/`hint_demand` calls make identical choices
//! (property-tested in `tests/prop_invariants.rs`).

use crate::fpga::device::FpgaAgent;
use crate::hsa::agent::Agent;
use crate::hsa::queue::Queue;
use crate::hsa::signal::Signal;
use crate::reconfig::manager::ReconfigStats;
use crate::trace::{EventKind, TraceRecorder};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Health-check tuning for the router's quarantine machinery.
///
/// An agent is **quarantined** (excluded from routing) when a health
/// check finds it killed, or finds an execution stuck inside it for
/// longer than `stall_threshold`. It is **re-admitted** when a later
/// check finds it alive with nothing overdue. `probe_interval` is how
/// long dispatch harvesters wait on a completion signal between health
/// probes, and `max_retries` bounds how many times one dispatch may be
/// retried on an alternate agent before its error is surfaced.
#[derive(Debug, Clone)]
pub struct HealthPolicy {
    pub stall_threshold: Duration,
    pub probe_interval: Duration,
    pub max_retries: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            stall_threshold: Duration::from_secs(2),
            probe_interval: Duration::from_millis(250),
            max_retries: 2,
        }
    }
}

/// What one [`Router::check_health`] pass changed.
#[derive(Debug, Clone, Default)]
pub struct HealthCheckOutcome {
    /// Slot indices newly quarantined by this pass.
    pub quarantined: Vec<usize>,
    /// Slot indices newly re-admitted by this pass.
    pub readmitted: Vec<usize>,
}

/// How the router assigns dispatches to pool agents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Cyclic assignment, blind to load and residency. Cheapest decision
    /// (one atomic increment) and perfectly even over any window that is
    /// a multiple of the pool size — but it reconfigures freely, so a
    /// working set larger than one agent's PR regions thrashes. The
    /// baseline the other strategies are measured against.
    RoundRobin,
    /// Lowest in-flight count wins, ties to the lowest agent index. Best
    /// when kernels are uniform (any agent serves any dispatch equally
    /// well) and batch runtimes vary; ignores bitstream residency, so it
    /// shares `RoundRobin`'s thrashing behaviour for large working sets.
    LeastLoaded,
    /// Residency-first routing: prefer agents already holding the
    /// kernel's bitstream in a PR region (dispatching there reconfigures
    /// nothing). A *cold* kernel is placed on an agent with a free region
    /// when one exists — loading there evicts nothing and spreads the
    /// working set — otherwise on the least-loaded agent. A *hot* kernel
    /// (queued demand from [`Router::hint_demand`] exceeding its replica
    /// count while every replica is busy) spills onto an idle agent,
    /// whose reconfiguration creates a new replica that later affinity
    /// decisions spread load across. The default for serving.
    KernelAffinity,
}

impl ShardStrategy {
    pub fn name(self) -> &'static str {
        match self {
            ShardStrategy::RoundRobin => "round-robin",
            ShardStrategy::LeastLoaded => "least-loaded",
            ShardStrategy::KernelAffinity => "kernel-affinity",
        }
    }

    pub fn parse(s: &str) -> Option<ShardStrategy> {
        match s {
            "round-robin" => Some(ShardStrategy::RoundRobin),
            "least-loaded" => Some(ShardStrategy::LeastLoaded),
            "kernel-affinity" => Some(ShardStrategy::KernelAffinity),
            _ => None,
        }
    }

    pub const ALL: [ShardStrategy; 3] = [
        ShardStrategy::RoundRobin,
        ShardStrategy::LeastLoaded,
        ShardStrategy::KernelAffinity,
    ];
}

struct Slot {
    agent: Arc<FpgaAgent>,
    queue: Queue,
    inflight: Arc<AtomicU64>,
    dispatches: AtomicU64,
    max_inflight: AtomicU64,
    /// True while the slot is excluded from routing (see [`HealthPolicy`]).
    quarantined: AtomicBool,
    /// Times this slot entered quarantine.
    quarantines: AtomicU64,
    /// Times this slot was re-admitted after quarantine.
    readmissions: AtomicU64,
    /// Dispatches abandoned on this slot and retried on an alternate.
    retries: AtomicU64,
}

/// Retires one routed dispatch from its agent's in-flight gauge on drop.
///
/// Lifecycle: [`Router::route`] increments the chosen slot's gauge and
/// hands the guard to whoever owns the dispatch — plan replay holds it in
/// the in-flight ring until the step's completion signal fires;
/// `Session::run_async` moves it into the returned `PendingRun`, so the
/// gauge retires when the caller harvests (or drops) the pending result.
/// Hold the guard for exactly as long as the dispatch occupies the agent:
/// dropping early under-reports load (least-loaded routing over-commits
/// the agent), leaking it pins the agent "busy" forever. The guard only
/// touches the shared gauge, so it is `Send` and may drop on any thread.
#[derive(Debug)]
pub struct RouteGuard {
    inflight: Arc<AtomicU64>,
}

impl Drop for RouteGuard {
    fn drop(&mut self) {
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Point-in-time accounting of one pool agent (see [`Router::report`]).
#[derive(Debug, Clone)]
pub struct ShardAgentReport {
    pub agent: String,
    /// Dispatches routed to this agent.
    pub dispatches: u64,
    /// Dispatches routed but not yet retired.
    pub inflight: u64,
    /// High-water mark of concurrently in-flight dispatches.
    pub max_inflight: u64,
    /// The agent's own reconfiguration accounting.
    pub reconfig: ReconfigStats,
    /// Whether the agent is currently excluded from routing. (In the
    /// pooled rollup: whether *any* agent is.)
    pub quarantined: bool,
    /// Times the agent entered quarantine.
    pub quarantines: u64,
    /// Times the agent was re-admitted after quarantine.
    pub readmissions: u64,
    /// Dispatches abandoned on this agent and retried on an alternate.
    pub retries: u64,
    /// False after [`FpgaAgent::kill`] (rollup: false if any agent dead).
    pub alive: bool,
    /// Time since the agent last completed an execution, µs (None =
    /// never; rollup: the freshest Some across the pool).
    pub heartbeat_age_us: Option<u64>,
    /// Age of the oldest execution still inside the agent, µs (0 when
    /// idle; rollup: the max across the pool).
    pub oldest_inflight_us: u64,
}

/// Routes FPGA dispatches across a pool of agents.
pub struct Router {
    slots: Vec<Slot>,
    strategy: ShardStrategy,
    rr_next: AtomicUsize,
    /// Latest queued-demand hint per kernel object (serving queue depths),
    /// consulted by `KernelAffinity` to decide replication. Ordered map so
    /// iteration/debug output is deterministic.
    demand: Mutex<BTreeMap<u64, u64>>,
    health: HealthPolicy,
    /// Abandoned-but-still-executing dispatches (a retry left a stall
    /// behind): the completion signal plus the route guard that keeps the
    /// slot's in-flight gauge truthful until the stall actually finishes.
    /// Swept by `check_health`/`report`.
    zombies: Mutex<Vec<(Signal, RouteGuard)>>,
    /// Zombies whose late completion has been observed and discarded.
    zombies_reaped: AtomicU64,
    /// Optional recorder for routing-decision annotations. Purely
    /// observational: [`Router::pick`] never consults it, so tracing can
    /// never perturb the (property-pinned) routing determinism.
    trace: Option<TraceRecorder>,
}

impl Router {
    /// Build a router over `(agent, queue)` pairs — one AQL queue per
    /// agent, created by the caller on the shared runtime.
    pub fn new(
        slots: Vec<(Arc<FpgaAgent>, Queue)>,
        strategy: ShardStrategy,
    ) -> Router {
        Router::with_health_policy(slots, strategy, HealthPolicy::default())
    }

    /// Like [`Router::new`] with explicit health-check tuning.
    pub fn with_health_policy(
        slots: Vec<(Arc<FpgaAgent>, Queue)>,
        strategy: ShardStrategy,
        health: HealthPolicy,
    ) -> Router {
        assert!(!slots.is_empty(), "router needs at least one agent");
        Router {
            slots: slots
                .into_iter()
                .map(|(agent, queue)| Slot {
                    agent,
                    queue,
                    inflight: Arc::new(AtomicU64::new(0)),
                    dispatches: AtomicU64::new(0),
                    max_inflight: AtomicU64::new(0),
                    quarantined: AtomicBool::new(false),
                    quarantines: AtomicU64::new(0),
                    readmissions: AtomicU64::new(0),
                    retries: AtomicU64::new(0),
                })
                .collect(),
            strategy,
            rr_next: AtomicUsize::new(0),
            demand: Mutex::new(BTreeMap::new()),
            health,
            zombies: Mutex::new(Vec::new()),
            zombies_reaped: AtomicU64::new(0),
            trace: None,
        }
    }

    /// Attach a trace recorder: every routing decision emits an
    /// instantaneous annotation (strategy, chosen agent, quarantine skips)
    /// onto the `router` track. Observational only — the decision itself
    /// is made before the event is recorded and never depends on it.
    pub fn set_trace(&mut self, trace: TraceRecorder) {
        self.trace = Some(trace);
    }

    pub fn health_policy(&self) -> &HealthPolicy {
        &self.health
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn strategy(&self) -> ShardStrategy {
        self.strategy
    }

    pub fn agent(&self, i: usize) -> &Arc<FpgaAgent> {
        &self.slots[i].agent
    }

    pub fn agents(&self) -> impl Iterator<Item = &Arc<FpgaAgent>> {
        self.slots.iter().map(|s| &s.agent)
    }

    /// Pick an agent for `kernel_object` and account the dispatch.
    /// Returns the chosen index, a clone of its queue, and the guard that
    /// retires the dispatch when dropped.
    pub fn route(&self, kernel_object: u64) -> (usize, Queue, RouteGuard) {
        let i = self.pick(kernel_object);
        let slot = &self.slots[i];
        slot.dispatches.fetch_add(1, Ordering::Relaxed);
        let now = slot.inflight.fetch_add(1, Ordering::AcqRel) + 1;
        slot.max_inflight.fetch_max(now, Ordering::AcqRel);
        if let Some(tr) = &self.trace {
            let skipped = self
                .slots
                .iter()
                .filter(|s| s.quarantined.load(Ordering::Acquire))
                .count();
            let agent = &slot.agent.info().name;
            let name = if skipped > 0 {
                format!(
                    "route[{}] -> {agent} (skipped {skipped} quarantined)",
                    self.strategy.name(),
                )
            } else {
                format!("route[{}] -> {agent}", self.strategy.name())
            };
            let ts = tr.now_us();
            tr.record(EventKind::Dispatch, name, "router", i as u32, ts, 0);
        }
        (
            i,
            slot.queue.clone(),
            RouteGuard { inflight: Arc::clone(&slot.inflight) },
        )
    }

    /// Whether slot `i` may receive new dispatches. When *every* slot is
    /// quarantined the mask is void — availability beats purity, and the
    /// dispatch surfaces its own error if the whole pool really is dead.
    /// With zero quarantined slots this accepts everything, so routing is
    /// bit-identical to the mask-free router (regression-pinned by the
    /// determinism properties).
    fn eligible(&self, i: usize) -> bool {
        !self.slots[i].quarantined.load(Ordering::Acquire)
    }

    fn any_eligible(&self) -> bool {
        (0..self.slots.len()).any(|i| self.eligible(i))
    }

    fn pick(&self, kernel_object: u64) -> usize {
        let masked = self.any_eligible();
        let ok = |i: usize| !masked || self.eligible(i);
        match self.strategy {
            ShardStrategy::RoundRobin => {
                // One counter increment per route (quarantined or not), so
                // the cycle position is a pure function of the call count;
                // scan forward deterministically past ineligible slots.
                let start = self.rr_next.fetch_add(1, Ordering::Relaxed);
                (0..self.slots.len())
                    .map(|k| (start + k) % self.slots.len())
                    .find(|&i| ok(i))
                    .unwrap_or(start % self.slots.len())
            }
            ShardStrategy::LeastLoaded => self.least_loaded(ok),
            ShardStrategy::KernelAffinity => self.pick_affinity(kernel_object, &ok),
        }
    }

    /// Index of the least-loaded slot among those passing `eligible`
    /// (lowest index on ties). `eligible` must accept at least one slot.
    ///
    /// Reconfiguration cost is a routing input: at equal in-flight load
    /// an agent whose ICAP is mid-transaction ranks behind an idle one —
    /// a non-resident kernel dispatched there queues behind the transfer.
    /// With prefetching off no ICAP is ever busy in the background, so
    /// the key degenerates to `(inflight, index)` and routing stays
    /// bit-identical (regression-pinned by the determinism properties).
    fn least_loaded(&self, eligible: impl Fn(usize) -> bool) -> usize {
        self.slots
            .iter()
            .enumerate()
            .filter(|(i, _)| eligible(*i))
            .min_by_key(|(i, s)| {
                (s.inflight.load(Ordering::Acquire), s.agent.icap_busy(), *i)
            })
            .map(|(i, _)| i)
            .expect("least_loaded over empty eligible set")
    }

    fn pick_affinity(&self, kernel_object: u64, ok: &dyn Fn(usize) -> bool) -> usize {
        // Every candidate set below is filtered through the eligibility
        // mask. A kernel resident *only* on quarantined agents therefore
        // looks cold, so the cold path re-replicates it onto a healthy
        // agent — exactly the failover the quarantine is for.
        let resident: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(i, s)| ok(*i) && s.agent.is_resident(kernel_object))
            .map(|(i, _)| i)
            .collect();
        // Cost-aware refinement: among resident replicas, prefer agents
        // whose ICAP is idle — one mid-reprogram is about to take on the
        // prefetched role's traffic, and anything queued behind its
        // transfer waits. Only a tie-break: if *every* replica is
        // mid-reprogram the full set stands (never route a resident
        // kernel cold just to dodge a busy ICAP). Inert with prefetch
        // off (no background transaction ever exists).
        let ready: Vec<usize> = resident
            .iter()
            .copied()
            .filter(|&i| !self.slots[i].agent.icap_busy())
            .collect();
        let resident = if ready.is_empty() { resident } else { ready };
        if resident.is_empty() {
            // Cold kernel: prefer an agent with a free PR region (loading
            // there evicts nothing, and spreads the working set across
            // the pool); with no free region anywhere, lowest load wins.
            let free: Vec<usize> = self
                .slots
                .iter()
                .enumerate()
                .filter(|(i, s)| ok(*i) && s.agent.has_free_region())
                .map(|(i, _)| i)
                .collect();
            if !free.is_empty() {
                return self.least_loaded(|i| free.contains(&i));
            }
            return self.least_loaded(ok);
        }
        let best = self.least_loaded(|i| resident.contains(&i));
        // Replication: the kernel is hot (more queued demand than resident
        // replicas), every replica is busy, and an idle agent exists —
        // spill there; its reconfiguration loads a new replica, and
        // subsequent affinity routing spreads across both.
        let demand = self
            .demand
            .lock()
            .unwrap()
            .get(&kernel_object)
            .copied()
            .unwrap_or(0);
        let best_busy = self.slots[best].inflight.load(Ordering::Acquire) > 0;
        if best_busy && demand > resident.len() as u64 {
            let idle = self
                .slots
                .iter()
                .enumerate()
                .find(|(i, s)| {
                    ok(*i)
                        && !resident.contains(i)
                        && s.inflight.load(Ordering::Acquire) == 0
                })
                .map(|(i, _)| i);
            if let Some(i) = idle {
                return i;
            }
        }
        best
    }

    // ---- health / quarantine ----

    /// Whether slot `i` is currently quarantined.
    pub fn is_quarantined(&self, i: usize) -> bool {
        self.slots[i].quarantined.load(Ordering::Acquire)
    }

    /// Whether any slot is quarantined.
    pub fn any_quarantined(&self) -> bool {
        self.slots.iter().any(|s| s.quarantined.load(Ordering::Acquire))
    }

    /// Quarantine slot `i` (manual; `check_health` does this for killed or
    /// stalled agents, dispatch retry paths do it on agent-down errors).
    /// Returns true if the slot was newly quarantined by this call.
    pub fn quarantine(&self, i: usize) -> bool {
        let newly = !self.slots[i].quarantined.swap(true, Ordering::AcqRel);
        if newly {
            self.slots[i].quarantines.fetch_add(1, Ordering::Relaxed);
        }
        newly
    }

    /// Re-admit slot `i`. Returns true if it was quarantined.
    pub fn readmit(&self, i: usize) -> bool {
        let was = self.slots[i].quarantined.swap(false, Ordering::AcqRel);
        if was {
            self.slots[i].readmissions.fetch_add(1, Ordering::Relaxed);
        }
        was
    }

    /// Quarantine the slot whose agent carries `name` (how dispatch paths
    /// that only see an "agent down: <name>" error attribute the failure).
    pub fn quarantine_named(&self, name: &str) -> Option<usize> {
        let i = self
            .slots
            .iter()
            .position(|s| s.agent.info().name == name)?;
        self.quarantine(i);
        Some(i)
    }

    /// Account one dispatch abandoned on slot `i` and retried elsewhere.
    pub fn note_retry(&self, i: usize) {
        self.slots[i].retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Park an abandoned dispatch: its completion signal plus the guard
    /// keeping slot gauges truthful. Swept (guard dropped, slot gauge
    /// retired) when the stalled execution eventually finishes.
    pub fn park_zombie(&self, signal: Signal, guard: RouteGuard) {
        self.zombies.lock().unwrap().push((signal, guard));
    }

    fn sweep_zombies(&self) {
        let mut zombies = self.zombies.lock().unwrap();
        let before = zombies.len();
        zombies.retain(|(sig, _guard)| !sig.is_zero());
        let reaped = before - zombies.len();
        if reaped > 0 {
            self.zombies_reaped.fetch_add(reaped as u64, Ordering::Relaxed);
        }
    }

    /// Abandoned dispatches whose late completion has been observed.
    pub fn zombies_reaped(&self) -> u64 {
        self.sweep_zombies();
        self.zombies_reaped.load(Ordering::Relaxed)
    }

    /// Probe every agent and update quarantine state: a killed agent, or
    /// one with an execution stuck past `HealthPolicy::stall_threshold`,
    /// is quarantined; an agent that is alive with nothing overdue is
    /// re-admitted. Also sweeps completed zombies. Safe (and cheap) to
    /// call from any thread at any rate; dispatch harvesters call it once
    /// per probe interval while they wait.
    pub fn check_health(&self) -> HealthCheckOutcome {
        self.sweep_zombies();
        let mut outcome = HealthCheckOutcome::default();
        for i in 0..self.slots.len() {
            let agent = &self.slots[i].agent;
            let stalled = agent
                .oldest_inflight_age()
                .is_some_and(|age| age > self.health.stall_threshold);
            let healthy = agent.is_alive() && !stalled;
            if !healthy {
                if self.quarantine(i) {
                    outcome.quarantined.push(i);
                }
            } else if self.readmit(i) {
                outcome.readmitted.push(i);
            }
        }
        outcome
    }

    /// Queued-demand hint from the serving layer: `queued` requests are
    /// waiting on `kernel_object` (0 clears it). Recorded for the
    /// replication decision and forwarded to *every* agent's eviction
    /// policy — a demand-aware policy spares the role on whichever agent
    /// holds (or is about to hold) it.
    pub fn hint_demand(&self, kernel_object: u64, queued: u64) {
        {
            let mut demand = self.demand.lock().unwrap();
            if queued == 0 {
                demand.remove(&kernel_object);
            } else {
                demand.insert(kernel_object, queued);
            }
        }
        for slot in &self.slots {
            slot.agent.hint_demand(kernel_object, queued);
        }
    }

    /// Snapshot of the queued-demand table as `(kernel_object, queued)`
    /// pairs in kernel-object order — the prefetch scheduler's priority
    /// input (`PrefetchScheduler::pump_demand` sorts hottest-first).
    pub fn demand_snapshot(&self) -> Vec<(u64, u64)> {
        self.demand.lock().unwrap().iter().map(|(&k, &v)| (k, v)).collect()
    }

    /// Age every agent's queued-demand hints by one retired serving
    /// batch (see `EvictionPolicy::decay_demand`): a signature that
    /// spiked once must not stay protected from eviction forever.
    pub fn decay_demand(&self) {
        for slot in &self.slots {
            slot.agent.decay_demand();
        }
    }

    /// Dispatches currently in flight across the whole pool.
    pub fn inflight(&self) -> u64 {
        self.slots.iter().map(|s| s.inflight.load(Ordering::Acquire)).sum()
    }

    /// Per-agent accounting, in agent-index order.
    pub fn report(&self) -> Vec<ShardAgentReport> {
        self.sweep_zombies();
        self.slots
            .iter()
            .map(|s| {
                let health = s.agent.health();
                ShardAgentReport {
                    agent: s.agent.info().name.clone(),
                    dispatches: s.dispatches.load(Ordering::Relaxed),
                    inflight: s.inflight.load(Ordering::Acquire),
                    max_inflight: s.max_inflight.load(Ordering::Acquire),
                    reconfig: s.agent.reconfig_stats(),
                    quarantined: s.quarantined.load(Ordering::Acquire),
                    quarantines: s.quarantines.load(Ordering::Relaxed),
                    readmissions: s.readmissions.load(Ordering::Relaxed),
                    retries: s.retries.load(Ordering::Relaxed),
                    alive: health.alive,
                    heartbeat_age_us: health
                        .heartbeat_age
                        .map(|d| d.as_micros() as u64),
                    oldest_inflight_us: health
                        .oldest_inflight_age
                        .map_or(0, |d| d.as_micros() as u64),
                }
            })
            .collect()
    }

    /// Pooled rollup of [`Router::report`]: sums every counter (the
    /// reconfig stats accumulate field-wise); `quarantined` is true if
    /// any agent is quarantined, `alive` false if any agent is dead,
    /// `heartbeat_age_us` the freshest beat and `oldest_inflight_us` the
    /// oldest stuck execution across the pool.
    pub fn rollup(&self) -> ShardAgentReport {
        let mut total = ShardAgentReport {
            agent: "pool".to_string(),
            dispatches: 0,
            inflight: 0,
            max_inflight: 0,
            reconfig: ReconfigStats::default(),
            quarantined: false,
            quarantines: 0,
            readmissions: 0,
            retries: 0,
            alive: true,
            heartbeat_age_us: None,
            oldest_inflight_us: 0,
        };
        for r in self.report() {
            total.dispatches += r.dispatches;
            total.inflight += r.inflight;
            total.max_inflight += r.max_inflight;
            total.reconfig.accumulate(&r.reconfig);
            total.quarantined |= r.quarantined;
            total.quarantines += r.quarantines;
            total.readmissions += r.readmissions;
            total.retries += r.retries;
            total.alive &= r.alive;
            total.heartbeat_age_us = match (total.heartbeat_age_us, r.heartbeat_age_us) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            total.oldest_inflight_us = total.oldest_inflight_us.max(r.oldest_inflight_us);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::{ComputeBinding, FpgaConfig};
    use crate::fpga::roles::paper_roles;
    use crate::hsa::agent::Agent;
    use crate::hsa::packet::AqlPacket;
    use crate::hsa::signal::Signal;
    use crate::reconfig::policy::PolicyKind;
    use crate::sharding::pool::FpgaPool;
    use crate::tf::tensor::Tensor;

    fn mk_router(n: usize, strategy: ShardStrategy) -> (FpgaPool, Router, Vec<u64>) {
        let pool = FpgaPool::new(n, |i| FpgaConfig {
            num_regions: 1,
            policy: PolicyKind::Lru.build(i as u64),
            realtime: false,
            realtime_scale: 1.0,
            trace: None,
        });
        let echo = ComputeBinding::Native(std::sync::Arc::new(
            |ins: &[Tensor]| Ok(ins.to_vec()),
        ));
        let ids: Vec<u64> = paper_roles()
            .into_iter()
            .take(2)
            .map(|r| pool.register_role(r, echo.clone()))
            .collect();
        let slots = pool
            .agents()
            .iter()
            .map(|a| (std::sync::Arc::clone(a), Queue::new(8)))
            .collect();
        let router = Router::new(slots, strategy);
        (pool, router, ids)
    }

    /// Execute a dispatch on the routed agent directly (no runtime), so
    /// residency is established for affinity tests.
    fn execute_on(router: &Router, idx: usize, kernel_object: u64) {
        let x = Tensor::from_f32(&[1, 2], vec![0.5, -0.5]).unwrap();
        let (pkt, _args) = AqlPacket::dispatch(kernel_object, vec![x], Signal::new(1));
        if let AqlPacket::KernelDispatch(d) = pkt {
            router.agent(idx).execute(&d).unwrap();
        }
    }

    #[test]
    fn round_robin_cycles_across_agents() {
        let (_pool, router, ids) = mk_router(3, ShardStrategy::RoundRobin);
        let picks: Vec<usize> =
            (0..6).map(|_| router.route(ids[0]).0).collect();
        assert_eq!(picks, [0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle_agent_and_breaks_ties_low() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::LeastLoaded);
        let (first, _, g0) = router.route(ids[0]);
        assert_eq!(first, 0, "all idle: lowest index");
        let (second, _, g1) = router.route(ids[0]);
        assert_eq!(second, 1, "agent 0 busy: spill to 1");
        drop(g0);
        let (third, _, _g2) = router.route(ids[0]);
        assert_eq!(third, 0, "agent 0 retired: back to it");
        drop(g1);
    }

    #[test]
    fn guard_drop_retires_inflight() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::LeastLoaded);
        let (_, _, g) = router.route(ids[0]);
        assert_eq!(router.inflight(), 1);
        drop(g);
        assert_eq!(router.inflight(), 0);
        let rep = router.rollup();
        assert_eq!(rep.dispatches, 1);
        assert_eq!(rep.max_inflight, 1);
    }

    #[test]
    fn affinity_prefers_resident_agent() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::KernelAffinity);
        // Make the kernel resident on agent 1 only.
        execute_on(&router, 1, ids[0]);
        for _ in 0..3 {
            let (i, _, g) = router.route(ids[0]);
            assert_eq!(i, 1, "resident agent wins even though 0 is idle");
            drop(g);
        }
    }

    #[test]
    fn affinity_cold_kernel_goes_least_loaded() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::KernelAffinity);
        let (_, _, _g) = router.route(ids[1]); // busies agent 0 (cold pick)
        let (i, _, _g2) = router.route(ids[0]);
        assert_eq!(i, 1, "cold kernel avoids the busy agent");
    }

    #[test]
    fn affinity_replicates_hot_kernel_onto_idle_agent() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::KernelAffinity);
        execute_on(&router, 0, ids[0]); // resident only on agent 0
        // Replica busy + no demand: stays put (no replication).
        let (i, _, g) = router.route(ids[0]);
        assert_eq!(i, 0);
        let (j, _, g2) = router.route(ids[0]);
        assert_eq!(j, 0, "without demand hints the replica is never split");
        drop(g2);
        // Replica busy + hot demand: spill to the idle agent.
        router.hint_demand(ids[0], 8);
        let (k, _, g3) = router.route(ids[0]);
        assert_eq!(k, 1, "hot kernel replicates onto the idle agent");
        drop(g3);
        drop(g);
        // Clearing the hint returns to pure affinity.
        router.hint_demand(ids[0], 0);
        execute_on(&router, 1, ids[0]); // now resident on both
        let (l, _, _g4) = router.route(ids[0]);
        assert_eq!(l, 0, "both resident + idle: lowest index");
    }

    #[test]
    fn report_is_per_agent_and_rollup_sums() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::RoundRobin);
        let g0 = router.route(ids[0]).2;
        let g1 = router.route(ids[0]).2;
        let g2 = router.route(ids[0]).2;
        let rep = router.report();
        assert_eq!(rep.len(), 2);
        assert_eq!(rep[0].dispatches, 2);
        assert_eq!(rep[1].dispatches, 1);
        assert_eq!(router.rollup().dispatches, 3);
        assert_eq!(router.rollup().inflight, 3);
        drop((g0, g1, g2));
        assert_eq!(router.rollup().inflight, 0);
    }

    #[test]
    fn quarantine_excludes_agent_from_every_strategy() {
        for strategy in ShardStrategy::ALL {
            let (_pool, router, ids) = mk_router(3, strategy);
            assert!(router.quarantine(1), "{strategy:?}: newly quarantined");
            assert!(!router.quarantine(1), "{strategy:?}: already quarantined");
            for _ in 0..6 {
                let (i, _, g) = router.route(ids[0]);
                assert_ne!(i, 1, "{strategy:?} routed to a quarantined agent");
                drop(g);
            }
            assert!(router.is_quarantined(1) && router.any_quarantined());
            let rep = router.report();
            assert!(rep[1].quarantined && rep[1].quarantines == 1);
            assert_eq!(rep[1].dispatches, 0);
        }
    }

    #[test]
    fn all_quarantined_falls_back_to_routing_anyway() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::LeastLoaded);
        router.quarantine(0);
        router.quarantine(1);
        // Availability beats purity: the route still lands somewhere.
        let (i, _, _g) = router.route(ids[0]);
        assert_eq!(i, 0, "void mask keeps deterministic low-index pick");
    }

    #[test]
    fn round_robin_skips_quarantined_deterministically() {
        let (_pool, router, ids) = mk_router(3, ShardStrategy::RoundRobin);
        router.quarantine(1);
        let picks: Vec<usize> =
            (0..6).map(|_| router.route(ids[0]).0).collect();
        assert_eq!(picks, [0, 2, 2, 0, 2, 2], "cycle scans past slot 1");
        router.readmit(1);
        let picks: Vec<usize> =
            (0..3).map(|_| router.route(ids[0]).0).collect();
        assert_eq!(picks, [0, 1, 2], "counter position survived quarantine");
    }

    #[test]
    fn affinity_rereplicates_when_resident_agent_is_quarantined() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::KernelAffinity);
        execute_on(&router, 0, ids[0]); // resident only on agent 0
        let (i, _, g) = router.route(ids[0]);
        assert_eq!(i, 0, "resident agent preferred while healthy");
        drop(g);
        router.quarantine(0);
        // The only replica is quarantined → the kernel looks cold and
        // re-replicates onto the healthy agent.
        let (j, _, _g) = router.route(ids[0]);
        assert_eq!(j, 1, "quarantined replica ignored; healthy agent loads");
    }

    #[test]
    fn check_health_quarantines_killed_agent_and_readmits_after_revive() {
        let (_pool, router, _ids) = mk_router(2, ShardStrategy::LeastLoaded);
        assert!(router.check_health().quarantined.is_empty());
        router.agent(1).kill();
        let outcome = router.check_health();
        assert_eq!(outcome.quarantined, vec![1]);
        assert!(router.is_quarantined(1));
        let rep = router.report();
        assert!(!rep[1].alive && rep[1].quarantined);
        assert!(rep[0].alive && !rep[0].quarantined);
        router.agent(1).revive();
        let outcome = router.check_health();
        assert_eq!(outcome.readmitted, vec![1]);
        assert!(!router.any_quarantined());
        let rep = router.report();
        assert_eq!((rep[1].quarantines, rep[1].readmissions), (1, 1));
    }

    #[test]
    fn quarantine_named_attributes_by_agent_name() {
        let (_pool, router, _ids) = mk_router(3, ShardStrategy::RoundRobin);
        let name = router.agent(2).info().name.clone();
        assert_eq!(router.quarantine_named(&name), Some(2));
        assert!(router.is_quarantined(2));
        assert_eq!(router.quarantine_named("no-such-agent"), None);
    }

    #[test]
    fn parked_zombie_holds_gauge_until_completion() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::LeastLoaded);
        let (i, _, g) = router.route(ids[0]);
        let sig = Signal::new(1);
        router.note_retry(i);
        router.park_zombie(sig.clone(), g);
        assert_eq!(router.inflight(), 1, "zombie still occupies the gauge");
        assert_eq!(router.zombies_reaped(), 0);
        sig.subtract(1); // the stalled execution finally retires
        assert_eq!(router.zombies_reaped(), 1);
        assert_eq!(router.inflight(), 0, "sweep dropped the guard");
        assert_eq!(router.report()[i].retries, 1);
    }

    #[test]
    fn rollup_sums_health_counters() {
        let (_pool, router, _ids) = mk_router(2, ShardStrategy::RoundRobin);
        router.quarantine(0);
        router.note_retry(0);
        router.note_retry(1);
        let total = router.rollup();
        assert!(total.quarantined);
        assert_eq!(total.quarantines, 1);
        assert_eq!(total.retries, 2);
        assert!(total.alive);
        router.readmit(0);
        let total = router.rollup();
        assert!(!total.quarantined);
        assert_eq!(total.readmissions, 1);
    }

    #[test]
    fn strategy_parse_round_trip() {
        for s in ShardStrategy::ALL {
            assert_eq!(ShardStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(ShardStrategy::parse("zipf"), None);
    }

    /// Like `mk_router` but with two PR regions per agent, so an agent
    /// can host a resident role *and* stream a background prefetch.
    fn mk_router2(n: usize, strategy: ShardStrategy) -> (FpgaPool, Router, Vec<u64>) {
        let pool = FpgaPool::new(n, |i| FpgaConfig {
            num_regions: 2,
            policy: PolicyKind::Lru.build(i as u64),
            realtime: false,
            realtime_scale: 1.0,
            trace: None,
        });
        let echo = ComputeBinding::Native(std::sync::Arc::new(
            |ins: &[Tensor]| Ok(ins.to_vec()),
        ));
        let ids: Vec<u64> = paper_roles()
            .into_iter()
            .take(3)
            .map(|r| pool.register_role(r, echo.clone()))
            .collect();
        let slots = pool
            .agents()
            .iter()
            .map(|a| (std::sync::Arc::clone(a), Queue::new(8)))
            .collect();
        let router = Router::new(slots, strategy);
        (pool, router, ids)
    }

    #[test]
    fn least_loaded_breaks_ties_away_from_busy_icap() {
        use crate::reconfig::scheduler::Prefetch;
        let (_pool, router, ids) = mk_router2(2, ShardStrategy::LeastLoaded);
        assert!(matches!(
            router.agent(0).try_prefetch(ids[1], &[], 0, 0),
            Prefetch::Started { .. }
        ));
        assert!(router.agent(0).icap_busy());
        let (i, _, _g) = router.route(ids[0]);
        assert_eq!(i, 1, "equal load: the idle ICAP wins the tie");
    }

    #[test]
    fn affinity_avoids_resident_replica_mid_reprogram() {
        use crate::reconfig::scheduler::{CostClass, Prefetch};
        let (_pool, router, ids) = mk_router2(2, ShardStrategy::KernelAffinity);
        execute_on(&router, 0, ids[0]);
        execute_on(&router, 1, ids[0]); // resident on both agents
        let (i, _, g) = router.route(ids[0]);
        assert_eq!(i, 0, "both replicas idle: lowest index");
        drop(g);
        // Agent 0 starts streaming a different role in the background.
        assert!(matches!(
            router.agent(0).try_prefetch(ids[2], &[], 0, 0),
            Prefetch::Started { .. }
        ));
        assert_eq!(router.agent(0).reconfig_cost(ids[1]), CostClass::IcapBusy);
        assert_eq!(
            router.agent(0).reconfig_cost(ids[0]),
            CostClass::Resident,
            "already-resident roles are unaffected by the transfer"
        );
        let (j, _, g2) = router.route(ids[0]);
        assert_eq!(j, 1, "replica mid-reprogram loses to the idle replica");
        drop(g2);
        // The sole replica mid-reprogram still beats going cold.
        let (_pool2, solo, ids2) = mk_router2(2, ShardStrategy::KernelAffinity);
        execute_on(&solo, 0, ids2[0]);
        assert!(matches!(
            solo.agent(0).try_prefetch(ids2[2], &[], 0, 0),
            Prefetch::Started { .. }
        ));
        let (k, _, _g3) = solo.route(ids2[0]);
        assert_eq!(k, 0, "never route a resident kernel cold to dodge the ICAP");
    }

    #[test]
    fn demand_snapshot_orders_by_kernel_object() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::KernelAffinity);
        router.hint_demand(ids[1], 7);
        router.hint_demand(ids[0], 3);
        let mut expect = vec![(ids[0], 3), (ids[1], 7)];
        expect.sort();
        assert_eq!(router.demand_snapshot(), expect);
        router.hint_demand(ids[1], 0);
        assert_eq!(router.demand_snapshot(), vec![(ids[0], 3)]);
        router.decay_demand(); // demand-blind Lru agents: a quiet no-op
        assert_eq!(router.demand_snapshot(), vec![(ids[0], 3)]);
    }
}
