//! Replica-aware, load-balanced routing of FPGA dispatches.
//!
//! The router owns one *slot* per pool agent — the agent handle, its AQL
//! queue and a trio of counters (in-flight gauge, total dispatches,
//! in-flight high-water mark). [`Router::route`] picks a slot for a
//! kernel object and returns the slot's queue plus a [`RouteGuard`] whose
//! `Drop` retires the dispatch from the gauge, so callers need no
//! completion callbacks: hold the guard until the kernel's result is
//! harvested and load balancing stays truthful.
//!
//! Strategy selection is **deterministic**: every tie breaks toward the
//! lowest agent index, and the only inputs are the router's own counters,
//! the agents' residency maps and the demand table — all of which are
//! pure functions of the call sequence. Two routers fed the same sequence
//! of `route`/guard-drop/`hint_demand` calls make identical choices
//! (property-tested in `tests/prop_invariants.rs`).

use crate::fpga::device::FpgaAgent;
use crate::hsa::agent::Agent;
use crate::hsa::queue::Queue;
use crate::reconfig::manager::ReconfigStats;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// How the router assigns dispatches to pool agents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Cyclic assignment, blind to load and residency. Cheapest decision
    /// (one atomic increment) and perfectly even over any window that is
    /// a multiple of the pool size — but it reconfigures freely, so a
    /// working set larger than one agent's PR regions thrashes. The
    /// baseline the other strategies are measured against.
    RoundRobin,
    /// Lowest in-flight count wins, ties to the lowest agent index. Best
    /// when kernels are uniform (any agent serves any dispatch equally
    /// well) and batch runtimes vary; ignores bitstream residency, so it
    /// shares `RoundRobin`'s thrashing behaviour for large working sets.
    LeastLoaded,
    /// Residency-first routing: prefer agents already holding the
    /// kernel's bitstream in a PR region (dispatching there reconfigures
    /// nothing). A *cold* kernel is placed on an agent with a free region
    /// when one exists — loading there evicts nothing and spreads the
    /// working set — otherwise on the least-loaded agent. A *hot* kernel
    /// (queued demand from [`Router::hint_demand`] exceeding its replica
    /// count while every replica is busy) spills onto an idle agent,
    /// whose reconfiguration creates a new replica that later affinity
    /// decisions spread load across. The default for serving.
    KernelAffinity,
}

impl ShardStrategy {
    pub fn name(self) -> &'static str {
        match self {
            ShardStrategy::RoundRobin => "round-robin",
            ShardStrategy::LeastLoaded => "least-loaded",
            ShardStrategy::KernelAffinity => "kernel-affinity",
        }
    }

    pub fn parse(s: &str) -> Option<ShardStrategy> {
        match s {
            "round-robin" => Some(ShardStrategy::RoundRobin),
            "least-loaded" => Some(ShardStrategy::LeastLoaded),
            "kernel-affinity" => Some(ShardStrategy::KernelAffinity),
            _ => None,
        }
    }

    pub const ALL: [ShardStrategy; 3] = [
        ShardStrategy::RoundRobin,
        ShardStrategy::LeastLoaded,
        ShardStrategy::KernelAffinity,
    ];
}

struct Slot {
    agent: Arc<FpgaAgent>,
    queue: Queue,
    inflight: Arc<AtomicU64>,
    dispatches: AtomicU64,
    max_inflight: AtomicU64,
}

/// Retires one routed dispatch from its agent's in-flight gauge on drop.
///
/// Lifecycle: [`Router::route`] increments the chosen slot's gauge and
/// hands the guard to whoever owns the dispatch — plan replay holds it in
/// the in-flight ring until the step's completion signal fires;
/// `Session::run_async` moves it into the returned `PendingRun`, so the
/// gauge retires when the caller harvests (or drops) the pending result.
/// Hold the guard for exactly as long as the dispatch occupies the agent:
/// dropping early under-reports load (least-loaded routing over-commits
/// the agent), leaking it pins the agent "busy" forever. The guard only
/// touches the shared gauge, so it is `Send` and may drop on any thread.
#[derive(Debug)]
pub struct RouteGuard {
    inflight: Arc<AtomicU64>,
}

impl Drop for RouteGuard {
    fn drop(&mut self) {
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Point-in-time accounting of one pool agent (see [`Router::report`]).
#[derive(Debug, Clone)]
pub struct ShardAgentReport {
    pub agent: String,
    /// Dispatches routed to this agent.
    pub dispatches: u64,
    /// Dispatches routed but not yet retired.
    pub inflight: u64,
    /// High-water mark of concurrently in-flight dispatches.
    pub max_inflight: u64,
    /// The agent's own reconfiguration accounting.
    pub reconfig: ReconfigStats,
}

/// Routes FPGA dispatches across a pool of agents.
pub struct Router {
    slots: Vec<Slot>,
    strategy: ShardStrategy,
    rr_next: AtomicUsize,
    /// Latest queued-demand hint per kernel object (serving queue depths),
    /// consulted by `KernelAffinity` to decide replication. Ordered map so
    /// iteration/debug output is deterministic.
    demand: Mutex<BTreeMap<u64, u64>>,
}

impl Router {
    /// Build a router over `(agent, queue)` pairs — one AQL queue per
    /// agent, created by the caller on the shared runtime.
    pub fn new(
        slots: Vec<(Arc<FpgaAgent>, Queue)>,
        strategy: ShardStrategy,
    ) -> Router {
        assert!(!slots.is_empty(), "router needs at least one agent");
        Router {
            slots: slots
                .into_iter()
                .map(|(agent, queue)| Slot {
                    agent,
                    queue,
                    inflight: Arc::new(AtomicU64::new(0)),
                    dispatches: AtomicU64::new(0),
                    max_inflight: AtomicU64::new(0),
                })
                .collect(),
            strategy,
            rr_next: AtomicUsize::new(0),
            demand: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn strategy(&self) -> ShardStrategy {
        self.strategy
    }

    pub fn agent(&self, i: usize) -> &Arc<FpgaAgent> {
        &self.slots[i].agent
    }

    pub fn agents(&self) -> impl Iterator<Item = &Arc<FpgaAgent>> {
        self.slots.iter().map(|s| &s.agent)
    }

    /// Pick an agent for `kernel_object` and account the dispatch.
    /// Returns the chosen index, a clone of its queue, and the guard that
    /// retires the dispatch when dropped.
    pub fn route(&self, kernel_object: u64) -> (usize, Queue, RouteGuard) {
        let i = self.pick(kernel_object);
        let slot = &self.slots[i];
        slot.dispatches.fetch_add(1, Ordering::Relaxed);
        let now = slot.inflight.fetch_add(1, Ordering::AcqRel) + 1;
        slot.max_inflight.fetch_max(now, Ordering::AcqRel);
        (
            i,
            slot.queue.clone(),
            RouteGuard { inflight: Arc::clone(&slot.inflight) },
        )
    }

    fn pick(&self, kernel_object: u64) -> usize {
        match self.strategy {
            ShardStrategy::RoundRobin => {
                self.rr_next.fetch_add(1, Ordering::Relaxed) % self.slots.len()
            }
            ShardStrategy::LeastLoaded => self.least_loaded(|_| true),
            ShardStrategy::KernelAffinity => self.pick_affinity(kernel_object),
        }
    }

    /// Index of the least-loaded slot among those passing `eligible`
    /// (lowest index on ties). `eligible` must accept at least one slot.
    fn least_loaded(&self, eligible: impl Fn(usize) -> bool) -> usize {
        self.slots
            .iter()
            .enumerate()
            .filter(|(i, _)| eligible(*i))
            .min_by_key(|(i, s)| (s.inflight.load(Ordering::Acquire), *i))
            .map(|(i, _)| i)
            .expect("least_loaded over empty eligible set")
    }

    fn pick_affinity(&self, kernel_object: u64) -> usize {
        let resident: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.agent.is_resident(kernel_object))
            .map(|(i, _)| i)
            .collect();
        if resident.is_empty() {
            // Cold kernel: prefer an agent with a free PR region (loading
            // there evicts nothing, and spreads the working set across
            // the pool); with no free region anywhere, lowest load wins.
            let free: Vec<usize> = self
                .slots
                .iter()
                .enumerate()
                .filter(|(_, s)| s.agent.has_free_region())
                .map(|(i, _)| i)
                .collect();
            if !free.is_empty() {
                return self.least_loaded(|i| free.contains(&i));
            }
            return self.least_loaded(|_| true);
        }
        let best = self.least_loaded(|i| resident.contains(&i));
        // Replication: the kernel is hot (more queued demand than resident
        // replicas), every replica is busy, and an idle agent exists —
        // spill there; its reconfiguration loads a new replica, and
        // subsequent affinity routing spreads across both.
        let demand = self
            .demand
            .lock()
            .unwrap()
            .get(&kernel_object)
            .copied()
            .unwrap_or(0);
        let best_busy = self.slots[best].inflight.load(Ordering::Acquire) > 0;
        if best_busy && demand > resident.len() as u64 {
            let idle = self
                .slots
                .iter()
                .enumerate()
                .find(|(i, s)| {
                    !resident.contains(i) && s.inflight.load(Ordering::Acquire) == 0
                })
                .map(|(i, _)| i);
            if let Some(i) = idle {
                return i;
            }
        }
        best
    }

    /// Queued-demand hint from the serving layer: `queued` requests are
    /// waiting on `kernel_object` (0 clears it). Recorded for the
    /// replication decision and forwarded to *every* agent's eviction
    /// policy — a demand-aware policy spares the role on whichever agent
    /// holds (or is about to hold) it.
    pub fn hint_demand(&self, kernel_object: u64, queued: u64) {
        {
            let mut demand = self.demand.lock().unwrap();
            if queued == 0 {
                demand.remove(&kernel_object);
            } else {
                demand.insert(kernel_object, queued);
            }
        }
        for slot in &self.slots {
            slot.agent.hint_demand(kernel_object, queued);
        }
    }

    /// Dispatches currently in flight across the whole pool.
    pub fn inflight(&self) -> u64 {
        self.slots.iter().map(|s| s.inflight.load(Ordering::Acquire)).sum()
    }

    /// Per-agent accounting, in agent-index order.
    pub fn report(&self) -> Vec<ShardAgentReport> {
        self.slots
            .iter()
            .map(|s| ShardAgentReport {
                agent: s.agent.info().name.clone(),
                dispatches: s.dispatches.load(Ordering::Relaxed),
                inflight: s.inflight.load(Ordering::Acquire),
                max_inflight: s.max_inflight.load(Ordering::Acquire),
                reconfig: s.agent.reconfig_stats(),
            })
            .collect()
    }

    /// Pooled rollup of [`Router::report`]: sums every counter (the
    /// reconfig stats accumulate field-wise).
    pub fn rollup(&self) -> ShardAgentReport {
        let mut total = ShardAgentReport {
            agent: "pool".to_string(),
            dispatches: 0,
            inflight: 0,
            max_inflight: 0,
            reconfig: ReconfigStats::default(),
        };
        for r in self.report() {
            total.dispatches += r.dispatches;
            total.inflight += r.inflight;
            total.max_inflight += r.max_inflight;
            total.reconfig.accumulate(&r.reconfig);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::{ComputeBinding, FpgaConfig};
    use crate::fpga::roles::paper_roles;
    use crate::hsa::agent::Agent;
    use crate::hsa::packet::AqlPacket;
    use crate::hsa::signal::Signal;
    use crate::reconfig::policy::PolicyKind;
    use crate::sharding::pool::FpgaPool;
    use crate::tf::tensor::Tensor;

    fn mk_router(n: usize, strategy: ShardStrategy) -> (FpgaPool, Router, Vec<u64>) {
        let pool = FpgaPool::new(n, |i| FpgaConfig {
            num_regions: 1,
            policy: PolicyKind::Lru.build(i as u64),
            realtime: false,
            realtime_scale: 1.0,
            trace: None,
        });
        let echo = ComputeBinding::Native(std::sync::Arc::new(
            |ins: &[Tensor]| Ok(ins.to_vec()),
        ));
        let ids: Vec<u64> = paper_roles()
            .into_iter()
            .take(2)
            .map(|r| pool.register_role(r, echo.clone()))
            .collect();
        let slots = pool
            .agents()
            .iter()
            .map(|a| (std::sync::Arc::clone(a), Queue::new(8)))
            .collect();
        let router = Router::new(slots, strategy);
        (pool, router, ids)
    }

    /// Execute a dispatch on the routed agent directly (no runtime), so
    /// residency is established for affinity tests.
    fn execute_on(router: &Router, idx: usize, kernel_object: u64) {
        let x = Tensor::from_f32(&[1, 2], vec![0.5, -0.5]).unwrap();
        let (pkt, _args) = AqlPacket::dispatch(kernel_object, vec![x], Signal::new(1));
        if let AqlPacket::KernelDispatch(d) = pkt {
            router.agent(idx).execute(&d).unwrap();
        }
    }

    #[test]
    fn round_robin_cycles_across_agents() {
        let (_pool, router, ids) = mk_router(3, ShardStrategy::RoundRobin);
        let picks: Vec<usize> =
            (0..6).map(|_| router.route(ids[0]).0).collect();
        assert_eq!(picks, [0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle_agent_and_breaks_ties_low() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::LeastLoaded);
        let (first, _, g0) = router.route(ids[0]);
        assert_eq!(first, 0, "all idle: lowest index");
        let (second, _, g1) = router.route(ids[0]);
        assert_eq!(second, 1, "agent 0 busy: spill to 1");
        drop(g0);
        let (third, _, _g2) = router.route(ids[0]);
        assert_eq!(third, 0, "agent 0 retired: back to it");
        drop(g1);
    }

    #[test]
    fn guard_drop_retires_inflight() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::LeastLoaded);
        let (_, _, g) = router.route(ids[0]);
        assert_eq!(router.inflight(), 1);
        drop(g);
        assert_eq!(router.inflight(), 0);
        let rep = router.rollup();
        assert_eq!(rep.dispatches, 1);
        assert_eq!(rep.max_inflight, 1);
    }

    #[test]
    fn affinity_prefers_resident_agent() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::KernelAffinity);
        // Make the kernel resident on agent 1 only.
        execute_on(&router, 1, ids[0]);
        for _ in 0..3 {
            let (i, _, g) = router.route(ids[0]);
            assert_eq!(i, 1, "resident agent wins even though 0 is idle");
            drop(g);
        }
    }

    #[test]
    fn affinity_cold_kernel_goes_least_loaded() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::KernelAffinity);
        let (_, _, _g) = router.route(ids[1]); // busies agent 0 (cold pick)
        let (i, _, _g2) = router.route(ids[0]);
        assert_eq!(i, 1, "cold kernel avoids the busy agent");
    }

    #[test]
    fn affinity_replicates_hot_kernel_onto_idle_agent() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::KernelAffinity);
        execute_on(&router, 0, ids[0]); // resident only on agent 0
        // Replica busy + no demand: stays put (no replication).
        let (i, _, g) = router.route(ids[0]);
        assert_eq!(i, 0);
        let (j, _, g2) = router.route(ids[0]);
        assert_eq!(j, 0, "without demand hints the replica is never split");
        drop(g2);
        // Replica busy + hot demand: spill to the idle agent.
        router.hint_demand(ids[0], 8);
        let (k, _, g3) = router.route(ids[0]);
        assert_eq!(k, 1, "hot kernel replicates onto the idle agent");
        drop(g3);
        drop(g);
        // Clearing the hint returns to pure affinity.
        router.hint_demand(ids[0], 0);
        execute_on(&router, 1, ids[0]); // now resident on both
        let (l, _, _g4) = router.route(ids[0]);
        assert_eq!(l, 0, "both resident + idle: lowest index");
    }

    #[test]
    fn report_is_per_agent_and_rollup_sums() {
        let (_pool, router, ids) = mk_router(2, ShardStrategy::RoundRobin);
        let g0 = router.route(ids[0]).2;
        let g1 = router.route(ids[0]).2;
        let g2 = router.route(ids[0]).2;
        let rep = router.report();
        assert_eq!(rep.len(), 2);
        assert_eq!(rep[0].dispatches, 2);
        assert_eq!(rep[1].dispatches, 1);
        assert_eq!(router.rollup().dispatches, 3);
        assert_eq!(router.rollup().inflight, 3);
        drop((g0, g1, g2));
        assert_eq!(router.rollup().inflight, 0);
    }

    #[test]
    fn strategy_parse_round_trip() {
        for s in ShardStrategy::ALL {
            assert_eq!(ShardStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(ShardStrategy::parse("zipf"), None);
    }
}
