//! A pool of independent FPGA agents sharing one kernel namespace.
//!
//! Each member is a full [`FpgaAgent`]: its own PR regions, ICAP timing
//! model, eviction policy and reconfiguration statistics. The pool's one
//! job is to keep the *kernel-object ids identical across members*: a role
//! registered through [`FpgaPool::register_role`] is cloned onto every
//! agent under the same [`crate::fpga::bitstream::RoleId`], so placement,
//! compiled plans and the kernel registry never need to know how many
//! agents exist — only the [`super::Router`] does.

use crate::fpga::device::{ComputeBinding, FpgaAgent, FpgaConfig};
use crate::fpga::bitstream::Bitstream;
use std::sync::Arc;

/// N independent FPGA agents with a shared role namespace.
///
/// Usually constructed for you via
/// [`SessionOptions::fpga_pool`](crate::tf::session::SessionOptions);
/// build one directly when wiring a custom runtime:
///
/// ```
/// use tf_fpga::fpga::device::{ComputeBinding, FpgaConfig};
/// use tf_fpga::fpga::roles::paper_roles;
/// use tf_fpga::reconfig::policy::PolicyKind;
/// use tf_fpga::sharding::FpgaPool;
/// use tf_fpga::tf::tensor::Tensor;
/// use std::sync::Arc;
///
/// // Two agents, each with its own 2-region PR fabric and LRU policy.
/// let pool = FpgaPool::new(2, |i| FpgaConfig {
///     num_regions: 2,
///     policy: PolicyKind::Lru.build(i as u64),
///     ..FpgaConfig::default()
/// });
/// assert_eq!(pool.len(), 2);
///
/// // One registration covers every member under the same kernel id, so
/// // compiled plans stay valid wherever the router sends them.
/// let echo = ComputeBinding::Native(Arc::new(|ins: &[Tensor]| Ok(ins.to_vec())));
/// let kernel = pool.register_role(paper_roles().remove(0), echo);
/// assert!(pool.agents().iter().all(|a| !a.is_resident(kernel)),
///         "registration alone reconfigures nothing");
/// ```
pub struct FpgaPool {
    agents: Vec<Arc<FpgaAgent>>,
}

impl FpgaPool {
    /// Build a pool of `n` agents (at least one). `config` is called once
    /// per agent with the agent index, so each member gets its own
    /// eviction policy instance (policies are stateful) and, when wanted,
    /// a per-agent seed. Agents are named `ultra96-pl-<i>`; a pool of one
    /// keeps the historical name `ultra96-pl`.
    pub fn new(n: usize, mut config: impl FnMut(usize) -> FpgaConfig) -> FpgaPool {
        let n = n.max(1);
        let agents = (0..n)
            .map(|i| {
                let name = if n == 1 {
                    "ultra96-pl".to_string()
                } else {
                    format!("ultra96-pl-{i}")
                };
                FpgaAgent::new_named(config(i), name)
            })
            .collect();
        FpgaPool { agents }
    }

    /// Number of agents in the pool (≥ 1).
    pub fn len(&self) -> usize {
        self.agents.len()
    }

    /// Never true — `new` clamps to at least one agent — but provided for
    /// the `len`/`is_empty` convention.
    pub fn is_empty(&self) -> bool {
        self.agents.is_empty()
    }

    /// All members in index order (the order routing ties break toward).
    pub fn agents(&self) -> &[Arc<FpgaAgent>] {
        &self.agents
    }

    /// Member `i`. Panics when out of range, like slice indexing.
    pub fn agent(&self, i: usize) -> &Arc<FpgaAgent> {
        &self.agents[i]
    }

    /// Register `bitstream` as a dispatchable kernel on **every** agent.
    /// All members receive a clone carrying the same `RoleId`, so the
    /// returned kernel object resolves on whichever agent the router
    /// picks. The binding is cloned per agent (bindings are `Arc`-backed).
    pub fn register_role(&self, bitstream: Bitstream, binding: ComputeBinding) -> u64 {
        let id = bitstream.id.0;
        for agent in &self.agents {
            agent.register_role(bitstream.clone(), binding.clone());
        }
        id
    }

    /// How many pool members currently hold `kernel_object` in a PR
    /// region. The prefetch scheduler skips roles with at least one
    /// replica; benches use the count to check replication spread.
    pub fn resident_replicas(&self, kernel_object: u64) -> usize {
        self.agents.iter().filter(|a| a.is_resident(kernel_object)).count()
    }

    /// Age every member's queued-demand hints by one retired batch (see
    /// `EvictionPolicy::decay_demand`). Custom runtimes wired without a
    /// [`super::Router`] call this directly; sessions go through
    /// `Router::decay_demand`.
    pub fn decay_demand(&self) {
        for agent in &self.agents {
            agent.decay_demand();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::roles::paper_roles;
    use crate::hsa::agent::Agent;
    use crate::reconfig::policy::PolicyKind;
    use crate::tf::tensor::Tensor;

    fn config(seed: u64) -> FpgaConfig {
        FpgaConfig {
            num_regions: 2,
            policy: PolicyKind::Lru.build(seed),
            realtime: false,
            realtime_scale: 1.0,
            trace: None,
        }
    }

    fn echo() -> ComputeBinding {
        ComputeBinding::Native(Arc::new(|ins: &[Tensor]| Ok(ins.to_vec())))
    }

    #[test]
    fn pool_members_are_independent_agents_with_distinct_names() {
        let pool = FpgaPool::new(3, |i| config(i as u64));
        assert_eq!(pool.len(), 3);
        let names: Vec<_> =
            pool.agents().iter().map(|a| a.info().name.clone()).collect();
        assert_eq!(names, ["ultra96-pl-0", "ultra96-pl-1", "ultra96-pl-2"]);
    }

    #[test]
    fn single_agent_pool_keeps_historical_name() {
        let pool = FpgaPool::new(1, |i| config(i as u64));
        assert_eq!(pool.agent(0).info().name, "ultra96-pl");
    }

    #[test]
    fn zero_is_clamped_to_one_agent() {
        assert_eq!(FpgaPool::new(0, |i| config(i as u64)).len(), 1);
    }

    #[test]
    fn register_role_shares_one_kernel_object_across_agents() {
        let pool = FpgaPool::new(2, |i| config(i as u64));
        let role = paper_roles().remove(0);
        let want = role.id.0;
        let got = pool.register_role(role, echo());
        assert_eq!(got, want);
        // Both agents resolve the id: dispatching marks residency on
        // exactly the agent that executed, not its peers.
        for agent in pool.agents() {
            assert!(!agent.is_resident(got), "nothing dispatched yet");
        }
    }

    #[test]
    fn reconfig_state_is_per_agent() {
        use crate::hsa::packet::AqlPacket;
        use crate::hsa::signal::Signal;
        let pool = FpgaPool::new(2, |i| config(i as u64));
        let id = pool.register_role(paper_roles().remove(0), echo());
        let x = Tensor::from_f32(&[1, 2], vec![1.0, 2.0]).unwrap();
        let (pkt, _args) = AqlPacket::dispatch(id, vec![x], Signal::new(1));
        if let AqlPacket::KernelDispatch(d) = pkt {
            pool.agent(0).execute(&d).unwrap();
        }
        assert!(pool.agent(0).is_resident(id), "executor agent holds the role");
        assert!(!pool.agent(1).is_resident(id), "peer agent untouched");
        assert_eq!(pool.agent(0).reconfig_stats().misses, 1);
        assert_eq!(pool.agent(1).reconfig_stats().dispatches, 0);
    }

    #[test]
    fn resident_replicas_counts_only_agents_holding_the_role() {
        use crate::hsa::packet::AqlPacket;
        use crate::hsa::signal::Signal;
        let pool = FpgaPool::new(3, |i| config(i as u64));
        let id = pool.register_role(paper_roles().remove(0), echo());
        assert_eq!(pool.resident_replicas(id), 0);
        let x = Tensor::from_f32(&[1, 2], vec![1.0, 2.0]).unwrap();
        for agent in &pool.agents()[..2] {
            let (pkt, _args) =
                AqlPacket::dispatch(id, vec![x.clone()], Signal::new(1));
            if let AqlPacket::KernelDispatch(d) = pkt {
                agent.execute(&d).unwrap();
            }
        }
        assert_eq!(pool.resident_replicas(id), 2);
        assert_eq!(pool.resident_replicas(0xDEAD_BEEF), 0, "unknown kernel");
        // Demand decay broadcast is a no-op for demand-blind LRU members.
        pool.decay_demand();
        assert_eq!(pool.resident_replicas(id), 2);
    }
}
