//! Device placement.
//!
//! Explicit annotations (`graph.set_device`, the paper's code annotation)
//! are honored when a kernel exists for that device; otherwise placement
//! fails — unless soft placement is on, in which case the node falls back
//! to the best available device with a warning flag, exactly TF's
//! `allow_soft_placement` semantics. Unannotated compute nodes take the
//! registry's preference order (FPGA first when implemented).

use crate::hsa::agent::DeviceType;
use crate::hsa::error::{HsaError, Result};
use crate::tf::graph::{Graph, NodeId};
use crate::tf::kernel::KernelRegistry;
use std::collections::HashMap;

/// Placement decision per node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Structural op, executes inline in the executor.
    Inline,
    /// Dispatch to this device's queue with this kernel object.
    Device { device: DeviceType, kernel_object: u64 },
}

/// Placement options.
#[derive(Debug, Clone, Copy)]
pub struct PlacerOptions {
    /// Fall back when an explicit annotation cannot be satisfied.
    pub allow_soft_placement: bool,
    /// Default preference: place on FPGA when available.
    pub prefer_fpga: bool,
}

impl Default for PlacerOptions {
    fn default() -> Self {
        PlacerOptions { allow_soft_placement: true, prefer_fpga: true }
    }
}

/// Result of placing a graph.
#[derive(Debug, Clone)]
pub struct PlacementMap {
    pub by_node: HashMap<NodeId, Placement>,
    /// Nodes whose explicit annotation was soft-overridden.
    pub soft_placed: Vec<NodeId>,
}

impl PlacementMap {
    pub fn device_of(&self, id: NodeId) -> Option<DeviceType> {
        match self.by_node.get(&id) {
            Some(Placement::Device { device, .. }) => Some(*device),
            _ => None,
        }
    }
}

/// Place every node of a finalized graph.
pub fn place(
    graph: &Graph,
    registry: &KernelRegistry,
    opts: PlacerOptions,
) -> Result<PlacementMap> {
    let mut by_node = HashMap::new();
    let mut soft_placed = Vec::new();

    for node in graph.nodes() {
        let Some(kernel) = node.op.kernel_name() else {
            by_node.insert(node.id, Placement::Inline);
            continue;
        };

        let placement = match node.device {
            Some(want) => match registry.lookup(&kernel, want) {
                Some(obj) => Placement::Device { device: want, kernel_object: obj },
                None if opts.allow_soft_placement => {
                    let fallback = pick_default(registry, &kernel, opts).ok_or_else(|| {
                        HsaError::Runtime(format!(
                            "node '{}': kernel '{kernel}' implemented nowhere",
                            node.name
                        ))
                    })?;
                    soft_placed.push(node.id);
                    fallback
                }
                None => {
                    return Err(HsaError::Runtime(format!(
                        "node '{}': kernel '{kernel}' not registered for {want} \
                         (soft placement disabled)",
                        node.name
                    )))
                }
            },
            None => pick_default(registry, &kernel, opts).ok_or_else(|| {
                HsaError::Runtime(format!(
                    "node '{}': kernel '{kernel}' implemented nowhere",
                    node.name
                ))
            })?,
        };
        by_node.insert(node.id, placement);
    }

    Ok(PlacementMap { by_node, soft_placed })
}

/// Total preference order over devices. Fully deterministic — rank first,
/// then the `DeviceType` ordering as tie-break — so default placement (and
/// therefore plan-cache keys derived from it) is reproducible run to run
/// regardless of registry iteration or sort-stability details.
fn device_rank(d: DeviceType, prefer_fpga: bool) -> u8 {
    if prefer_fpga {
        match d {
            DeviceType::Fpga => 0,
            DeviceType::Gpu => 1,
            DeviceType::Dsp => 2,
            DeviceType::Cpu => 3,
        }
    } else {
        // CPU-first order (the paper's Table III baseline runs).
        match d {
            DeviceType::Cpu => 0,
            DeviceType::Fpga => 1,
            DeviceType::Gpu => 2,
            DeviceType::Dsp => 3,
        }
    }
}

fn pick_default(
    registry: &KernelRegistry,
    kernel: &str,
    opts: PlacerOptions,
) -> Option<Placement> {
    let mut order = registry.devices_for(kernel);
    order.sort_by_key(|d| (device_rank(*d, opts.prefer_fpga), *d));
    let device = *order.first()?;
    let obj = registry.lookup(kernel, device)?;
    Some(Placement::Device { device, kernel_object: obj })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tf::dtype::DType;
    use crate::tf::graph::OpKind;
    use crate::tf::tensor::Tensor;

    fn graph_and_registry() -> (Graph, KernelRegistry, NodeId, NodeId) {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[4, 8], DType::F32).unwrap();
        let w = g.constant("w", Tensor::zeros(&[8, 2], DType::F32)).unwrap();
        let b = g.constant("b", Tensor::zeros(&[2], DType::F32)).unwrap();
        let y = g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
        let r = g.add("r", OpKind::Relu, &[y]).unwrap();
        g.finalize().unwrap();
        let mut reg = KernelRegistry::new();
        reg.register("fc", DeviceType::Cpu, 1);
        reg.register("fc", DeviceType::Fpga, 2);
        reg.register("relu", DeviceType::Cpu, 3);
        (g, reg, y, r)
    }

    #[test]
    fn default_prefers_fpga() {
        let (g, reg, y, r) = graph_and_registry();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        assert_eq!(p.device_of(y), Some(DeviceType::Fpga));
        assert_eq!(p.device_of(r), Some(DeviceType::Cpu), "relu is CPU-only");
        assert!(p.soft_placed.is_empty());
    }

    #[test]
    fn cpu_first_when_not_preferring_fpga() {
        let (g, reg, y, _) = graph_and_registry();
        let p = place(
            &g,
            &reg,
            PlacerOptions { prefer_fpga: false, allow_soft_placement: true },
        )
        .unwrap();
        assert_eq!(p.device_of(y), Some(DeviceType::Cpu));
    }

    #[test]
    fn default_placement_is_deterministic_across_repeats() {
        // Same kernel on every device: the pick must be identical on every
        // call in both preference modes (plan-cache keys depend on it).
        let mut g = Graph::new();
        let x = g.placeholder("x", &[2, 2], DType::F32).unwrap();
        let w = g.constant("w", Tensor::zeros(&[2, 2], DType::F32)).unwrap();
        let b = g.constant("b", Tensor::zeros(&[2], DType::F32)).unwrap();
        let y = g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
        g.finalize().unwrap();
        let mut reg = KernelRegistry::new();
        for (i, d) in [DeviceType::Cpu, DeviceType::Fpga, DeviceType::Gpu, DeviceType::Dsp]
            .into_iter()
            .enumerate()
        {
            reg.register("fc", d, i as u64 + 1);
        }
        for prefer_fpga in [true, false] {
            let opts = PlacerOptions { prefer_fpga, allow_soft_placement: true };
            let first = place(&g, &reg, opts).unwrap().device_of(y);
            for _ in 0..10 {
                assert_eq!(place(&g, &reg, opts).unwrap().device_of(y), first);
            }
            let want = if prefer_fpga { DeviceType::Fpga } else { DeviceType::Cpu };
            assert_eq!(first, Some(want));
        }
        // Rank tie (neither CPU nor FPGA): DeviceType order breaks the tie.
        let mut reg2 = KernelRegistry::new();
        reg2.register("fc", DeviceType::Dsp, 1);
        reg2.register("fc", DeviceType::Gpu, 2);
        let p = place(
            &g,
            &reg2,
            PlacerOptions { prefer_fpga: false, allow_soft_placement: true },
        )
        .unwrap();
        assert_eq!(p.device_of(y), Some(DeviceType::Gpu), "Gpu ranks before Dsp");
    }

    #[test]
    fn explicit_annotation_honored() {
        let (mut g, reg, y, _) = graph_and_registry();
        g.set_device(y, DeviceType::Cpu);
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        assert_eq!(p.device_of(y), Some(DeviceType::Cpu));
    }

    #[test]
    fn soft_placement_falls_back() {
        let (mut g, reg, _, r) = graph_and_registry();
        g.set_device(r, DeviceType::Fpga); // relu has no FPGA kernel
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        assert_eq!(p.device_of(r), Some(DeviceType::Cpu));
        assert_eq!(p.soft_placed, vec![r]);
    }

    #[test]
    fn hard_placement_fails_loudly() {
        let (mut g, reg, _, r) = graph_and_registry();
        g.set_device(r, DeviceType::Fpga);
        let err = place(
            &g,
            &reg,
            PlacerOptions { allow_soft_placement: false, prefer_fpga: true },
        )
        .unwrap_err();
        assert!(err.to_string().contains("relu"), "{err}");
    }

    #[test]
    fn structural_ops_are_inline() {
        let (g, reg, _, _) = graph_and_registry();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let x = g.by_name("x").unwrap();
        assert_eq!(p.by_node[&x], Placement::Inline);
    }

    #[test]
    fn unimplemented_kernel_is_an_error() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 28, 28], DType::I16).unwrap();
        g.add("c", OpKind::Conv5x5I16, &[x]).unwrap();
        g.finalize().unwrap();
        let reg = KernelRegistry::new();
        assert!(place(&g, &reg, PlacerOptions::default()).is_err());
    }
}
