//! Element types supported by the frontend (mirrors the artifact manifest).

use std::fmt;

/// Tensor element type. The paper's roles use `F32` (FC) and `I16`
/// (fixed-point conv); `I32` appears as the conv accumulator type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    F32,
    I16,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I16 => 2,
        }
    }

    /// Manifest string form (`"f32"`, `"i16"`, `"i32"`).
    pub fn from_manifest(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i16" => Some(DType::I16),
            "i32" => Some(DType::I32),
            _ => None,
        }
    }

    pub fn as_manifest(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I16 => "i16",
            DType::I32 => "i32",
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_manifest())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::I16.size_bytes(), 2);
        assert_eq!(DType::I32.size_bytes(), 4);
    }

    #[test]
    fn manifest_round_trip() {
        for dt in [DType::F32, DType::I16, DType::I32] {
            assert_eq!(DType::from_manifest(dt.as_manifest()), Some(dt));
        }
        assert_eq!(DType::from_manifest("f64"), None);
    }
}
