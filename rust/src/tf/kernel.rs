//! Kernel registry: `(kernel name, device type)` → HSA kernel object.
//!
//! This is the paper's central mechanism: "If TF is able to find a
//! registered kernel implementation for HSA devices it will be dispatched
//! using HSA runtime calls." For FPGA entries the kernel object names a
//! pre-synthesized bitstream on the FPGA agent; for CPU entries a native
//! kernel on the CPU agent.

use crate::hsa::agent::DeviceType;
use crate::hsa::error::{HsaError, Result};
use std::collections::HashMap;

/// Suffix appended to a base kernel name to form its ReLU-fused variant
/// (e.g. `"fc"` → `"fc+relu"`). The plan compiler's fusion pass looks these
/// names up; backends that register them get single-dispatch FC+ReLU /
/// Conv+ReLU steps, everyone else transparently falls back to the pair.
pub const FUSED_RELU_SUFFIX: &str = "+relu";

/// Registry key of the ReLU-fused variant of `base`.
pub fn fused_relu_name(base: &str) -> String {
    format!("{base}{FUSED_RELU_SUFFIX}")
}

/// One registered implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelEntry {
    pub device: DeviceType,
    pub kernel_object: u64,
}

/// The registry.
#[derive(Debug, Default, Clone)]
pub struct KernelRegistry {
    entries: HashMap<(String, DeviceType), u64>,
}

impl KernelRegistry {
    pub fn new() -> KernelRegistry {
        KernelRegistry::default()
    }

    /// Register an implementation; re-registration replaces (TF allows
    /// kernel overrides in priority order; last wins here).
    pub fn register(&mut self, name: impl Into<String>, device: DeviceType, object: u64) {
        self.entries.insert((name.into(), device), object);
    }

    pub fn lookup(&self, name: &str, device: DeviceType) -> Option<u64> {
        self.entries.get(&(name.to_string(), device)).copied()
    }

    /// Kernel object of the ReLU-fused variant of `base` on `device`, if
    /// one is registered (`None` = fusion must fall back to the unfused
    /// pair).
    pub fn lookup_fused_relu(&self, base: &str, device: DeviceType) -> Option<u64> {
        self.lookup(&fused_relu_name(base), device)
    }

    /// Devices that implement `name`, in preference order (FPGA first —
    /// accelerate when possible, the paper's default placement).
    pub fn devices_for(&self, name: &str) -> Vec<DeviceType> {
        let mut out: Vec<DeviceType> = [DeviceType::Fpga, DeviceType::Gpu, DeviceType::Dsp, DeviceType::Cpu]
            .into_iter()
            .filter(|d| self.lookup(name, *d).is_some())
            .collect();
        out.dedup();
        out
    }

    /// Resolve for a required device or fail.
    pub fn require(&self, name: &str, device: DeviceType) -> Result<KernelEntry> {
        self.lookup(name, device)
            .map(|kernel_object| KernelEntry { device, kernel_object })
            .ok_or_else(|| {
                HsaError::Runtime(format!(
                    "no kernel '{name}' registered for device {device}"
                ))
            })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All registered kernel names (sorted, deduplicated).
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.entries.keys().map(|(n, _)| n.clone()).collect();
        names.sort();
        names.dedup();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_lookup() {
        let mut r = KernelRegistry::new();
        r.register("fc", DeviceType::Cpu, 1);
        r.register("fc", DeviceType::Fpga, 2);
        assert_eq!(r.lookup("fc", DeviceType::Cpu), Some(1));
        assert_eq!(r.lookup("fc", DeviceType::Fpga), Some(2));
        assert_eq!(r.lookup("fc", DeviceType::Gpu), None);
    }

    #[test]
    fn fpga_preferred_in_device_order() {
        let mut r = KernelRegistry::new();
        r.register("fc", DeviceType::Cpu, 1);
        r.register("fc", DeviceType::Fpga, 2);
        assert_eq!(r.devices_for("fc"), vec![DeviceType::Fpga, DeviceType::Cpu]);
    }

    #[test]
    fn cpu_only_op() {
        let mut r = KernelRegistry::new();
        r.register("relu", DeviceType::Cpu, 3);
        assert_eq!(r.devices_for("relu"), vec![DeviceType::Cpu]);
    }

    #[test]
    fn require_error_is_descriptive() {
        let r = KernelRegistry::new();
        let err = r.require("fc", DeviceType::Fpga).unwrap_err();
        assert!(err.to_string().contains("fc"));
        assert!(err.to_string().contains("Fpga"));
    }

    #[test]
    fn reregistration_replaces() {
        let mut r = KernelRegistry::new();
        r.register("fc", DeviceType::Cpu, 1);
        r.register("fc", DeviceType::Cpu, 9);
        assert_eq!(r.lookup("fc", DeviceType::Cpu), Some(9));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn fused_relu_lookup_resolves_suffix_name() {
        let mut r = KernelRegistry::new();
        r.register("fc", DeviceType::Fpga, 1);
        r.register(fused_relu_name("fc"), DeviceType::Fpga, 7);
        assert_eq!(fused_relu_name("fc"), "fc+relu");
        assert_eq!(r.lookup_fused_relu("fc", DeviceType::Fpga), Some(7));
        assert_eq!(r.lookup_fused_relu("fc", DeviceType::Cpu), None);
        assert_eq!(r.lookup_fused_relu("relu", DeviceType::Fpga), None);
    }

    #[test]
    fn names_sorted_unique() {
        let mut r = KernelRegistry::new();
        r.register("b", DeviceType::Cpu, 1);
        r.register("a", DeviceType::Cpu, 2);
        r.register("a", DeviceType::Fpga, 3);
        assert_eq!(r.names(), vec!["a".to_string(), "b".to_string()]);
    }
}
