//! The dataflow graph: nodes, ops, shape inference.

use crate::hsa::agent::DeviceType;
use crate::hsa::error::{HsaError, Result};
use crate::tf::dtype::DType;
use crate::tf::tensor::Tensor;
use std::collections::HashMap;

/// Node handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// Operation kinds. Structural ops (`Placeholder`, `Constant`, `Reshape`)
/// execute inline in the executor; compute ops resolve to registered
/// kernels by `kernel_name()` and dispatch through HSA.
#[derive(Debug, Clone)]
pub enum OpKind {
    /// Fed at run time.
    Placeholder { shape: Vec<usize>, dtype: DType },
    /// Baked into the graph.
    Constant(Tensor),
    /// `x @ w + b` (inputs: x, w, b) — role 1.
    FullyConnected,
    /// Same math, barrier-synchronized datapath — role 2.
    FcBarrier,
    /// Fixed-weight int16 conv 5x5, 1 filter — role 3 (input: x).
    Conv5x5I16,
    /// Fixed-weight int16 conv 3x3, 2 filters — role 4 (input: x).
    Conv3x3I16,
    /// Named fixed-weight f32 conv (the CNN layers); weights resolved by
    /// the session from the artifact store.
    ConvFixedF32 { weights: String, filters: usize, cin: usize, kh: usize, kw: usize },
    /// Named fixed-weight fully connected (x only; w/b from artifacts).
    FcFixed { weights_w: String, weights_b: String, out_width: usize },
    /// Generic f32 conv with weights/bias as graph inputs (x, w, b) and
    /// symmetric zero padding — the landing op for imported ONNX `Conv`
    /// nodes. `pad` is baked into the kernel name (`conv2d:p{pad}`), so
    /// each distinct padding registers its own kernel variant.
    Conv2dF32 { pad: usize },
    Relu,
    /// Softmax over the last axis (rank-2 f32).
    Softmax,
    MaxPool2,
    /// Global average pool `(C,H,W)` → `(C,1,1)` (ONNX `GlobalAveragePool`).
    GlobalAvgPool,
    /// Concatenate along `axis` (variadic; ONNX `Concat` with the batch
    /// dim already stripped). Axis is baked into the kernel name.
    Concat { axis: usize },
    Reshape { shape: Vec<usize> },
    Add,
    Quantize { frac_bits: u32 },
    Dequantize { frac_bits: u32 },
    /// Whole-model kernel (one dispatch = one batch of CNN inference).
    MnistCnn,
    /// Registry-resolved custom kernel with explicit output meta.
    Custom { kernel: String, out_shape: Vec<usize>, out_dtype: DType },
}

impl OpKind {
    /// Registry key for compute ops; `None` for structural ops.
    pub fn kernel_name(&self) -> Option<String> {
        match self {
            OpKind::Placeholder { .. } | OpKind::Constant(_) | OpKind::Reshape { .. } => {
                None
            }
            OpKind::FullyConnected => Some("fc".into()),
            OpKind::FcBarrier => Some("fc_barrier".into()),
            OpKind::Conv5x5I16 => Some("conv5x5_i16".into()),
            OpKind::Conv3x3I16 => Some("conv3x3_i16".into()),
            OpKind::ConvFixedF32 { weights, .. } => Some(format!("convf32:{weights}")),
            OpKind::FcFixed { weights_w, .. } => Some(format!("fcfixed:{weights_w}")),
            OpKind::Conv2dF32 { pad } => Some(format!("conv2d:p{pad}")),
            OpKind::Relu => Some("relu".into()),
            OpKind::Softmax => Some("softmax".into()),
            OpKind::MaxPool2 => Some("maxpool2".into()),
            OpKind::GlobalAvgPool => Some("global_avgpool".into()),
            OpKind::Concat { axis } => Some(format!("concat:a{axis}")),
            OpKind::Add => Some("add".into()),
            OpKind::Quantize { .. } => Some("quantize".into()),
            OpKind::Dequantize { .. } => Some("dequantize".into()),
            OpKind::MnistCnn => Some("mnist_cnn".into()),
            OpKind::Custom { kernel, .. } => Some(kernel.clone()),
        }
    }

    /// Expected input arity (`None` = variadic).
    pub fn arity(&self) -> Option<usize> {
        match self {
            OpKind::Placeholder { .. } | OpKind::Constant(_) => Some(0),
            OpKind::FullyConnected | OpKind::FcBarrier | OpKind::Conv2dF32 { .. } => Some(3),
            OpKind::Add => Some(2),
            OpKind::Custom { .. } | OpKind::Concat { .. } => None,
            _ => Some(1),
        }
    }

    /// Infer output (shape, dtype) from input metas.
    pub fn infer(&self, inputs: &[(Vec<usize>, DType)]) -> Result<(Vec<usize>, DType)> {
        let bad = |msg: String| Err(HsaError::Runtime(format!("shape inference: {msg}")));
        match self {
            OpKind::Placeholder { shape, dtype } => Ok((shape.clone(), *dtype)),
            OpKind::Constant(t) => Ok((t.shape().to_vec(), t.dtype())),
            OpKind::FullyConnected | OpKind::FcBarrier => {
                let (x, w, b) = (&inputs[0], &inputs[1], &inputs[2]);
                if x.0.len() != 2 || w.0.len() != 2 || x.0[1] != w.0[0] {
                    return bad(format!("fc: {:?} @ {:?}", x.0, w.0));
                }
                if b.0 != vec![w.0[1]] {
                    return bad(format!("fc bias {:?} != [{}]", b.0, w.0[1]));
                }
                if x.1 != DType::F32 {
                    return bad("fc wants f32".into());
                }
                Ok((vec![x.0[0], w.0[1]], DType::F32))
            }
            OpKind::Conv5x5I16 => conv_infer(&inputs[0], 1, 1, 5, 5, DType::I16),
            OpKind::Conv3x3I16 => conv_infer(&inputs[0], 2, 1, 3, 3, DType::I16),
            OpKind::ConvFixedF32 { filters, cin, kh, kw, .. } => {
                conv_infer(&inputs[0], *filters, *cin, *kh, *kw, DType::F32)
            }
            OpKind::FcFixed { out_width, .. } => {
                let x = &inputs[0];
                if x.0.len() != 2 || x.1 != DType::F32 {
                    return bad(format!("fc_fixed wants rank-2 f32, got {:?}", x.0));
                }
                Ok((vec![x.0[0], *out_width], DType::F32))
            }
            OpKind::Conv2dF32 { pad } => {
                let (x, w, b) = (&inputs[0], &inputs[1], &inputs[2]);
                if x.0.len() != 3 || w.0.len() != 4 || x.1 != DType::F32 || w.1 != DType::F32
                {
                    return bad(format!("conv2d wants (C,H,W) f32 x (F,C,KH,KW) f32, got {:?} {} / {:?} {}", x.0, x.1, w.0, w.1));
                }
                let (c, h, wi) = (x.0[0], x.0[1], x.0[2]);
                let (f, wc, kh, kw) = (w.0[0], w.0[1], w.0[2], w.0[3]);
                if wc != c {
                    return bad(format!("conv2d weight channels {wc} != input {c}"));
                }
                if b.0 != vec![f] || b.1 != DType::F32 {
                    return bad(format!("conv2d bias {:?} {} != [{f}] f32", b.0, b.1));
                }
                if h + 2 * pad < kh || wi + 2 * pad < kw {
                    return bad(format!(
                        "conv2d padded input {}x{} smaller than filter {kh}x{kw}",
                        h + 2 * pad,
                        wi + 2 * pad
                    ));
                }
                Ok((vec![f, h + 2 * pad - kh + 1, wi + 2 * pad - kw + 1], DType::F32))
            }
            OpKind::Relu => Ok(inputs[0].clone()),
            OpKind::Softmax => {
                let (s, dt) = &inputs[0];
                if s.len() != 2 || *dt != DType::F32 {
                    return bad(format!("softmax wants rank-2 f32, got {s:?} {dt}"));
                }
                Ok(inputs[0].clone())
            }
            OpKind::MaxPool2 => {
                let (s, dt) = &inputs[0];
                if s.len() != 3 {
                    return bad(format!("maxpool rank {}", s.len()));
                }
                Ok((vec![s[0], s[1] / 2, s[2] / 2], *dt))
            }
            OpKind::GlobalAvgPool => {
                let (s, dt) = &inputs[0];
                if s.len() != 3 || *dt != DType::F32 {
                    return bad(format!("global_avgpool wants rank-3 f32, got {s:?} {dt}"));
                }
                if s[1] * s[2] == 0 {
                    return bad("global_avgpool over empty spatial dims".into());
                }
                Ok((vec![s[0], 1, 1], DType::F32))
            }
            OpKind::Concat { axis } => {
                let first = match inputs.first() {
                    Some(f) => f,
                    None => return bad("concat needs at least one input".into()),
                };
                let rank = first.0.len();
                if *axis >= rank {
                    return bad(format!("concat axis {axis} out of range for rank {rank}"));
                }
                let mut shape = first.0.clone();
                shape[*axis] = 0;
                for (s, dt) in inputs {
                    if *dt != DType::F32 {
                        return bad(format!("concat wants f32, got {dt}"));
                    }
                    if s.len() != rank {
                        return bad(format!("concat rank mismatch {} vs {rank}", s.len()));
                    }
                    for d in 0..rank {
                        if d != *axis && s[d] != first.0[d] {
                            return bad(format!(
                                "concat dim {d} mismatch: {s:?} vs {:?}",
                                first.0
                            ));
                        }
                    }
                    shape[*axis] += s[*axis];
                }
                Ok((shape, DType::F32))
            }
            OpKind::Reshape { shape } => {
                let (s, dt) = &inputs[0];
                let from: usize = s.iter().product();
                let to: usize = shape.iter().product();
                if from != to {
                    return bad(format!("reshape {s:?} -> {shape:?}"));
                }
                Ok((shape.clone(), *dt))
            }
            OpKind::Add => {
                if inputs[0] != inputs[1] {
                    return bad("add operands differ".into());
                }
                Ok(inputs[0].clone())
            }
            OpKind::Quantize { .. } => {
                let (s, dt) = &inputs[0];
                if *dt != DType::F32 {
                    return bad("quantize wants f32".into());
                }
                Ok((s.clone(), DType::I16))
            }
            OpKind::Dequantize { .. } => {
                let (s, dt) = &inputs[0];
                if *dt != DType::I16 {
                    return bad("dequantize wants i16".into());
                }
                Ok((s.clone(), DType::F32))
            }
            OpKind::MnistCnn => {
                let (s, dt) = &inputs[0];
                if s.len() != 4 || s[1] != 1 || s[2] != 28 || s[3] != 28 || *dt != DType::F32
                {
                    return bad(format!("mnist_cnn wants (B,1,28,28) f32, got {s:?}"));
                }
                Ok((vec![s[0], 10], DType::F32))
            }
            OpKind::Custom { out_shape, out_dtype, .. } => {
                Ok((out_shape.clone(), *out_dtype))
            }
        }
    }
}

fn conv_infer(
    x: &(Vec<usize>, DType),
    f: usize,
    c: usize,
    kh: usize,
    kw: usize,
    want: DType,
) -> Result<(Vec<usize>, DType)> {
    let (s, dt) = x;
    if s.len() != 3 || s[0] != c || s[1] < kh || s[2] < kw || *dt != want {
        return Err(HsaError::Runtime(format!(
            "conv{kh}x{kw}: bad input {s:?} {dt} (want {c} ch, {want})"
        )));
    }
    Ok((vec![f, s[1] - kh + 1, s[2] - kw + 1], want))
}

/// A graph node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: OpKind,
    pub inputs: Vec<NodeId>,
    /// Explicit device annotation (the paper's `with tf.device(...)`).
    pub device: Option<DeviceType>,
    /// Filled by shape inference at finalize.
    pub out_shape: Vec<usize>,
    pub out_dtype: DType,
}

/// The dataflow graph builder.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    nodes: Vec<Node>,
    by_name: HashMap<String, NodeId>,
    finalized: bool,
}

impl Graph {
    pub fn new() -> Graph {
        Graph::default()
    }

    /// Add a node. Names must be unique; inputs must already exist.
    pub fn add(
        &mut self,
        name: impl Into<String>,
        op: OpKind,
        inputs: &[NodeId],
    ) -> Result<NodeId> {
        assert!(!self.finalized, "graph is finalized");
        let name = name.into();
        if self.by_name.contains_key(&name) {
            return Err(HsaError::Runtime(format!("duplicate node name '{name}'")));
        }
        if let Some(arity) = op.arity() {
            if inputs.len() != arity {
                return Err(HsaError::Runtime(format!(
                    "node '{name}': op wants {arity} inputs, got {}",
                    inputs.len()
                )));
            }
        }
        for &i in inputs {
            if i.0 >= self.nodes.len() {
                return Err(HsaError::Runtime(format!("node '{name}': bad input {i:?}")));
            }
        }
        let id = NodeId(self.nodes.len());
        self.nodes.push(Node {
            id,
            name: name.clone(),
            op,
            inputs: inputs.to_vec(),
            device: None,
            out_shape: Vec::new(),
            out_dtype: DType::F32,
        });
        self.by_name.insert(name, id);
        Ok(id)
    }

    /// Convenience: placeholder node.
    pub fn placeholder(
        &mut self,
        name: impl Into<String>,
        shape: &[usize],
        dtype: DType,
    ) -> Result<NodeId> {
        self.add(name, OpKind::Placeholder { shape: shape.to_vec(), dtype }, &[])
    }

    /// Convenience: constant node.
    pub fn constant(&mut self, name: impl Into<String>, t: Tensor) -> Result<NodeId> {
        self.add(name, OpKind::Constant(t), &[])
    }

    /// Pin a node to a device type (`with tf.device(...)`). Allowed after
    /// finalize — placement is orthogonal to shape inference.
    pub fn set_device(&mut self, id: NodeId, device: DeviceType) {
        self.nodes[id.0].device = Some(device);
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0]
    }

    pub fn by_name(&self, name: &str) -> Option<NodeId> {
        self.by_name.get(name).copied()
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn is_finalized(&self) -> bool {
        self.finalized
    }

    /// Run shape inference over the whole graph (nodes are in insertion
    /// order, which is already topological because inputs must pre-exist).
    pub fn finalize(&mut self) -> Result<()> {
        for i in 0..self.nodes.len() {
            let metas: Vec<(Vec<usize>, DType)> = self.nodes[i]
                .inputs
                .iter()
                .map(|&j| (self.nodes[j.0].out_shape.clone(), self.nodes[j.0].out_dtype))
                .collect();
            let (shape, dtype) = self.nodes[i]
                .op
                .infer(&metas)
                .map_err(|e| HsaError::Runtime(format!("node '{}': {e}", self.nodes[i].name)))?;
            self.nodes[i].out_shape = shape;
            self.nodes[i].out_dtype = dtype;
        }
        self.finalized = true;
        Ok(())
    }

    /// Topological order (insertion order is topological by construction).
    pub fn topo_order(&self) -> Vec<NodeId> {
        (0..self.nodes.len()).map(NodeId).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fc_graph() -> (Graph, NodeId) {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[4, 8], DType::F32).unwrap();
        let w = g
            .constant("w", Tensor::zeros(&[8, 2], DType::F32))
            .unwrap();
        let b = g.constant("b", Tensor::zeros(&[2], DType::F32)).unwrap();
        let y = g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
        (g, y)
    }

    #[test]
    fn build_and_infer() {
        let (mut g, y) = fc_graph();
        g.finalize().unwrap();
        assert_eq!(g.node(y).out_shape, vec![4, 2]);
        assert_eq!(g.node(y).out_dtype, DType::F32);
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut g = Graph::new();
        g.placeholder("x", &[1], DType::F32).unwrap();
        assert!(g.placeholder("x", &[1], DType::F32).is_err());
    }

    #[test]
    fn arity_enforced() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[2, 2], DType::F32).unwrap();
        assert!(g.add("y", OpKind::FullyConnected, &[x]).is_err());
    }

    #[test]
    fn bad_fc_shapes_fail_at_finalize() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[4, 8], DType::F32).unwrap();
        let w = g.constant("w", Tensor::zeros(&[7, 2], DType::F32)).unwrap();
        let b = g.constant("b", Tensor::zeros(&[2], DType::F32)).unwrap();
        g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
        assert!(g.finalize().is_err());
    }

    #[test]
    fn conv_shapes() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 28, 28], DType::I16).unwrap();
        let c5 = g.add("c5", OpKind::Conv5x5I16, &[x]).unwrap();
        let c3 = g.add("c3", OpKind::Conv3x3I16, &[x]).unwrap();
        g.finalize().unwrap();
        assert_eq!(g.node(c5).out_shape, vec![1, 24, 24]);
        assert_eq!(g.node(c3).out_shape, vec![2, 26, 26]);
    }

    #[test]
    fn conv2d_pad_gap_concat_shapes() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[3, 8, 8], DType::F32).unwrap();
        let w = g.constant("w", Tensor::zeros(&[4, 3, 3, 3], DType::F32)).unwrap();
        let b = g.constant("b", Tensor::zeros(&[4], DType::F32)).unwrap();
        let c = g.add("c", OpKind::Conv2dF32 { pad: 1 }, &[x, w, b]).unwrap();
        let gap = g.add("gap", OpKind::GlobalAvgPool, &[c]).unwrap();
        let cat = g.add("cat", OpKind::Concat { axis: 0 }, &[c, c]).unwrap();
        g.finalize().unwrap();
        assert_eq!(g.node(c).out_shape, vec![4, 8, 8], "same padding keeps dims");
        assert_eq!(g.node(gap).out_shape, vec![4, 1, 1]);
        assert_eq!(g.node(cat).out_shape, vec![8, 8, 8]);
        assert_eq!(g.node(c).op.kernel_name().unwrap(), "conv2d:p1");
        assert_eq!(g.node(cat).op.kernel_name().unwrap(), "concat:a0");
    }

    #[test]
    fn conv2d_channel_mismatch_fails_at_finalize() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[2, 8, 8], DType::F32).unwrap();
        let w = g.constant("w", Tensor::zeros(&[4, 3, 3, 3], DType::F32)).unwrap();
        let b = g.constant("b", Tensor::zeros(&[4], DType::F32)).unwrap();
        g.add("c", OpKind::Conv2dF32 { pad: 0 }, &[x, w, b]).unwrap();
        assert!(g.finalize().is_err());
    }

    #[test]
    fn quant_dequant_dtype_flow() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 8, 8], DType::F32).unwrap();
        let q = g.add("q", OpKind::Quantize { frac_bits: 8 }, &[x]).unwrap();
        let d = g.add("d", OpKind::Dequantize { frac_bits: 8 }, &[q]).unwrap();
        g.finalize().unwrap();
        assert_eq!(g.node(q).out_dtype, DType::I16);
        assert_eq!(g.node(d).out_dtype, DType::F32);
    }

    #[test]
    fn reshape_validates_elements() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[2, 6], DType::F32).unwrap();
        g.add("r", OpKind::Reshape { shape: vec![3, 4] }, &[x]).unwrap();
        g.finalize().unwrap();
        let mut g2 = Graph::new();
        let x2 = g2.placeholder("x", &[2, 6], DType::F32).unwrap();
        g2.add("r", OpKind::Reshape { shape: vec![5, 5] }, &[x2]).unwrap();
        assert!(g2.finalize().is_err());
    }

    #[test]
    fn device_annotation_stored() {
        let (mut g, y) = fc_graph();
        g.set_device(y, DeviceType::Fpga);
        assert_eq!(g.node(y).device, Some(DeviceType::Fpga));
    }

    #[test]
    fn topo_order_is_complete() {
        let (mut g, _) = fc_graph();
        g.finalize().unwrap();
        assert_eq!(g.topo_order().len(), g.len());
    }
}
