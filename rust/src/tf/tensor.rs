//! Dense host tensors moved through the graph executor and HSA packets.

use crate::tf::dtype::DType;
use std::fmt;
use std::sync::Arc;

/// Raw storage variants (one per supported dtype). Buffers are `Arc`-shared:
/// a dispatch clones the handle, not the data.
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Arc<Vec<f32>>),
    I16(Arc<Vec<i16>>),
    I32(Arc<Vec<i32>>),
}

/// A dense, row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    storage: Storage,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum TensorError {
    #[error("shape {shape:?} implies {expected} elements, buffer has {actual}")]
    LengthMismatch { shape: Vec<usize>, expected: usize, actual: usize },
    #[error("dtype mismatch: tensor is {actual}, requested {requested}")]
    DTypeMismatch { actual: DType, requested: DType },
    #[error("cannot reshape {from:?} ({from_n} elems) to {to:?} ({to_n} elems)")]
    ReshapeMismatch { from: Vec<usize>, from_n: usize, to: Vec<usize>, to_n: usize },
}

impl Tensor {
    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Result<Tensor, TensorError> {
        Self::check_len(shape, data.len())?;
        Ok(Tensor { shape: shape.to_vec(), storage: Storage::F32(Arc::new(data)) })
    }

    pub fn from_i16(shape: &[usize], data: Vec<i16>) -> Result<Tensor, TensorError> {
        Self::check_len(shape, data.len())?;
        Ok(Tensor { shape: shape.to_vec(), storage: Storage::I16(Arc::new(data)) })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Result<Tensor, TensorError> {
        Self::check_len(shape, data.len())?;
        Ok(Tensor { shape: shape.to_vec(), storage: Storage::I32(Arc::new(data)) })
    }

    pub fn zeros(shape: &[usize], dtype: DType) -> Tensor {
        let n = shape.iter().product();
        let storage = match dtype {
            DType::F32 => Storage::F32(Arc::new(vec![0.0; n])),
            DType::I16 => Storage::I16(Arc::new(vec![0; n])),
            DType::I32 => Storage::I32(Arc::new(vec![0; n])),
        };
        Tensor { shape: shape.to_vec(), storage }
    }

    fn check_len(shape: &[usize], actual: usize) -> Result<(), TensorError> {
        let expected: usize = shape.iter().product();
        if expected != actual {
            return Err(TensorError::LengthMismatch {
                shape: shape.to_vec(),
                expected,
                actual,
            });
        }
        Ok(())
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self.storage {
            Storage::F32(_) => DType::F32,
            Storage::I16(_) => DType::I16,
            Storage::I32(_) => DType::I32,
        }
    }

    pub fn byte_len(&self) -> usize {
        self.len() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32], TensorError> {
        match &self.storage {
            Storage::F32(v) => Ok(v),
            _ => Err(TensorError::DTypeMismatch { actual: self.dtype(), requested: DType::F32 }),
        }
    }

    pub fn as_i16(&self) -> Result<&[i16], TensorError> {
        match &self.storage {
            Storage::I16(v) => Ok(v),
            _ => Err(TensorError::DTypeMismatch { actual: self.dtype(), requested: DType::I16 }),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32], TensorError> {
        match &self.storage {
            Storage::I32(v) => Ok(v),
            _ => Err(TensorError::DTypeMismatch { actual: self.dtype(), requested: DType::I32 }),
        }
    }

    /// Reclaim the f32 buffer if this tensor holds the *only* reference
    /// to it (no live clones in feeds, plans or pending dispatches).
    /// `None` for shared storage or non-f32 tensors. The serving pipeline
    /// uses this to recycle a retired batch's staging buffer back into
    /// its lane instead of allocating fresh memory per batch.
    pub fn try_take_f32(self) -> Option<Vec<f32>> {
        match self.storage {
            Storage::F32(arc) => Arc::try_unwrap(arc).ok(),
            _ => None,
        }
    }

    /// Same data, new shape (element counts must match).
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor, TensorError> {
        let to_n: usize = shape.iter().product();
        if to_n != self.len() {
            return Err(TensorError::ReshapeMismatch {
                from: self.shape.clone(),
                from_n: self.len(),
                to: shape.to_vec(),
                to_n,
            });
        }
        Ok(Tensor { shape: shape.to_vec(), storage: self.storage.clone() })
    }

    /// Row-major offset for index tuple (debug/testing helper).
    pub fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(ix < dim, "index {ix} out of bounds for dim {i} ({dim})");
            off = off * dim + ix;
        }
        off
    }

    /// Max |a - b| between two f32 tensors (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f64, TensorError> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        assert_eq!(self.shape, other.shape, "shape mismatch in comparison");
        Ok(a.iter()
            .zip(b)
            .map(|(x, y)| (*x as f64 - *y as f64).abs())
            .fold(0.0, f64::max))
    }
}

impl fmt::Display for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor<{}>{:?}", self.dtype(), self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_length() {
        assert!(Tensor::from_f32(&[2, 3], vec![0.0; 6]).is_ok());
        let err = Tensor::from_f32(&[2, 3], vec![0.0; 5]).unwrap_err();
        assert!(matches!(err, TensorError::LengthMismatch { expected: 6, actual: 5, .. }));
    }

    #[test]
    fn dtype_accessors_enforced() {
        let t = Tensor::from_i16(&[4], vec![1, 2, 3, 4]).unwrap();
        assert_eq!(t.dtype(), DType::I16);
        assert!(t.as_i16().is_ok());
        assert!(t.as_f32().is_err());
    }

    #[test]
    fn reshape_shares_storage() {
        let t = Tensor::from_f32(&[2, 6], (0..12).map(|x| x as f32).collect()).unwrap();
        let r = t.reshape(&[3, 4]).unwrap();
        assert_eq!(r.shape(), &[3, 4]);
        assert_eq!(t.as_f32().unwrap(), r.as_f32().unwrap());
        assert!(t.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn offset_is_row_major() {
        let t = Tensor::zeros(&[2, 3, 4], DType::F32);
        assert_eq!(t.offset(&[0, 0, 0]), 0);
        assert_eq!(t.offset(&[0, 0, 3]), 3);
        assert_eq!(t.offset(&[0, 1, 0]), 4);
        assert_eq!(t.offset(&[1, 2, 3]), 23);
    }

    #[test]
    fn byte_len_counts_dtype_size() {
        assert_eq!(Tensor::zeros(&[10], DType::I16).byte_len(), 20);
        assert_eq!(Tensor::zeros(&[10], DType::F32).byte_len(), 40);
    }

    #[test]
    fn try_take_recovers_unique_buffers_only() {
        let t = Tensor::from_f32(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let shared = t.clone();
        assert_eq!(t.try_take_f32(), None, "clone still holds the storage");
        assert_eq!(shared.try_take_f32(), Some(vec![1.0, 2.0, 3.0]));
        let i = Tensor::from_i32(&[1], vec![7]).unwrap();
        assert_eq!(i.try_take_f32(), None, "wrong dtype");
    }

    #[test]
    fn scalar_and_empty() {
        let s = Tensor::from_f32(&[], vec![7.0]).unwrap();
        assert_eq!(s.len(), 1);
        let e = Tensor::from_f32(&[0], vec![]).unwrap();
        assert!(e.is_empty());
    }
}
