//! TensorFlow-like frontend (the paper's §III "everything needed is
//! completely integrated into TF itself").
//!
//! The shape mirrors TF 1.x's C++ core: build a [`graph::Graph`] of ops,
//! annotate nodes with a device ([`placer`] fills in the rest, soft-placing
//! onto the FPGA when a kernel implementation is registered for it), then
//! run it through a [`session::Session`] whose executor dispatches each
//! node to its device's HSA queue.

pub mod dtype;
pub mod executor;
pub mod graph;
pub mod kernel;
pub mod placer;
pub mod session;
pub mod tensor;

pub use dtype::DType;
pub use graph::{Graph, NodeId, OpKind};
pub use kernel::KernelRegistry;
pub use session::{Session, SessionOptions};
pub use tensor::Tensor;
