//! TensorFlow-like frontend (the paper's §III "everything needed is
//! completely integrated into TF itself").
//!
//! The shape mirrors TF 1.x's C++ core: build a [`graph::Graph`] of ops,
//! annotate nodes with a device ([`placer`] fills in the rest, soft-placing
//! onto the FPGA when a kernel implementation is registered for it), then
//! run it through a [`session::Session`]. The session compiles each
//! `(feeds, fetches)` shape once into an [`plan::ExecutionPlan`] — pruned,
//! constant-folded, op-fused, slot-allocated — and replays it on every
//! subsequent `run`; the interpreted [`executor`] walk remains as the
//! reference path.
//!
//! Above the session sits [`model`]: serialized GraphDef bundles
//! ([`model::ModelBundle`], `model.json` on disk — the exchange format the
//! Python frontend exports) and the [`model::Model`] facade that resolves
//! feeds/fetches by *signature endpoint name* instead of raw node names.

pub mod dtype;
pub mod executor;
pub mod fusion;
pub mod graph;
pub mod kernel;
pub mod model;
pub mod onnx;
pub mod placer;
pub mod plan;
pub mod session;
pub mod tensor;

pub use dtype::DType;
pub use graph::{Graph, NodeId, OpKind};
pub use kernel::KernelRegistry;
pub use model::{Endpoint, Model, ModelBundle, Signature};
pub use plan::{ExecutionPlan, PlanOptions};
pub use session::{Session, SessionOptions};
pub use tensor::Tensor;
