//! TensorFlow-like frontend (the paper's §III "everything needed is
//! completely integrated into TF itself").
//!
//! The shape mirrors TF 1.x's C++ core: build a [`graph::Graph`] of ops,
//! annotate nodes with a device ([`placer`] fills in the rest, soft-placing
//! onto the FPGA when a kernel implementation is registered for it), then
//! run it through a [`session::Session`]. The session compiles each
//! `(feeds, fetches)` shape once into an [`plan::ExecutionPlan`] — pruned,
//! constant-folded, op-fused, slot-allocated — and replays it on every
//! subsequent `run`; the interpreted [`executor`] walk remains as the
//! reference path.

pub mod dtype;
pub mod executor;
pub mod fusion;
pub mod graph;
pub mod kernel;
pub mod placer;
pub mod plan;
pub mod session;
pub mod tensor;

pub use dtype::DType;
pub use graph::{Graph, NodeId, OpKind};
pub use kernel::KernelRegistry;
pub use plan::{ExecutionPlan, PlanOptions};
pub use session::{Session, SessionOptions};
pub use tensor::Tensor;
