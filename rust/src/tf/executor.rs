//! The interpreted graph executor: topological walk, inline structural
//! ops, HSA dispatch for compute ops, reference-counted tensor lifetimes.
//!
//! This is the *reference* execution path. The serving hot path replays a
//! precompiled [`crate::tf::plan::ExecutionPlan`] instead (pruning,
//! constant folding, op fusion, slot-based buffers, concurrent dispatch);
//! [`crate::tf::session::Session::run`] routes through cached plans and
//! `Session::run_interpreted` exposes this walk for comparison. The
//! plan-equivalence property test (`tests/prop_invariants.rs`) pins the
//! two paths to bitwise-identical outputs.

use crate::hsa::agent::DeviceType;
use crate::hsa::error::{HsaError, Result};
use crate::hsa::queue::Queue;
use crate::hsa::runtime::HsaRuntime;
use crate::tf::dtype::DType;
use crate::tf::graph::{Graph, NodeId, OpKind};
use crate::tf::placer::{Placement, PlacementMap};
use crate::tf::tensor::Tensor;
use std::collections::HashMap;
use std::time::Instant;

/// Validate a fed tensor against its placeholder declaration. Shared by
/// the interpreter, plan replay, the plan cache and the async fast path so
/// the rule (and its error message) can never drift between them.
pub(crate) fn check_feed(
    name: &str,
    shape: &[usize],
    dtype: DType,
    t: &Tensor,
) -> Result<()> {
    if t.shape() != shape || t.dtype() != dtype {
        return Err(HsaError::Runtime(format!(
            "feed '{name}': expected {shape:?} {dtype}, got {:?} {}",
            t.shape(),
            t.dtype()
        )));
    }
    Ok(())
}

/// Unwrap a kernel's single output, checking it against shape inference
/// (`expected_shape` empty = skip the shape check). Shared by the
/// interpreter, plan compile-time folding and plan replay.
pub(crate) fn check_kernel_output(
    name: &str,
    expected_shape: &[usize],
    mut outs: Vec<Tensor>,
) -> Result<Tensor> {
    if outs.len() != 1 {
        return Err(HsaError::Runtime(format!(
            "kernel for '{name}' returned {} outputs",
            outs.len()
        )));
    }
    let out = outs.pop().unwrap();
    if !expected_shape.is_empty() && out.shape() != expected_shape {
        return Err(HsaError::Runtime(format!(
            "node '{name}': kernel produced {:?}, inference said {:?}",
            out.shape(),
            expected_shape
        )));
    }
    Ok(out)
}

/// Per-run statistics (feeds Table II's dispatch-latency analysis).
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Structural ops executed inline. The interpreter counts
    /// placeholders, constants and reshapes it runs; plan replay counts
    /// only feeds and reshapes (constants are preloaded at compile time),
    /// so compare `dispatches` across paths, not this.
    pub inline_ops: u64,
    pub dispatches: u64,
    pub dispatches_by_device: HashMap<DeviceType, u64>,
    /// Dispatches that covered a fused op pair (plan replay only; the
    /// interpreted walk never fuses, so it leaves this at 0).
    pub fused_dispatches: u64,
    /// Steps in the replayed plan (0 for the interpreted walk).
    pub plan_steps: u64,
    pub wall_us: u128,
}

/// Execution environment: the HSA runtime, one queue per device type, and
/// (optionally) a multi-FPGA router that fans FPGA dispatches out across
/// an agent pool instead of the single mapped queue.
pub struct ExecEnv<'a> {
    pub runtime: &'a HsaRuntime,
    pub queues: &'a HashMap<DeviceType, Queue>,
    /// `Some` when the session runs a pool (`SessionOptions::fpga_pool`
    /// > 1, or 1 — the degenerate router); `None` for bare test
    /// environments, which fall back to the `queues` map for every
    /// device.
    pub router: Option<&'a crate::sharding::Router>,
}

impl ExecEnv<'_> {
    /// Resolve the queue a `(device, kernel_object)` dispatch should land
    /// on. FPGA dispatches with a router present are shard-routed and
    /// return a [`crate::sharding::RouteGuard`] the caller must hold
    /// until the dispatch's result is harvested (it retires the agent's
    /// in-flight gauge on drop); everything else uses the per-device
    /// queue map.
    pub fn route(
        &self,
        device: DeviceType,
        kernel_object: u64,
    ) -> Result<(Queue, Option<crate::sharding::RouteGuard>)> {
        self.route_indexed(device, kernel_object)
            .map(|(_, queue, guard)| (queue, guard))
    }

    /// Like [`ExecEnv::route`], also returning the router slot index the
    /// dispatch landed on (None for non-routed dispatches). Retry paths
    /// need the index to attribute failures to (and quarantine) the
    /// specific agent.
    pub fn route_indexed(
        &self,
        device: DeviceType,
        kernel_object: u64,
    ) -> Result<(Option<usize>, Queue, Option<crate::sharding::RouteGuard>)> {
        if device == DeviceType::Fpga {
            if let Some(router) = self.router {
                let (i, queue, guard) = router.route(kernel_object);
                return Ok((Some(i), queue, Some(guard)));
            }
        }
        self.queues
            .get(&device)
            .cloned()
            .map(|q| (None, q, None))
            .ok_or_else(|| HsaError::Runtime(format!("no queue for device {device}")))
    }
}

/// Execute a finalized, placed graph.
pub fn run(
    graph: &Graph,
    placement: &PlacementMap,
    env: &ExecEnv<'_>,
    feeds: &HashMap<String, Tensor>,
    fetches: &[&str],
) -> Result<(Vec<Tensor>, RunStats)> {
    assert!(graph.is_finalized(), "finalize the graph before running");
    let t0 = Instant::now();
    let mut stats = RunStats::default();

    // Reference counts: free intermediate tensors when the last consumer is
    // done (keeps peak memory at the working set, not the whole graph).
    let mut refcount: Vec<usize> = vec![0; graph.len()];
    for node in graph.nodes() {
        for &i in &node.inputs {
            refcount[i.0] += 1;
        }
    }
    for &name in fetches {
        let id = graph
            .by_name(name)
            .ok_or_else(|| HsaError::Runtime(format!("fetch '{name}' not in graph")))?;
        refcount[id.0] += 1;
    }

    let mut values: Vec<Option<Tensor>> = vec![None; graph.len()];

    for id in graph.topo_order() {
        let node = graph.node(id);
        // Dead nodes — refcount 0 because nothing consumes them and they
        // are not fetched — are skipped entirely, the on-the-fly analogue
        // of TF's graph pruning. (The plan compiler prunes them at compile
        // time instead.)
        if refcount[id.0] == 0 {
            continue;
        }
        // Gather inputs, decrementing refcounts as we go: the last
        // consumer *moves* the tensor out of `values` instead of cloning
        // it, so intermediate buffers transfer ownership along the chain.
        let mut inputs = Vec::with_capacity(node.inputs.len());
        for &i in &node.inputs {
            refcount[i.0] -= 1;
            let t = if refcount[i.0] == 0 {
                values[i.0].take()
            } else {
                values[i.0].clone()
            };
            inputs.push(t.ok_or_else(|| {
                HsaError::Runtime(format!("input of '{}' missing", node.name))
            })?);
        }

        let out = match placement.by_node.get(&id) {
            Some(Placement::Inline) | None => {
                stats.inline_ops += 1;
                run_inline(node.id, graph, feeds, &inputs)?
            }
            Some(Placement::Device { device, kernel_object }) => {
                let (queue, _route) = env.route(*device, *kernel_object)?;
                stats.dispatches += 1;
                *stats.dispatches_by_device.entry(*device).or_insert(0) += 1;
                let outs = env.runtime.dispatch_sync(&queue, *kernel_object, inputs)?;
                // Shape checked below (shared with the inline branch).
                check_kernel_output(&node.name, &[], outs)?
            }
        };

        // Shape check against inference (strict mode catches kernel bugs).
        if !node.out_shape.is_empty() && out.shape() != node.out_shape.as_slice() {
            return Err(HsaError::Runtime(format!(
                "node '{}': kernel produced {:?}, inference said {:?}",
                node.name,
                out.shape(),
                node.out_shape
            )));
        }

        values[id.0] = Some(out);
    }

    let mut results = Vec::with_capacity(fetches.len());
    for &name in fetches {
        let id = graph.by_name(name).unwrap();
        let t = values[id.0]
            .clone()
            .ok_or_else(|| HsaError::Runtime(format!("fetch '{name}' was not computed")))?;
        results.push(t);
    }
    stats.wall_us = t0.elapsed().as_micros();
    Ok((results, stats))
}

fn run_inline(
    id: NodeId,
    graph: &Graph,
    feeds: &HashMap<String, Tensor>,
    inputs: &[Tensor],
) -> Result<Tensor> {
    let node = graph.node(id);
    match &node.op {
        OpKind::Placeholder { shape, dtype } => {
            let t = feeds.get(&node.name).ok_or_else(|| {
                HsaError::Runtime(format!("placeholder '{}' not fed", node.name))
            })?;
            check_feed(&node.name, shape, *dtype, t)?;
            Ok(t.clone())
        }
        OpKind::Constant(t) => Ok(t.clone()),
        OpKind::Reshape { shape } => Ok(inputs[0].reshape(shape)?),
        other => Err(HsaError::Runtime(format!(
            "op {other:?} is not inline-executable"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::device::{CpuAgent, CpuKernel};
    use crate::cpu::a53::CpuKernelClass;
    use crate::tf::dtype::DType;
    use crate::tf::kernel::KernelRegistry;
    use crate::tf::placer::{place, PlacerOptions};
    use std::sync::Arc;

    fn env_with_cpu() -> (HsaRuntime, HashMap<DeviceType, Queue>, KernelRegistry) {
        let cpu = CpuAgent::with_defaults();
        let fc = cpu.register_kernel(CpuKernel {
            name: "fc".into(),
            func: Arc::new(|ins| Ok(vec![crate::ops::fc_f32(&ins[0], &ins[1], &ins[2])?])),
            class: CpuKernelClass::FcF32,
            op_template: None,
        });
        let relu = cpu.register_kernel(CpuKernel {
            name: "relu".into(),
            func: Arc::new(|ins| Ok(vec![crate::ops::relu_f32(&ins[0])?])),
            class: CpuKernelClass::Memory,
            op_template: None,
        });
        let add = cpu.register_kernel(CpuKernel {
            name: "add".into(),
            func: Arc::new(|ins| Ok(vec![crate::ops::add_f32(&ins[0], &ins[1])?])),
            class: CpuKernelClass::Memory,
            op_template: None,
        });
        let rt = HsaRuntime::builder().with_agent(cpu.clone()).build();
        let q = rt.create_queue(rt.agent_by_type(DeviceType::Cpu).unwrap(), 64);
        let mut queues = HashMap::new();
        queues.insert(DeviceType::Cpu, q);
        let mut reg = KernelRegistry::new();
        reg.register("fc", DeviceType::Cpu, fc);
        reg.register("relu", DeviceType::Cpu, relu);
        reg.register("add", DeviceType::Cpu, add);
        (rt, queues, reg)
    }

    fn small_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 2], DType::F32).unwrap();
        let w = g
            .constant(
                "w",
                Tensor::from_f32(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap(),
            )
            .unwrap();
        let b = g
            .constant("b", Tensor::from_f32(&[2], vec![-5.0, 5.0]).unwrap())
            .unwrap();
        let y = g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
        g.add("out", OpKind::Relu, &[y]).unwrap();
        g.finalize().unwrap();
        g
    }

    #[test]
    fn executes_fc_relu_pipeline() {
        let (rt, queues, reg) = env_with_cpu();
        let g = small_graph();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::from_f32(&[1, 2], vec![1.0, 2.0]).unwrap(),
        );
        let (outs, stats) = run(&g, &p, &env, &feeds, &["out", "y"]).unwrap();
        // y = [1-5, 2+5] = [-4, 7]; relu -> [0, 7].
        assert_eq!(outs[0].as_f32().unwrap(), &[0.0, 7.0]);
        assert_eq!(outs[1].as_f32().unwrap(), &[-4.0, 7.0]);
        assert_eq!(stats.dispatches, 2);
        assert_eq!(stats.inline_ops, 3);
        rt.shutdown();
    }

    #[test]
    fn missing_feed_is_an_error() {
        let (rt, queues, reg) = env_with_cpu();
        let g = small_graph();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let err = run(&g, &p, &env, &HashMap::new(), &["out"]).unwrap_err();
        assert!(err.to_string().contains("not fed"), "{err}");
        rt.shutdown();
    }

    #[test]
    fn wrong_feed_shape_rejected() {
        let (rt, queues, reg) = env_with_cpu();
        let g = small_graph();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let mut feeds = HashMap::new();
        feeds.insert("x".to_string(), Tensor::zeros(&[2, 2], DType::F32));
        assert!(run(&g, &p, &env, &feeds, &["out"]).is_err());
        rt.shutdown();
    }

    #[test]
    fn unknown_fetch_rejected() {
        let (rt, queues, reg) = env_with_cpu();
        let g = small_graph();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        assert!(run(&g, &p, &env, &HashMap::new(), &["zzz"]).is_err());
        rt.shutdown();
    }

    #[test]
    fn node_consuming_same_input_twice_survives_move_optimization() {
        // Add(r, r): the first read must clone, only the final read may
        // move the tensor out of the value table.
        let (rt, queues, reg) = env_with_cpu();
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 2], DType::F32).unwrap();
        let r = g.add("r", OpKind::Relu, &[x]).unwrap();
        g.add("d", OpKind::Add, &[r, r]).unwrap();
        g.finalize().unwrap();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let mut feeds = HashMap::new();
        feeds.insert("x".to_string(), Tensor::from_f32(&[1, 2], vec![-1.0, 3.0]).unwrap());
        let (outs, _) = run(&g, &p, &env, &feeds, &["d"]).unwrap();
        assert_eq!(outs[0].as_f32().unwrap(), &[0.0, 6.0]);
        rt.shutdown();
    }

    #[test]
    fn dead_nodes_are_skipped() {
        let (rt, queues, reg) = env_with_cpu();
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1], DType::F32).unwrap();
        g.add("dead", OpKind::Relu, &[x]).unwrap();
        g.add("live", OpKind::Relu, &[x]).unwrap();
        g.finalize().unwrap();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let mut feeds = HashMap::new();
        feeds.insert("x".to_string(), Tensor::from_f32(&[1], vec![-3.0]).unwrap());
        let (outs, stats) = run(&g, &p, &env, &feeds, &["live"]).unwrap();
        assert_eq!(outs[0].as_f32().unwrap(), &[0.0]);
        assert_eq!(stats.dispatches, 1, "dead relu must not dispatch");
        rt.shutdown();
    }
}
