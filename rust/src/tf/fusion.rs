//! Op fusion for the execution plan compiler.
//!
//! The only pattern the backends currently implement in hardware is
//! *compute-op + ReLU*: the paper's roles are streaming datapaths whose
//! output stage can clamp at zero for free (one saturation unit, no extra
//! cycles), so `FullyConnected → Relu` and `Conv → Relu` collapse into a
//! single dispatch whenever a fused kernel is registered for the device
//! the producer was placed on. When no fused kernel exists the pair simply
//! stays unfused — fusion is an optimization, never a requirement.

use crate::hsa::agent::DeviceType;
use crate::tf::graph::{Graph, NodeId, OpKind};
use crate::tf::kernel::{fused_relu_name, KernelRegistry};
use crate::tf::placer::{Placement, PlacementMap};

/// One producer→ReLU pair that will execute as a single fused dispatch.
#[derive(Debug, Clone)]
pub struct Fusion {
    /// The compute op absorbing the activation.
    pub producer: NodeId,
    /// The ReLU node being absorbed (its output becomes the step output).
    pub activation: NodeId,
    /// Device the fused step runs on (the producer's placement).
    pub device: DeviceType,
    /// Kernel object of the registered fused kernel.
    pub kernel_object: u64,
    /// Registry name of the fused kernel (`"<base>+relu"`).
    pub kernel: String,
}

/// Whether `op` has a ReLU-fusible hardware shape (a dense / conv datapath
/// whose output stream can be clamped in place).
pub fn fusible_with_relu(op: &OpKind) -> bool {
    matches!(
        op,
        OpKind::FullyConnected
            | OpKind::FcBarrier
            | OpKind::Conv5x5I16
            | OpKind::Conv3x3I16
            | OpKind::ConvFixedF32 { .. }
            | OpKind::FcFixed { .. }
            | OpKind::Conv2dF32 { .. }
    )
}

/// Find every producer→ReLU pair that can fuse.
///
/// A pair fuses iff:
/// * both nodes are live (reverse-reachable from the fetch set) and not
///   already folded to constants,
/// * the producer's *only* live consumer is the ReLU and the producer
///   itself is not fetched (its intermediate value must not be observable),
/// * the ReLU carries no explicit device annotation pinning it elsewhere
///   (a user's `with tf.device(...)` must not be silently overridden),
/// * the producer is device-placed and the registry has the fused kernel
///   (`<base>+relu`) on that same device.
///
/// `is_const[i]` marks nodes whose value was folded at compile time;
/// `fetched[i]` marks fetch-set members.
pub fn find_relu_fusions(
    graph: &Graph,
    placement: &PlacementMap,
    registry: &KernelRegistry,
    live: &[bool],
    is_const: &[bool],
    fetched: &[bool],
) -> Vec<Fusion> {
    // Consumer counts over the live subgraph only: a producer whose other
    // consumers were all pruned can still fuse.
    let mut consumers = vec![0usize; graph.len()];
    for node in graph.nodes() {
        if live[node.id.0] && !is_const[node.id.0] {
            for &i in &node.inputs {
                consumers[i.0] += 1;
            }
        }
    }

    let mut out = Vec::new();
    for node in graph.nodes() {
        let relu = node.id;
        if !live[relu.0] || is_const[relu.0] || !matches!(node.op, OpKind::Relu) {
            continue;
        }
        let producer = node.inputs[0];
        if is_const[producer.0] || fetched[producer.0] || consumers[producer.0] != 1 {
            continue;
        }
        let pnode = graph.node(producer);
        if !fusible_with_relu(&pnode.op) {
            continue;
        }
        let Some(base) = pnode.op.kernel_name() else { continue };
        let Some(Placement::Device { device, .. }) = placement.by_node.get(&producer)
        else {
            continue;
        };
        // An explicit device pin on the ReLU is a user contract: only fuse
        // when it agrees with where the fused step will actually run.
        if matches!(node.device, Some(d) if d != *device) {
            continue;
        }
        let Some(kernel_object) = registry.lookup_fused_relu(&base, *device) else {
            continue;
        };
        out.push(Fusion {
            producer,
            activation: relu,
            device: *device,
            kernel_object,
            kernel: fused_relu_name(&base),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tf::dtype::DType;
    use crate::tf::placer::{place, PlacerOptions};
    use crate::tf::tensor::Tensor;

    fn fc_relu_graph() -> (Graph, NodeId, NodeId) {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 4], DType::F32).unwrap();
        let w = g.constant("w", Tensor::zeros(&[4, 2], DType::F32)).unwrap();
        let b = g.constant("b", Tensor::zeros(&[2], DType::F32)).unwrap();
        let y = g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
        let r = g.add("out", OpKind::Relu, &[y]).unwrap();
        g.finalize().unwrap();
        (g, y, r)
    }

    fn registry(with_fused: bool) -> KernelRegistry {
        let mut reg = KernelRegistry::new();
        reg.register("fc", DeviceType::Cpu, 1);
        reg.register("relu", DeviceType::Cpu, 2);
        if with_fused {
            reg.register(fused_relu_name("fc"), DeviceType::Cpu, 3);
        }
        reg
    }

    fn all(g: &Graph, v: bool) -> Vec<bool> {
        vec![v; g.len()]
    }

    #[test]
    fn fc_relu_pair_fuses_when_kernel_registered() {
        let (g, y, r) = fc_relu_graph();
        let reg = registry(true);
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let mut fetched = all(&g, false);
        fetched[r.0] = true;
        let f = find_relu_fusions(&g, &p, &reg, &all(&g, true), &all(&g, false), &fetched);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].producer, f[0].activation), (y, r));
        assert_eq!(f[0].kernel, "fc+relu");
        assert_eq!(f[0].kernel_object, 3);
    }

    #[test]
    fn no_fused_kernel_means_no_fusion() {
        let (g, _, r) = fc_relu_graph();
        let reg = registry(false);
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let mut fetched = all(&g, false);
        fetched[r.0] = true;
        let f = find_relu_fusions(&g, &p, &reg, &all(&g, true), &all(&g, false), &fetched);
        assert!(f.is_empty(), "must fall back to the unfused pair");
    }

    #[test]
    fn fetched_producer_blocks_fusion() {
        let (g, y, r) = fc_relu_graph();
        let reg = registry(true);
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let mut fetched = all(&g, false);
        fetched[y.0] = true; // the intermediate is observable
        fetched[r.0] = true;
        let f = find_relu_fusions(&g, &p, &reg, &all(&g, true), &all(&g, false), &fetched);
        assert!(f.is_empty());
    }

    #[test]
    fn explicitly_pinned_relu_blocks_cross_device_fusion() {
        let (mut g, y, r) = fc_relu_graph();
        let mut reg = KernelRegistry::new();
        reg.register("fc", DeviceType::Fpga, 1);
        reg.register(fused_relu_name("fc"), DeviceType::Fpga, 2);
        reg.register("relu", DeviceType::Cpu, 3);
        // The user pinned the relu to the CPU: fusing it into the FPGA
        // dispatch would silently override that annotation.
        g.set_device(r, DeviceType::Cpu);
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let mut fetched = all(&g, false);
        fetched[r.0] = true;
        let f = find_relu_fusions(&g, &p, &reg, &all(&g, true), &all(&g, false), &fetched);
        assert!(f.is_empty(), "explicit CPU pin on relu must block FPGA fusion");
        // Pinning it to the producer's own device keeps fusion legal.
        g.set_device(r, DeviceType::Fpga);
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let f = find_relu_fusions(&g, &p, &reg, &all(&g, true), &all(&g, false), &fetched);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].producer, y);
    }

    #[test]
    fn conv2d_relu_pair_fuses_under_its_padded_kernel_name() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 4, 4], DType::F32).unwrap();
        let w = g.constant("w", Tensor::zeros(&[2, 1, 3, 3], DType::F32)).unwrap();
        let b = g.constant("b", Tensor::zeros(&[2], DType::F32)).unwrap();
        let c = g.add("c", OpKind::Conv2dF32 { pad: 1 }, &[x, w, b]).unwrap();
        let r = g.add("r", OpKind::Relu, &[c]).unwrap();
        g.finalize().unwrap();
        let mut reg = KernelRegistry::new();
        reg.register("conv2d:p1", DeviceType::Cpu, 1);
        reg.register("relu", DeviceType::Cpu, 2);
        reg.register(fused_relu_name("conv2d:p1"), DeviceType::Cpu, 3);
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let mut fetched = all(&g, false);
        fetched[r.0] = true;
        let f = find_relu_fusions(&g, &p, &reg, &all(&g, true), &all(&g, false), &fetched);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].producer, f[0].activation), (c, r));
        assert_eq!(f[0].kernel, "conv2d:p1+relu");
    }

    #[test]
    fn second_consumer_blocks_fusion() {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 4], DType::F32).unwrap();
        let w = g.constant("w", Tensor::zeros(&[4, 2], DType::F32)).unwrap();
        let b = g.constant("b", Tensor::zeros(&[2], DType::F32)).unwrap();
        let y = g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
        let r = g.add("r", OpKind::Relu, &[y]).unwrap();
        let s = g.add("s", OpKind::Softmax, &[y]).unwrap(); // second consumer of y
        g.finalize().unwrap();
        let mut reg = registry(true);
        reg.register("softmax", DeviceType::Cpu, 4);
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let mut fetched = all(&g, false);
        fetched[r.0] = true;
        fetched[s.0] = true;
        let f = find_relu_fusions(&g, &p, &reg, &all(&g, true), &all(&g, false), &fetched);
        assert!(f.is_empty(), "y's value is needed by softmax too");
    }
}
