//! Compile-once / replay-many execution plans.
//!
//! [`Session::run`](crate::tf::session::Session::run) used to re-walk the
//! graph on every call: re-derive topological order and refcounts,
//! re-resolve placements, and clone tensors through a per-run `HashMap`.
//! An [`ExecutionPlan`] does all of that exactly once:
//!
//! 1. **Prune** — drop every node not reverse-reachable from the fetch set.
//! 2. **Fold** — evaluate const-only subgraphs at compile time (structural
//!    ops inline, compute ops via one real dispatch) and bake the results
//!    in as constants.
//! 3. **Fuse** — collapse `FullyConnected+Relu` / `Conv+Relu` pairs into a
//!    single dispatch when the backend registers a fused kernel
//!    (see [`crate::tf::fusion`]); otherwise keep the pair.
//! 4. **Allocate** — liveness analysis assigns every value a slot in a
//!    small reusable arena; the last consumer of a value *moves* it out of
//!    its slot instead of cloning, and dead slots are recycled for later
//!    outputs (only by steps already ordered after the slot's readers, so
//!    out-of-order replay can never clobber a live tensor).
//! 5. **Link** — each step gets a pre-resolved `(device, kernel_object)`
//!    and a dependency count, so replay is a counter-driven loop with no
//!    name or registry lookups.
//!
//! Replay issues every ready step immediately: inline steps run in place,
//! device steps are dispatched *asynchronously* onto their queue, so
//! independent steps on different devices (or on one device with a
//! processor pool) execute concurrently instead of the interpreted
//! executor's strictly serialized walk.

use crate::hsa::agent::DeviceType;
use crate::hsa::error::{message_indicates_agent_down, HsaError, Result};
use crate::hsa::packet::KernelArgs;
use crate::hsa::signal::Signal;
use crate::reconfig::scheduler::{KernelHorizon, PrefetchPolicy, PrefetchScheduler};
use crate::tf::dtype::DType;
use crate::tf::executor::{check_feed, check_kernel_output, ExecEnv, RunStats};
use crate::tf::fusion;
use crate::tf::graph::{Graph, NodeId, OpKind};
use crate::tf::kernel::KernelRegistry;
use crate::tf::placer::{Placement, PlacementMap};
use crate::tf::tensor::Tensor;
use std::collections::{HashMap, VecDeque};
use std::time::Instant;

/// Pass toggles (all on by default; tests flip them to compare paths).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanOptions {
    /// Collapse op+ReLU pairs into fused dispatches where registered.
    pub fusion: bool,
    /// Evaluate const-only subgraphs at compile time.
    pub fold_constants: bool,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions { fusion: true, fold_constants: true }
    }
}

/// What a step does when replayed.
#[derive(Debug, Clone)]
pub enum StepOp {
    /// Bind a fed placeholder tensor to the step's slot (validating shape
    /// and dtype against the graph's declaration). The bind is an Arc
    /// clone of the tensor's storage, never a data copy — which makes it
    /// the last link of the serving path's zero-copy chain: the HTTP
    /// worker decodes request rows straight into a batch lane's staging
    /// `Vec<f32>` (`serve::TensorWriter`), the batcher wraps that buffer
    /// into a [`Tensor`] without copying (`Tensor::from_f32`), and the
    /// feed here shares it with the executor by reference count alone.
    Feed { placeholder: String, shape: Vec<usize>, dtype: DType },
    /// Inline reshape (Arc'd storage: no data copy).
    Reshape { shape: Vec<usize> },
    /// One asynchronous kernel dispatch on a pre-resolved device queue.
    Dispatch { device: DeviceType, kernel_object: u64, kernel: String, fused: bool },
}

/// One input read: which arena slot, and whether this step may *move* the
/// tensor out (it is the value's only reader and the value is not fetched).
#[derive(Debug, Clone, Copy)]
pub struct SlotRead {
    pub slot: usize,
    pub take: bool,
    /// Value id expected in the slot (consumed by [`ExecutionPlan::validate`]).
    pub value: usize,
}

/// One replayable step.
#[derive(Debug, Clone)]
pub struct PlanStep {
    /// Node name (fused steps: `"producer+activation"`).
    pub name: String,
    pub op: StepOp,
    pub inputs: Vec<SlotRead>,
    pub out_slot: usize,
    /// Value id this step produces (for validation).
    pub out_value: usize,
    pub out_shape: Vec<usize>,
    pub out_dtype: DType,
    /// Number of producing steps that must complete before this one issues.
    pub num_deps: usize,
    /// Steps unblocked when this one completes.
    pub dependents: Vec<usize>,
}

/// Compile-time accounting.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanStats {
    pub graph_nodes: usize,
    /// Nodes dropped because nothing in the fetch set needs them.
    pub pruned_nodes: usize,
    /// Non-constant nodes evaluated at compile time (constant folding).
    pub folded_nodes: usize,
    /// Op pairs collapsed into fused dispatches.
    pub fused_pairs: usize,
    pub steps: usize,
    pub dispatch_steps: usize,
    /// Constants preloaded into the arena at the start of each replay.
    pub const_values: usize,
    /// Arena size — always ≤ live values thanks to slot recycling.
    pub slots: usize,
    pub compile_us: u128,
}

/// A compiled, replayable execution of one `(feeds, fetches)` shape of a
/// placed graph. See the module docs for the pass pipeline.
pub struct ExecutionPlan {
    steps: Vec<PlanStep>,
    /// `(slot, value id, tensor)` preloaded before the first step.
    consts: Vec<(usize, usize, Tensor)>,
    num_slots: usize,
    /// `(slot, value id)` per fetch, in fetch order.
    fetch_slots: Vec<(usize, usize)>,
    /// FPGA kernel objects in step order — the prefetch scheduler's
    /// compile-time view of what the replay is about to dispatch.
    horizon: KernelHorizon,
    stats: PlanStats,
}

impl ExecutionPlan {
    pub fn steps(&self) -> &[PlanStep] {
        &self.steps
    }

    pub fn stats(&self) -> &PlanStats {
        &self.stats
    }

    pub fn num_slots(&self) -> usize {
        self.num_slots
    }

    /// The FPGA dispatch sequence this plan will replay, in step order.
    /// Derived once at compile time; [`Self::replay_prefetched`] walks it
    /// with a cursor so the prefetch scheduler always knows which roles
    /// come next.
    pub fn horizon(&self) -> &KernelHorizon {
        &self.horizon
    }

    /// Compile the graph for one fetch set. `env` is used only at compile
    /// time, to evaluate const-only subgraphs with the real kernels.
    pub fn compile(
        graph: &Graph,
        placement: &PlacementMap,
        registry: &KernelRegistry,
        env: &ExecEnv<'_>,
        fetches: &[&str],
        opts: PlanOptions,
    ) -> Result<ExecutionPlan> {
        assert!(graph.is_finalized(), "finalize the graph before compiling");
        let t0 = Instant::now();

        let fetch_ids: Vec<NodeId> = fetches
            .iter()
            .map(|name| {
                graph.by_name(name).ok_or_else(|| {
                    HsaError::Runtime(format!("fetch '{name}' not in graph"))
                })
            })
            .collect::<Result<_>>()?;
        let mut fetched = vec![false; graph.len()];
        for &f in &fetch_ids {
            fetched[f.0] = true;
        }

        // Pass 1: prune — reverse reachability from the fetch set.
        let mut live = vec![false; graph.len()];
        let mut stack: Vec<NodeId> = fetch_ids.clone();
        while let Some(id) = stack.pop() {
            if live[id.0] {
                continue;
            }
            live[id.0] = true;
            stack.extend_from_slice(&graph.node(id).inputs);
        }
        let live_count = live.iter().filter(|&&l| l).count();

        // Pass 2: constant folding. `const_val[i]` holds the compile-time
        // value of node i if it is constant (Constant nodes always are;
        // with folding on, any live node whose inputs are all constant is
        // evaluated — structural ops inline, compute ops via one real
        // dispatch on the node's placed device).
        let mut const_val: Vec<Option<Tensor>> = vec![None; graph.len()];
        let mut folded_nodes = 0usize;
        for id in graph.topo_order() {
            if !live[id.0] {
                continue;
            }
            let node = graph.node(id);
            match &node.op {
                OpKind::Constant(t) => const_val[id.0] = Some(t.clone()),
                OpKind::Placeholder { .. } => {}
                _ => {
                    if !opts.fold_constants
                        || node.inputs.iter().any(|i| const_val[i.0].is_none())
                    {
                        continue;
                    }
                    let inputs: Vec<Tensor> = node
                        .inputs
                        .iter()
                        .map(|i| const_val[i.0].clone().unwrap())
                        .collect();
                    let out = match placement.by_node.get(&id) {
                        Some(Placement::Inline) | None => match &node.op {
                            OpKind::Reshape { shape } => inputs[0].reshape(shape)?,
                            other => {
                                return Err(HsaError::Runtime(format!(
                                    "op {other:?} is not inline-executable"
                                )))
                            }
                        },
                        Some(Placement::Device { device, kernel_object }) => {
                            let (queue, _route) = env.route(*device, *kernel_object)?;
                            let outs =
                                env.runtime.dispatch_sync(&queue, *kernel_object, inputs)?;
                            // Shape checked below (shared with the reshape branch).
                            check_kernel_output(&node.name, &[], outs)?
                        }
                    };
                    if !node.out_shape.is_empty() && out.shape() != node.out_shape.as_slice()
                    {
                        return Err(HsaError::Runtime(format!(
                            "node '{}': kernel produced {:?}, inference said {:?}",
                            node.name,
                            out.shape(),
                            node.out_shape
                        )));
                    }
                    const_val[id.0] = Some(out);
                    folded_nodes += 1;
                }
            }
        }
        let is_const: Vec<bool> = const_val.iter().map(|v| v.is_some()).collect();

        // Pass 3: fusion over the live, non-constant remainder.
        let fusions = if opts.fusion {
            fusion::find_relu_fusions(graph, placement, registry, &live, &is_const, &fetched)
        } else {
            Vec::new()
        };
        let mut fused_by_producer: HashMap<NodeId, fusion::Fusion> = HashMap::new();
        let mut fused_activation = vec![false; graph.len()];
        for f in fusions {
            fused_activation[f.activation.0] = true;
            fused_by_producer.insert(f.producer, f);
        }
        let fused_pairs = fused_by_producer.len();

        // Pass 4: emit steps in topological order.
        struct EmitStep {
            out_node: NodeId,
            name: String,
            op: StepOp,
            input_nodes: Vec<NodeId>,
            out_shape: Vec<usize>,
            out_dtype: DType,
        }
        let mut emits: Vec<EmitStep> = Vec::new();
        for id in graph.topo_order() {
            if !live[id.0] || is_const[id.0] || fused_activation[id.0] {
                continue;
            }
            let node = graph.node(id);
            if let Some(f) = fused_by_producer.get(&id) {
                let act = graph.node(f.activation);
                emits.push(EmitStep {
                    out_node: f.activation,
                    name: format!("{}+{}", node.name, act.name),
                    op: StepOp::Dispatch {
                        device: f.device,
                        kernel_object: f.kernel_object,
                        kernel: f.kernel.clone(),
                        fused: true,
                    },
                    input_nodes: node.inputs.clone(),
                    out_shape: act.out_shape.clone(),
                    out_dtype: act.out_dtype,
                });
                continue;
            }
            let op = match &node.op {
                OpKind::Placeholder { shape, dtype } => StepOp::Feed {
                    placeholder: node.name.clone(),
                    shape: shape.clone(),
                    dtype: *dtype,
                },
                OpKind::Constant(_) => unreachable!("constants are folded"),
                OpKind::Reshape { shape } => StepOp::Reshape { shape: shape.clone() },
                other => match placement.by_node.get(&id) {
                    Some(Placement::Device { device, kernel_object }) => StepOp::Dispatch {
                        device: *device,
                        kernel_object: *kernel_object,
                        kernel: other.kernel_name().unwrap_or_default(),
                        fused: false,
                    },
                    _ => {
                        return Err(HsaError::Runtime(format!(
                            "op {other:?} is not inline-executable"
                        )))
                    }
                },
            };
            emits.push(EmitStep {
                out_node: id,
                name: node.name.clone(),
                op,
                input_nodes: node.inputs.clone(),
                out_shape: node.out_shape.clone(),
                out_dtype: node.out_dtype,
            });
        }

        // Value numbering: constants that something still reads (folding
        // can orphan a Constant's direct value), then one value per step.
        let mut used_const = vec![false; graph.len()];
        for e in &emits {
            for &n in &e.input_nodes {
                if is_const[n.0] {
                    used_const[n.0] = true;
                }
            }
        }
        for &f in &fetch_ids {
            if is_const[f.0] {
                used_const[f.0] = true;
            }
        }
        let mut value_of_node: Vec<Option<usize>> = vec![None; graph.len()];
        let mut const_tensors: Vec<Tensor> = Vec::new();
        for (i, used) in used_const.iter().enumerate() {
            if *used {
                value_of_node[i] = Some(const_tensors.len());
                const_tensors.push(const_val[i].clone().unwrap());
            }
        }
        let num_const_values = const_tensors.len();
        for (si, e) in emits.iter().enumerate() {
            value_of_node[e.out_node.0] = Some(num_const_values + si);
        }
        let num_values = num_const_values + emits.len();

        // Liveness: per value, the reading steps and the last read.
        let mut step_inputs: Vec<Vec<usize>> = Vec::with_capacity(emits.len());
        let mut last_use: Vec<Option<usize>> = vec![None; num_values];
        let mut readers: Vec<Vec<usize>> = vec![Vec::new(); num_values];
        for (si, e) in emits.iter().enumerate() {
            let vals: Vec<usize> = e
                .input_nodes
                .iter()
                .map(|n| {
                    value_of_node[n.0].ok_or_else(|| {
                        HsaError::Runtime(format!(
                            "plan: input of '{}' has no value (internal)",
                            e.name
                        ))
                    })
                })
                .collect::<Result<_>>()?;
            for &v in &vals {
                last_use[v] = Some(si);
                if readers[v].last() != Some(&si) {
                    readers[v].push(si);
                }
            }
            step_inputs.push(vals);
        }
        let mut value_fetched = vec![false; num_values];
        let mut fetch_values = Vec::with_capacity(fetch_ids.len());
        for &f in &fetch_ids {
            let v = value_of_node[f.0].ok_or_else(|| {
                HsaError::Runtime("plan: fetch lost during compilation (internal)".into())
            })?;
            value_fetched[v] = true;
            fetch_values.push(v);
        }

        // Pass 5: slot assignment + dependency edges.
        let mut slot_of_value = vec![usize::MAX; num_values];
        let mut num_slots = 0usize;
        for slot in slot_of_value.iter_mut().take(num_const_values) {
            *slot = num_slots;
            num_slots += 1;
        }
        // Freed slots carry the step indices that read the previous
        // occupant: a slot may only be recycled by a step that already
        // depends on all of them, otherwise an out-of-order replay could
        // overwrite a tensor a not-yet-issued step still needs.
        let mut free: Vec<(usize, Vec<usize>)> = Vec::new();
        let mut steps: Vec<PlanStep> = Vec::with_capacity(emits.len());
        let mut deps_per_step: Vec<Vec<usize>> = Vec::with_capacity(emits.len());
        for (si, e) in emits.iter().enumerate() {
            let vals = &step_inputs[si];
            let mut deps: Vec<usize> = Vec::new();
            for &v in vals {
                if v >= num_const_values {
                    let p = v - num_const_values;
                    if !deps.contains(&p) {
                        deps.push(p);
                    }
                }
            }
            let mut inputs = Vec::with_capacity(vals.len());
            for (k, &v) in vals.iter().enumerate() {
                // Move-out is only safe when no other step ever reads the
                // value (replay is out of order across independent steps).
                let take = readers[v].len() == 1
                    && readers[v][0] == si
                    && !value_fetched[v]
                    && !vals[k + 1..].contains(&v);
                inputs.push(SlotRead { slot: slot_of_value[v], take, value: v });
            }
            let mut freed_here: Vec<usize> = Vec::new();
            for &v in vals {
                if last_use[v] == Some(si) && !value_fetched[v] && !freed_here.contains(&v)
                {
                    freed_here.push(v);
                    free.push((slot_of_value[v], readers[v].clone()));
                }
            }
            let reusable = free.iter().position(|(_, war)| {
                war.iter().all(|&r| r == si || deps.contains(&r))
            });
            let out_slot = match reusable {
                Some(ix) => free.remove(ix).0,
                None => {
                    let s = num_slots;
                    num_slots += 1;
                    s
                }
            };
            slot_of_value[num_const_values + si] = out_slot;
            steps.push(PlanStep {
                name: e.name.clone(),
                op: e.op.clone(),
                inputs,
                out_slot,
                out_value: num_const_values + si,
                out_shape: e.out_shape.clone(),
                out_dtype: e.out_dtype,
                num_deps: deps.len(),
                dependents: Vec::new(),
            });
            deps_per_step.push(deps);
        }
        for (si, deps) in deps_per_step.iter().enumerate() {
            for &p in deps {
                steps[p].dependents.push(si);
            }
        }

        let consts: Vec<(usize, usize, Tensor)> = const_tensors
            .into_iter()
            .enumerate()
            .map(|(v, t)| (slot_of_value[v], v, t))
            .collect();
        let fetch_slots: Vec<(usize, usize)> =
            fetch_values.iter().map(|&v| (slot_of_value[v], v)).collect();

        let dispatch_steps =
            steps.iter().filter(|s| matches!(s.op, StepOp::Dispatch { .. })).count();
        let horizon = KernelHorizon::new(
            steps
                .iter()
                .filter_map(|s| match &s.op {
                    StepOp::Dispatch { device, kernel_object, .. }
                        if *device == DeviceType::Fpga =>
                    {
                        Some(*kernel_object)
                    }
                    _ => None,
                })
                .collect(),
        );
        let plan = ExecutionPlan {
            stats: PlanStats {
                graph_nodes: graph.len(),
                pruned_nodes: graph.len() - live_count,
                folded_nodes,
                fused_pairs,
                steps: steps.len(),
                dispatch_steps,
                const_values: num_const_values,
                slots: num_slots,
                compile_us: t0.elapsed().as_micros(),
            },
            steps,
            consts,
            num_slots,
            fetch_slots,
            horizon,
        };
        plan.validate().map_err(|e| {
            HsaError::Runtime(format!("plan failed self-validation (internal): {e}"))
        })?;
        Ok(plan)
    }

    /// Symbolically execute the plan in program order, checking that every
    /// step finds exactly the value it expects in each slot — i.e. that
    /// the slot allocator never aliased two live tensors and every fetch
    /// survives to the end.
    pub fn validate(&self) -> std::result::Result<(), String> {
        let mut slots: Vec<Option<usize>> = vec![None; self.num_slots];
        for (slot, value, _) in &self.consts {
            if slots[*slot].is_some() {
                return Err(format!("two constants share slot {slot}"));
            }
            slots[*slot] = Some(*value);
        }
        for (si, step) in self.steps.iter().enumerate() {
            for r in &step.inputs {
                if slots[r.slot] != Some(r.value) {
                    return Err(format!(
                        "step {si} '{}' expected value {} in slot {}, found {:?}",
                        step.name, r.value, r.slot, slots[r.slot]
                    ));
                }
            }
            for r in &step.inputs {
                if r.take {
                    slots[r.slot] = None;
                }
            }
            slots[step.out_slot] = Some(step.out_value);
        }
        for (slot, value) in &self.fetch_slots {
            if slots[*slot] != Some(*value) {
                return Err(format!(
                    "fetch value {value} no longer in slot {slot}: {:?}",
                    slots[*slot]
                ));
            }
        }
        Ok(())
    }

    /// Replay the plan: dependency-counter scheduling, asynchronous device
    /// dispatch (independent steps overlap across queues), slot-arena
    /// tensor traffic.
    pub fn replay(
        &self,
        env: &ExecEnv<'_>,
        feeds: &HashMap<String, Tensor>,
    ) -> Result<(Vec<Tensor>, RunStats)> {
        self.replay_prefetched(env, feeds, PrefetchPolicy::disabled())
    }

    /// [`replay`](ExecutionPlan::replay) plus predictive reconfiguration:
    /// after each FPGA dispatch issues, the prefetch scheduler walks the
    /// plan's [`KernelHorizon`] from the current cursor and starts
    /// background ICAP loads for upcoming roles (see
    /// [`PrefetchScheduler::pump`]). With the policy disabled (the
    /// default) or no shard router in the env, this is byte-for-byte the
    /// plain replay. The cursor counts *issued* FPGA dispatches, which for
    /// plans with parallel branches is an approximation of the horizon
    /// position — prefetching a role slightly early or late is a
    /// performance wobble, never a correctness issue (the scheduler never
    /// evicts the role at or just before the cursor).
    pub fn replay_prefetched(
        &self,
        env: &ExecEnv<'_>,
        feeds: &HashMap<String, Tensor>,
        prefetch: PrefetchPolicy,
    ) -> Result<(Vec<Tensor>, RunStats)> {
        self.replay_traced(env, feeds, prefetch, None)
    }

    /// [`replay_prefetched`](ExecutionPlan::replay_prefetched) plus
    /// per-step dispatch tracing: with `trace` set to a recorder and a
    /// track name, every placed dispatch emits one Chrome-trace event
    /// (issue → harvest window, lane = the routed agent slot) onto that
    /// track. `None` is byte-for-byte the untraced replay.
    pub fn replay_traced(
        &self,
        env: &ExecEnv<'_>,
        feeds: &HashMap<String, Tensor>,
        prefetch: PrefetchPolicy,
        trace: Option<(&crate::trace::TraceRecorder, &str)>,
    ) -> Result<(Vec<Tensor>, RunStats)> {
        let t0 = Instant::now();
        let mut prefetcher = (prefetch.enabled && env.router.is_some())
            .then(|| PrefetchScheduler::new(prefetch));
        let mut fpga_cursor = 0usize;
        // Note: constants are *preloaded*, not executed, so they do not
        // count toward `inline_ops` — replay reports only the structural
        // work it actually performs (feeds and reshapes). The interpreter
        // counts constant nodes it executes, so the two paths' inline_ops
        // are intentionally not comparable; `dispatches` is.
        let mut stats = RunStats { plan_steps: self.steps.len() as u64, ..Default::default() };
        let mut values: Vec<Option<Tensor>> = vec![None; self.num_slots];
        for (slot, _, t) in &self.consts {
            values[*slot] = Some(t.clone());
        }
        let mut remaining: Vec<usize> = self.steps.iter().map(|s| s.num_deps).collect();
        let mut ready: VecDeque<usize> = (0..self.steps.len())
            .filter(|&i| self.steps[i].num_deps == 0)
            .collect();
        // In-flight dispatches carry their route guard (if shard-routed)
        // so the chosen agent's load gauge stays accurate until harvest,
        // plus the router slot index so a harvest stuck on a dying agent
        // can quarantine it and retry the step elsewhere.
        type InFlightStep = (
            usize,
            Signal,
            KernelArgs,
            Option<crate::sharding::RouteGuard>,
            Option<usize>,
            // Issue timestamp (recorder-epoch µs; 0 when untraced) for the
            // per-step dispatch event emitted at harvest.
            u64,
        );
        let mut inflight: VecDeque<InFlightStep> = VecDeque::new();
        let mut done = 0usize;

        while done < self.steps.len() {
            while let Some(i) = ready.pop_front() {
                let step = &self.steps[i];
                let mut ins: Vec<Tensor> = Vec::with_capacity(step.inputs.len());
                for r in &step.inputs {
                    let t = if r.take {
                        values[r.slot].take()
                    } else {
                        values[r.slot].clone()
                    };
                    ins.push(t.ok_or_else(|| {
                        HsaError::Runtime(format!("input of '{}' missing", step.name))
                    })?);
                }
                match &step.op {
                    StepOp::Feed { placeholder, shape, dtype } => {
                        let t = feeds.get(placeholder).ok_or_else(|| {
                            HsaError::Runtime(format!(
                                "placeholder '{placeholder}' not fed"
                            ))
                        })?;
                        check_feed(placeholder, shape, *dtype, t)?;
                        stats.inline_ops += 1;
                        values[step.out_slot] = Some(t.clone());
                        complete(i, &self.steps, &mut remaining, &mut ready, &mut done);
                    }
                    StepOp::Reshape { shape } => {
                        stats.inline_ops += 1;
                        values[step.out_slot] = Some(ins.swap_remove(0).reshape(shape)?);
                        complete(i, &self.steps, &mut remaining, &mut ready, &mut done);
                    }
                    StepOp::Dispatch { device, kernel_object, fused, .. } => {
                        // Shard-routed per step: independent steps of one
                        // replay fan out across the FPGA pool.
                        let (slot, queue, route) =
                            env.route_indexed(*device, *kernel_object)?;
                        stats.dispatches += 1;
                        *stats.dispatches_by_device.entry(*device).or_insert(0) += 1;
                        if *fused {
                            stats.fused_dispatches += 1;
                        }
                        let (sig, args) =
                            env.runtime.dispatch_async(&queue, *kernel_object, ins)?;
                        let issued_us = trace.map_or(0, |(tr, _)| tr.now_us());
                        inflight.push_back((i, sig, args, route, slot, issued_us));
                        if *device == DeviceType::Fpga {
                            fpga_cursor += 1;
                            if let (Some(p), Some(router)) =
                                (prefetcher.as_mut(), env.router)
                            {
                                p.pump(router, &self.horizon, fpga_cursor);
                            }
                        }
                    }
                }
            }
            if done == self.steps.len() {
                break;
            }
            // Harvest the oldest in-flight dispatch (the others keep
            // executing on their queues meanwhile). The route guard drops
            // at the end of this harvest, retiring the agent's gauge.
            // When the dispatch is shard-routed, harvesting probes the
            // completion signal in health-policy slices; a dispatch wedged
            // on (or failed by) a down agent is retried on an alternate
            // agent, bounded by max_retries and the dispatch deadline.
            let (i, mut sig, mut args, mut route, mut slot, issued_us) =
                inflight.pop_front().ok_or_else(|| {
                    HsaError::Runtime(
                        "plan replay stalled with no work in flight (internal)".into(),
                    )
                })?;
            let deadline = Instant::now() + crate::hsa::runtime::DISPATCH_TIMEOUT;
            let mut attempts: u32 = 0;
            let outs = loop {
                let mut retry_stalled = false;
                match env.router {
                    Some(router) if slot.is_some() => {
                        let policy = router.health_policy().clone();
                        loop {
                            if sig.wait_eq(0, Some(policy.probe_interval)).is_ok() {
                                break;
                            }
                            router.check_health();
                            if router.is_quarantined(slot.unwrap())
                                && attempts < policy.max_retries
                                && Instant::now() < deadline
                            {
                                retry_stalled = true;
                                break;
                            }
                            if Instant::now() >= deadline {
                                return Err(HsaError::SignalTimeout(
                                    crate::hsa::runtime::DISPATCH_TIMEOUT,
                                ));
                            }
                        }
                    }
                    _ => sig.wait_eq(0, Some(crate::hsa::runtime::DISPATCH_TIMEOUT))?,
                }
                if retry_stalled {
                    // Wedged on a quarantined agent. Park the old dispatch
                    // as a zombie — its guard keeps the load gauge truthful
                    // until the stall actually resolves — and fall through
                    // to re-dispatch.
                    let router = env.router.unwrap();
                    if let Some(guard) = route.take() {
                        router.park_zombie(sig.clone(), guard);
                    }
                    router.note_retry(slot.unwrap());
                } else {
                    match args.take_output() {
                        Some(Ok(outs)) => break outs,
                        Some(Err(msg)) => {
                            let retryable = env.router.is_some()
                                && slot.is_some()
                                && message_indicates_agent_down(&msg)
                                && attempts
                                    < env.router.unwrap().health_policy().max_retries
                                && Instant::now() < deadline;
                            if !retryable {
                                return Err(HsaError::KernelFailed(msg));
                            }
                            // The agent itself reported down (killed or a
                            // drop fault): quarantine it immediately so the
                            // re-route below cannot land back on it.
                            let router = env.router.unwrap();
                            router.quarantine(slot.unwrap());
                            router.note_retry(slot.unwrap());
                            route = None;
                        }
                        None => {
                            return Err(HsaError::KernelFailed(
                                "kernel retired without writing outputs".into(),
                            ))
                        }
                    }
                }
                attempts += 1;
                let (device, kernel_object) = match &self.steps[i].op {
                    StepOp::Dispatch { device, kernel_object, .. } => {
                        (*device, *kernel_object)
                    }
                    _ => {
                        return Err(HsaError::Runtime(
                            "non-dispatch step in flight (internal)".into(),
                        ))
                    }
                };
                let ins = args.inputs.clone();
                let (new_slot, queue, new_route) =
                    env.route_indexed(device, kernel_object)?;
                let (new_sig, new_args) =
                    env.runtime.dispatch_async(&queue, kernel_object, ins)?;
                sig = new_sig;
                args = new_args;
                route = new_route;
                slot = new_slot;
            };
            let step = &self.steps[i];
            if let Some((tr, track)) = trace {
                let now = tr.now_us();
                tr.record(
                    crate::trace::EventKind::Dispatch,
                    step.name.clone(),
                    track,
                    slot.map_or(0, |s| s as u32),
                    issued_us,
                    now.saturating_sub(issued_us).max(1),
                );
            }
            let out = check_kernel_output(&step.name, &step.out_shape, outs)?;
            values[step.out_slot] = Some(out);
            complete(i, &self.steps, &mut remaining, &mut ready, &mut done);
        }

        let mut results = Vec::with_capacity(self.fetch_slots.len());
        for (slot, _) in &self.fetch_slots {
            results.push(values[*slot].clone().ok_or_else(|| {
                HsaError::Runtime("fetch missing after replay (internal)".into())
            })?);
        }
        stats.wall_us = t0.elapsed().as_micros();
        Ok((results, stats))
    }
}

fn complete(
    i: usize,
    steps: &[PlanStep],
    remaining: &mut [usize],
    ready: &mut VecDeque<usize>,
    done: &mut usize,
) {
    *done += 1;
    for &d in &steps[i].dependents {
        remaining[d] -= 1;
        if remaining[d] == 0 {
            ready.push_back(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::a53::CpuKernelClass;
    use crate::cpu::device::{CpuAgent, CpuKernel};
    use crate::hsa::queue::Queue;
    use crate::hsa::runtime::HsaRuntime;
    use crate::tf::kernel::fused_relu_name;
    use crate::tf::placer::{place, PlacerOptions};
    use std::sync::Arc;

    fn cpu_env(
        with_fused: bool,
    ) -> (HsaRuntime, HashMap<DeviceType, Queue>, KernelRegistry) {
        let cpu = CpuAgent::with_defaults();
        let mut reg = KernelRegistry::new();
        let mut add = |name: &str,
                       f: Arc<dyn Fn(&[Tensor]) -> Result<Vec<Tensor>> + Send + Sync>| {
            let id = cpu.register_kernel(CpuKernel {
                name: name.into(),
                func: f,
                class: CpuKernelClass::Memory,
                op_template: None,
            });
            reg.register(name, DeviceType::Cpu, id);
        };
        add("fc", Arc::new(|ins| Ok(vec![crate::ops::fc_f32(&ins[0], &ins[1], &ins[2])?])));
        add("relu", Arc::new(|ins| Ok(vec![crate::ops::relu_f32(&ins[0])?])));
        add("add", Arc::new(|ins| Ok(vec![crate::ops::add_f32(&ins[0], &ins[1])?])));
        add("softmax", Arc::new(|ins| Ok(vec![crate::ops::softmax_f32(&ins[0])?])));
        if with_fused {
            add(
                &fused_relu_name("fc"),
                Arc::new(|ins| Ok(vec![crate::ops::fc_relu_f32(&ins[0], &ins[1], &ins[2])?])),
            );
        }
        let rt = HsaRuntime::builder().with_agent(cpu).build();
        let q = rt.create_queue(rt.agent_by_type(DeviceType::Cpu).unwrap(), 64);
        let mut queues = HashMap::new();
        queues.insert(DeviceType::Cpu, q);
        (rt, queues, reg)
    }

    fn fc_relu_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.placeholder("x", &[2, 3], DType::F32).unwrap();
        let w = g
            .constant("w", Tensor::from_f32(&[3, 2], vec![1.0, -1.0, 0.5, 0.5, -2.0, 2.0]).unwrap())
            .unwrap();
        let b = g.constant("b", Tensor::from_f32(&[2], vec![0.25, -0.25]).unwrap()).unwrap();
        let y = g.add("y", OpKind::FullyConnected, &[x, w, b]).unwrap();
        g.add("out", OpKind::Relu, &[y]).unwrap();
        g.finalize().unwrap();
        g
    }

    fn feeds(x: Tensor) -> HashMap<String, Tensor> {
        let mut m = HashMap::new();
        m.insert("x".to_string(), x);
        m
    }

    #[test]
    fn fusion_halves_dispatches_and_matches_interpreter() {
        let (rt, queues, reg) = cpu_env(true);
        let g = fc_relu_graph();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let x = Tensor::from_f32(&[2, 3], vec![1.0, -2.0, 0.5, 0.0, 3.0, -1.0]).unwrap();

        let plan =
            ExecutionPlan::compile(&g, &p, &reg, &env, &["out"], PlanOptions::default())
                .unwrap();
        assert_eq!(plan.stats().fused_pairs, 1);
        assert_eq!(plan.stats().dispatch_steps, 1, "FC+Relu is one fused dispatch");
        let (outs, stats) = plan.replay(&env, &feeds(x.clone())).unwrap();
        assert_eq!(stats.dispatches, 1);
        assert_eq!(stats.fused_dispatches, 1);

        let (ref_outs, ref_stats) =
            crate::tf::executor::run(&g, &p, &env, &feeds(x), &["out"]).unwrap();
        assert_eq!(ref_stats.dispatches, 2, "interpreter never fuses");
        assert_eq!(outs[0], ref_outs[0], "fused replay must be bitwise identical");
        assert!(stats.dispatches < ref_stats.dispatches);
        rt.shutdown();
    }

    #[test]
    fn fusion_falls_back_cleanly_without_fused_kernel() {
        let (rt, queues, reg) = cpu_env(false);
        let g = fc_relu_graph();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let plan =
            ExecutionPlan::compile(&g, &p, &reg, &env, &["out"], PlanOptions::default())
                .unwrap();
        assert_eq!(plan.stats().fused_pairs, 0);
        assert_eq!(plan.stats().dispatch_steps, 2, "unfused pair survives");
        let x = Tensor::from_f32(&[2, 3], vec![0.5; 6]).unwrap();
        let (outs, stats) = plan.replay(&env, &feeds(x.clone())).unwrap();
        assert_eq!(stats.dispatches, 2);
        assert_eq!(stats.fused_dispatches, 0);
        let (ref_outs, _) =
            crate::tf::executor::run(&g, &p, &env, &feeds(x), &["out"]).unwrap();
        assert_eq!(outs[0], ref_outs[0]);
        rt.shutdown();
    }

    #[test]
    fn constant_folding_removes_const_only_chains() {
        // relu(w) is const-only: folded at compile time; only the add of
        // the placeholder remains a dispatch.
        let (rt, queues, reg) = cpu_env(false);
        let mut g = Graph::new();
        let x = g.placeholder("x", &[2, 2], DType::F32).unwrap();
        let w = g
            .constant("w", Tensor::from_f32(&[2, 2], vec![-1.0, 2.0, -3.0, 4.0]).unwrap())
            .unwrap();
        let r = g.add("rw", OpKind::Relu, &[w]).unwrap();
        g.add("out", OpKind::Add, &[x, r]).unwrap();
        g.finalize().unwrap();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };

        let plan =
            ExecutionPlan::compile(&g, &p, &reg, &env, &["out"], PlanOptions::default())
                .unwrap();
        assert_eq!(plan.stats().folded_nodes, 1, "relu(const) folded");
        assert_eq!(plan.stats().dispatch_steps, 1, "only the add dispatches");
        let x = Tensor::from_f32(&[2, 2], vec![1.0; 4]).unwrap();
        let (outs, stats) = plan.replay(&env, &feeds(x)).unwrap();
        assert_eq!(stats.dispatches, 1);
        assert_eq!(outs[0].as_f32().unwrap(), &[1.0, 3.0, 1.0, 5.0]);

        // With folding off the chain stays in the plan.
        let plan2 = ExecutionPlan::compile(
            &g,
            &p,
            &reg,
            &env,
            &["out"],
            PlanOptions { fold_constants: false, ..Default::default() },
        )
        .unwrap();
        assert_eq!(plan2.stats().folded_nodes, 0);
        assert_eq!(plan2.stats().dispatch_steps, 2);
        rt.shutdown();
    }

    #[test]
    fn pruning_drops_nodes_outside_fetch_cone() {
        let (rt, queues, reg) = cpu_env(false);
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 2], DType::F32).unwrap();
        g.add("dead", OpKind::Relu, &[x]).unwrap();
        let live = g.add("live", OpKind::Relu, &[x]).unwrap();
        g.add("also_dead", OpKind::Softmax, &[live]).unwrap();
        g.finalize().unwrap();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let plan =
            ExecutionPlan::compile(&g, &p, &reg, &env, &["live"], PlanOptions::default())
                .unwrap();
        assert_eq!(plan.stats().pruned_nodes, 2);
        assert_eq!(plan.stats().dispatch_steps, 1);
        let (outs, stats) =
            plan.replay(&env, &feeds(Tensor::from_f32(&[1, 2], vec![-1.0, 2.0]).unwrap()))
                .unwrap();
        assert_eq!(stats.dispatches, 1, "dead relu and softmax never dispatch");
        assert_eq!(outs[0].as_f32().unwrap(), &[0.0, 2.0]);
        rt.shutdown();
    }

    #[test]
    fn slot_arena_reuses_slots_without_aliasing() {
        // A long chain must execute in a small arena; a diamond must keep
        // both live branches in distinct slots. validate() proves no
        // aliasing; the stats prove reuse actually happened.
        let (rt, queues, reg) = cpu_env(false);
        let mut g = Graph::new();
        let x = g.placeholder("x", &[1, 4], DType::F32).unwrap();
        let mut prev = x;
        for i in 0..6 {
            prev = g.add(format!("r{i}"), OpKind::Relu, &[prev]).unwrap();
        }
        let a = g.add("a", OpKind::Relu, &[prev]).unwrap();
        let b = g.add("b", OpKind::Softmax, &[prev]).unwrap();
        g.add("sum", OpKind::Add, &[a, b]).unwrap();
        g.finalize().unwrap();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let plan =
            ExecutionPlan::compile(&g, &p, &reg, &env, &["sum"], PlanOptions::default())
                .unwrap();
        plan.validate().expect("no two live tensors may share a slot");
        assert!(
            plan.num_slots() < plan.steps().len(),
            "chain slots must be recycled: {} slots for {} steps",
            plan.num_slots(),
            plan.steps().len()
        );
        let x = Tensor::from_f32(&[1, 4], vec![0.1, 0.2, 0.3, 0.4]).unwrap();
        let (outs, _) = plan.replay(&env, &feeds(x.clone())).unwrap();
        let (ref_outs, _) =
            crate::tf::executor::run(&g, &p, &env, &feeds(x), &["sum"]).unwrap();
        assert_eq!(outs[0], ref_outs[0]);
        rt.shutdown();
    }

    #[test]
    fn fetched_intermediate_is_never_moved_or_clobbered() {
        let (rt, queues, reg) = cpu_env(true);
        let g = fc_relu_graph();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        // Fetching "y" blocks fusion and pins y's slot for the whole run.
        let plan =
            ExecutionPlan::compile(&g, &p, &reg, &env, &["out", "y"], PlanOptions::default())
                .unwrap();
        assert_eq!(plan.stats().fused_pairs, 0, "fetched intermediate blocks fusion");
        let x = Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, -2.0, -3.0]).unwrap();
        let (outs, _) = plan.replay(&env, &feeds(x.clone())).unwrap();
        let (ref_outs, _) =
            crate::tf::executor::run(&g, &p, &env, &feeds(x), &["out", "y"]).unwrap();
        assert_eq!(outs[0], ref_outs[0]);
        assert_eq!(outs[1], ref_outs[1]);
        rt.shutdown();
    }

    #[test]
    fn unknown_fetch_fails_at_compile_time() {
        let (rt, queues, reg) = cpu_env(false);
        let g = fc_relu_graph();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let err =
            ExecutionPlan::compile(&g, &p, &reg, &env, &["zzz"], PlanOptions::default())
                .unwrap_err();
        assert!(err.to_string().contains("zzz"), "{err}");
        rt.shutdown();
    }

    #[test]
    fn replay_validates_feeds_like_the_interpreter() {
        let (rt, queues, reg) = cpu_env(false);
        let g = fc_relu_graph();
        let p = place(&g, &reg, PlacerOptions::default()).unwrap();
        let env = ExecEnv { runtime: &rt, queues: &queues, router: None };
        let plan =
            ExecutionPlan::compile(&g, &p, &reg, &env, &["out"], PlanOptions::default())
                .unwrap();
        let err = plan.replay(&env, &HashMap::new()).unwrap_err();
        assert!(err.to_string().contains("not fed"), "{err}");
        let err = plan
            .replay(&env, &feeds(Tensor::zeros(&[3, 3], DType::F32)))
            .unwrap_err();
        assert!(err.to_string().contains("expected"), "{err}");
        rt.shutdown();
    }
}
