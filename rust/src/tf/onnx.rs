//! ONNX front door: a minimal, dependency-free ONNX importer.
//!
//! Parses the ONNX protobuf wire format by hand (the repo vendors no
//! protobuf crate), maps the op subset real TinyML models actually use
//! onto [`OpKind`], folds `BatchNormalization` into the weights of the
//! preceding Conv/Gemm at import time, and emits a validated
//! [`ModelBundle`] the serving stack hosts exactly like a Python-exported
//! bundle.
//!
//! Supported ops: `Conv` (stride 1, symmetric pads), `Relu`, `MaxPool`
//! (2x2 stride 2, floor mode — the exact semantics of `maxpool2_f32`'s
//! drop-trailing behavior), `Add` (residual), `BatchNormalization`
//! (folded away), `Gemm`, `MatMul`, `Flatten`, `Reshape` (to rank 2),
//! `GlobalAveragePool`, `Concat`, `Softmax`, `Identity`.
//!
//! Error contract: every failure is a named `onnx import:` error that
//! says which node and which constraint — the transparent-acceleration
//! story is "run it, or say exactly why not", never silently degrade.
//!
//! Shape convention: ONNX models are batch-leading (`NCHW` / `NxK`). Our
//! graphs serve batches along dim 0 of a rank-2 tensor, and convolutions
//! operate on rank-3 `(C, H, W)` activations. A rank-4 ONNX input
//! `(1, C, H, W)` therefore becomes a `[1, C, H, W]` placeholder followed
//! by a `Reshape` to `[C, H, W]` (node `{input}/chw`), served at
//! `max_batch = 1`; a rank-2 input `(1, N)` maps directly.

use crate::hsa::error::{HsaError, Result};
use crate::tf::dtype::DType;
use crate::tf::graph::{Graph, NodeId, OpKind};
use crate::tf::model::{Endpoint, ModelBundle, Signature, SERVE_SIGNATURE};
use crate::tf::tensor::Tensor;
use std::collections::HashMap;
use std::path::Path;

fn err(msg: impl Into<String>) -> HsaError {
    HsaError::Runtime(format!("onnx import: {}", msg.into()))
}

// ---------------------------------------------------------------------------
// Protobuf wire-format reader.
// ---------------------------------------------------------------------------

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, i: 0 }
    }

    fn done(&self) -> bool {
        self.i >= self.b.len()
    }

    fn byte(&mut self) -> Result<u8> {
        let v = *self.b.get(self.i).ok_or_else(|| err("truncated protobuf"))?;
        self.i += 1;
        Ok(v)
    }

    fn varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        for shift in (0..64).step_by(7) {
            let byte = self.byte()?;
            v |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err(err("varint longer than 10 bytes"))
    }

    fn tag(&mut self) -> Result<(u64, u8)> {
        let v = self.varint()?;
        Ok((v >> 3, (v & 7) as u8))
    }

    fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.varint()? as usize;
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| err("length-delimited field overruns buffer"))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn fixed32(&mut self) -> Result<u32> {
        let mut a = [0u8; 4];
        for slot in &mut a {
            *slot = self.byte()?;
        }
        Ok(u32::from_le_bytes(a))
    }

    fn fixed64(&mut self) -> Result<u64> {
        let mut a = [0u8; 8];
        for slot in &mut a {
            *slot = self.byte()?;
        }
        Ok(u64::from_le_bytes(a))
    }

    fn skip(&mut self, wire: u8) -> Result<()> {
        match wire {
            0 => {
                self.varint()?;
            }
            1 => {
                self.fixed64()?;
            }
            2 => {
                self.bytes()?;
            }
            5 => {
                self.fixed32()?;
            }
            w => return Err(err(format!("unsupported wire type {w} (groups are not supported)"))),
        }
        Ok(())
    }
}

fn utf8(b: &[u8]) -> Result<String> {
    String::from_utf8(b.to_vec()).map_err(|_| err("non-UTF-8 string field"))
}

/// Repeated int64: accepts both packed (wire 2) and unpacked (wire 0).
fn varints(r: &mut Reader, wire: u8, out: &mut Vec<i64>) -> Result<()> {
    match wire {
        0 => out.push(r.varint()? as i64),
        2 => {
            let mut p = Reader::new(r.bytes()?);
            while !p.done() {
                out.push(p.varint()? as i64);
            }
        }
        w => return Err(err(format!("bad wire type {w} for repeated varint field"))),
    }
    Ok(())
}

/// Repeated float: accepts both packed (wire 2) and unpacked (wire 5).
fn fixed32s(r: &mut Reader, wire: u8, out: &mut Vec<f32>) -> Result<()> {
    match wire {
        5 => out.push(f32::from_bits(r.fixed32()?)),
        2 => {
            let mut p = Reader::new(r.bytes()?);
            while !p.done() {
                out.push(f32::from_bits(p.fixed32()?));
            }
        }
        w => return Err(err(format!("bad wire type {w} for repeated float field"))),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// The ONNX proto subset we understand.
// ---------------------------------------------------------------------------

const DT_FLOAT: i64 = 1;
const DT_INT64: i64 = 7;

#[derive(Default, Clone)]
struct TensorProto {
    name: String,
    dims: Vec<i64>,
    data_type: i64,
    floats: Vec<f32>,
    ints: Vec<i64>,
    raw: Vec<u8>,
}

fn parse_tensor(b: &[u8]) -> Result<TensorProto> {
    let mut t = TensorProto::default();
    let mut r = Reader::new(b);
    while !r.done() {
        let (field, wire) = r.tag()?;
        match field {
            1 => varints(&mut r, wire, &mut t.dims)?,
            2 => t.data_type = r.varint()? as i64,
            4 => fixed32s(&mut r, wire, &mut t.floats)?,
            7 => varints(&mut r, wire, &mut t.ints)?,
            8 => t.name = utf8(r.bytes()?)?,
            9 => t.raw = r.bytes()?.to_vec(),
            _ => r.skip(wire)?,
        }
    }
    Ok(t)
}

impl TensorProto {
    fn shape(&self) -> Result<Vec<usize>> {
        self.dims
            .iter()
            .map(|&d| {
                usize::try_from(d)
                    .map_err(|_| err(format!("initializer '{}' has negative dim {d}", self.name)))
            })
            .collect()
    }

    fn numel(&self) -> usize {
        self.dims.iter().map(|&d| d.max(0) as usize).product()
    }

    /// FLOAT payload: `float_data` if present, else little-endian `raw_data`.
    fn f32_data(&self) -> Result<Vec<f32>> {
        if self.data_type != DT_FLOAT {
            return Err(err(format!(
                "initializer '{}' has data_type {} where FLOAT (1) is required",
                self.name, self.data_type
            )));
        }
        let vals: Vec<f32> = if !self.floats.is_empty() {
            self.floats.clone()
        } else {
            if self.raw.len() % 4 != 0 {
                return Err(err(format!(
                    "initializer '{}' raw_data length {} is not a multiple of 4",
                    self.name,
                    self.raw.len()
                )));
            }
            self.raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        };
        if vals.len() != self.numel() {
            return Err(err(format!(
                "initializer '{}' carries {} values for shape {:?}",
                self.name,
                vals.len(),
                self.dims
            )));
        }
        Ok(vals)
    }

    /// INT64 payload: `int64_data` if present, else little-endian `raw_data`.
    fn i64_data(&self) -> Result<Vec<i64>> {
        if self.data_type != DT_INT64 {
            return Err(err(format!(
                "initializer '{}' has data_type {} where INT64 (7) is required",
                self.name, self.data_type
            )));
        }
        let vals: Vec<i64> = if !self.ints.is_empty() {
            self.ints.clone()
        } else {
            if self.raw.len() % 8 != 0 {
                return Err(err(format!(
                    "initializer '{}' raw_data length {} is not a multiple of 8",
                    self.name,
                    self.raw.len()
                )));
            }
            self.raw
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().expect("chunk of 8")))
                .collect()
        };
        if vals.len() != self.numel() {
            return Err(err(format!(
                "initializer '{}' carries {} values for shape {:?}",
                self.name,
                vals.len(),
                self.dims
            )));
        }
        Ok(vals)
    }
}

#[derive(Default, Clone)]
struct AttrProto {
    name: String,
    f: f32,
    i: i64,
    s: Vec<u8>,
    ints: Vec<i64>,
}

fn parse_attr(b: &[u8]) -> Result<AttrProto> {
    let mut a = AttrProto::default();
    let mut r = Reader::new(b);
    while !r.done() {
        let (field, wire) = r.tag()?;
        match field {
            1 => a.name = utf8(r.bytes()?)?,
            2 => a.f = f32::from_bits(r.fixed32()?),
            3 => a.i = r.varint()? as i64,
            4 => a.s = r.bytes()?.to_vec(),
            8 => varints(&mut r, wire, &mut a.ints)?,
            _ => r.skip(wire)?,
        }
    }
    Ok(a)
}

#[derive(Default, Clone)]
struct NodeProto {
    name: String,
    op_type: String,
    inputs: Vec<String>,
    outputs: Vec<String>,
    attrs: Vec<AttrProto>,
}

fn parse_node(b: &[u8]) -> Result<NodeProto> {
    let mut n = NodeProto::default();
    let mut r = Reader::new(b);
    while !r.done() {
        let (field, wire) = r.tag()?;
        match field {
            1 => n.inputs.push(utf8(r.bytes()?)?),
            2 => n.outputs.push(utf8(r.bytes()?)?),
            3 => n.name = utf8(r.bytes()?)?,
            4 => n.op_type = utf8(r.bytes()?)?,
            5 => n.attrs.push(parse_attr(r.bytes()?)?),
            _ => r.skip(wire)?,
        }
    }
    Ok(n)
}

impl NodeProto {
    /// Human-readable node label for error messages.
    fn label(&self) -> String {
        let out = self.outputs.first().map(String::as_str).unwrap_or("?");
        if self.name.is_empty() {
            format!("{}('{}')", self.op_type, out)
        } else {
            format!("{}('{}')", self.op_type, self.name)
        }
    }

    fn attr(&self, name: &str) -> Option<&AttrProto> {
        self.attrs.iter().find(|a| a.name == name)
    }

    fn attr_i(&self, name: &str, default: i64) -> i64 {
        self.attr(name).map(|a| a.i).unwrap_or(default)
    }

    fn attr_f(&self, name: &str, default: f32) -> f32 {
        self.attr(name).map(|a| a.f).unwrap_or(default)
    }

    fn attr_ints(&self, name: &str) -> Option<&[i64]> {
        self.attr(name).map(|a| a.ints.as_slice())
    }

    fn attr_s(&self, name: &str) -> Option<String> {
        self.attr(name).and_then(|a| String::from_utf8(a.s.clone()).ok())
    }
}

#[derive(Default, Clone)]
struct ValueInfo {
    name: String,
    elem_type: i64,
    /// Declared dims; `-1` stands for a symbolic (`dim_param`) dimension.
    dims: Vec<i64>,
}

fn parse_dim(b: &[u8]) -> Result<i64> {
    let mut r = Reader::new(b);
    let mut v: i64 = -1;
    while !r.done() {
        let (field, wire) = r.tag()?;
        match field {
            1 => v = r.varint()? as i64,
            2 => {
                r.bytes()?; // dim_param: symbolic, normalized to -1
                v = -1;
            }
            _ => r.skip(wire)?,
        }
    }
    Ok(v)
}

fn parse_value_info(b: &[u8]) -> Result<ValueInfo> {
    let mut vi = ValueInfo::default();
    let mut r = Reader::new(b);
    while !r.done() {
        let (field, wire) = r.tag()?;
        match field {
            1 => vi.name = utf8(r.bytes()?)?,
            2 => {
                // TypeProto → tensor_type (field 1) → {elem_type=1, shape=2}
                let mut tr = Reader::new(r.bytes()?);
                while !tr.done() {
                    let (tf, tw) = tr.tag()?;
                    if tf != 1 {
                        tr.skip(tw)?;
                        continue;
                    }
                    let mut tt = Reader::new(tr.bytes()?);
                    while !tt.done() {
                        let (f, w) = tt.tag()?;
                        match f {
                            1 => vi.elem_type = tt.varint()? as i64,
                            2 => {
                                // TensorShapeProto → repeated dim (field 1)
                                let mut sr = Reader::new(tt.bytes()?);
                                while !sr.done() {
                                    let (sf, sw) = sr.tag()?;
                                    if sf == 1 {
                                        vi.dims.push(parse_dim(sr.bytes()?)?);
                                    } else {
                                        sr.skip(sw)?;
                                    }
                                }
                            }
                            _ => tt.skip(w)?,
                        }
                    }
                }
            }
            _ => r.skip(wire)?,
        }
    }
    Ok(vi)
}

#[derive(Default)]
struct GraphProto {
    nodes: Vec<NodeProto>,
    initializers: Vec<TensorProto>,
    inputs: Vec<ValueInfo>,
    outputs: Vec<ValueInfo>,
}

fn parse_graph(b: &[u8]) -> Result<GraphProto> {
    let mut g = GraphProto::default();
    let mut r = Reader::new(b);
    while !r.done() {
        let (field, wire) = r.tag()?;
        match field {
            1 => g.nodes.push(parse_node(r.bytes()?)?),
            5 => g.initializers.push(parse_tensor(r.bytes()?)?),
            11 => g.inputs.push(parse_value_info(r.bytes()?)?),
            12 => g.outputs.push(parse_value_info(r.bytes()?)?),
            _ => r.skip(wire)?,
        }
    }
    Ok(g)
}

fn parse_model(b: &[u8]) -> Result<GraphProto> {
    let mut graph = None;
    let mut r = Reader::new(b);
    while !r.done() {
        let (field, wire) = r.tag()?;
        match field {
            7 => graph = Some(parse_graph(r.bytes()?)?),
            _ => r.skip(wire)?,
        }
    }
    graph.ok_or_else(|| err("ModelProto carries no GraphProto (is this an ONNX file?)"))
}

// ---------------------------------------------------------------------------
// Import: pending IR, BatchNorm folding, graph emission.
// ---------------------------------------------------------------------------

/// One imported op, held mutable until emission so BatchNormalization can
/// fold into Conv/Fc weights in place.
enum Pend {
    Conv { x: String, w: Vec<f32>, f: usize, c: usize, kh: usize, kw: usize, b: Vec<f32>, pad: usize },
    Fc { x: String, w: Vec<f32>, k: usize, n: usize, b: Vec<f32> },
    Relu { x: String },
    MaxPool2 { x: String },
    Gap { x: String },
    Softmax { x: String },
    Add { a: String, b: String },
    Concat { xs: Vec<String>, axis: usize },
    Reshape { x: String, shape: Vec<usize> },
}

struct Importer<'a> {
    inits: HashMap<&'a str, &'a TensorProto>,
    /// value name → canonical producer value name (Identity / folded BN /
    /// no-op Flatten chains collapse here).
    aliases: HashMap<String, String>,
    /// canonical value name → our-shape (batch dim dropped for rank-4).
    shapes: HashMap<String, Vec<usize>>,
    /// canonical value name → index into `pending`.
    index: HashMap<String, usize>,
    pending: Vec<(String, Pend)>,
    /// raw value name → number of consumers (node inputs + graph outputs).
    consumers: HashMap<&'a str, usize>,
    input_name: String,
    /// Placeholder shape as served: `[1, C, H, W]` or `[1, N]`.
    input_ph_shape: Vec<usize>,
    input_rank4: bool,
}

impl<'a> Importer<'a> {
    fn new(gp: &'a GraphProto) -> Result<Importer<'a>> {
        let mut inits: HashMap<&str, &TensorProto> = HashMap::new();
        for t in &gp.initializers {
            inits.insert(t.name.as_str(), t);
        }
        let mut consumers: HashMap<&str, usize> = HashMap::new();
        for node in &gp.nodes {
            for i in &node.inputs {
                if !i.is_empty() {
                    *consumers.entry(i.as_str()).or_insert(0) += 1;
                }
            }
        }
        for o in &gp.outputs {
            *consumers.entry(o.name.as_str()).or_insert(0) += 1;
        }

        // Exactly one data input (graph inputs minus initializers; older
        // exporters list initializers as inputs too).
        let data: Vec<&ValueInfo> =
            gp.inputs.iter().filter(|vi| !inits.contains_key(vi.name.as_str())).collect();
        if data.len() != 1 {
            return Err(err(format!(
                "expected exactly 1 graph input after excluding initializers, found {}",
                data.len()
            )));
        }
        let vi = data[0];
        if vi.elem_type != DT_FLOAT {
            return Err(err(format!(
                "graph input '{}' has elem_type {} where FLOAT (1) is required",
                vi.name, vi.elem_type
            )));
        }
        let mut dims = vi.dims.clone();
        if dims.is_empty() {
            return Err(err(format!("graph input '{}' declares no shape", vi.name)));
        }
        // The leading (batch) dim may be symbolic or 1; we serve at batch 1.
        if dims[0] == -1 {
            dims[0] = 1;
        }
        if dims[0] != 1 {
            return Err(err(format!(
                "graph input '{}' has batch dim {}; only batch 1 (or symbolic) is supported",
                vi.name, dims[0]
            )));
        }
        if dims[1..].iter().any(|&d| d <= 0) {
            return Err(err(format!(
                "graph input '{}' has non-positive or symbolic non-batch dims {:?}",
                vi.name, vi.dims
            )));
        }
        let udims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let (ph_shape, our_shape, rank4) = match udims.len() {
            4 => (udims.clone(), udims[1..].to_vec(), true),
            2 => (udims.clone(), udims.clone(), false),
            r => {
                return Err(err(format!(
                    "graph input '{}' has rank {r}; only rank-2 (N,K) and rank-4 (NCHW) inputs are supported",
                    vi.name
                )))
            }
        };
        let mut shapes = HashMap::new();
        shapes.insert(vi.name.clone(), our_shape);
        Ok(Importer {
            inits,
            aliases: HashMap::new(),
            shapes,
            index: HashMap::new(),
            pending: Vec::new(),
            consumers,
            input_name: vi.name.clone(),
            input_ph_shape: ph_shape,
            input_rank4: rank4,
        })
    }

    fn resolve(&self, name: &str) -> String {
        let mut cur = name;
        while let Some(next) = self.aliases.get(cur) {
            cur = next;
        }
        cur.to_string()
    }

    /// Resolve `raw` to a canonical activation produced earlier in the
    /// graph (the data input or a pending op's output).
    fn activation(&self, node: &NodeProto, raw: &str) -> Result<String> {
        let canon = self.resolve(raw);
        if self.inits.contains_key(canon.as_str()) {
            return Err(err(format!(
                "{}: input '{raw}' must be an activation, not an initializer",
                node.label()
            )));
        }
        if !self.shapes.contains_key(&canon) {
            return Err(err(format!(
                "{}: input '{raw}' is not produced by any earlier node",
                node.label()
            )));
        }
        Ok(canon)
    }

    fn shape_of(&self, canon: &str) -> &[usize] {
        self.shapes.get(canon).map(Vec::as_slice).unwrap_or(&[])
    }

    fn initializer(&self, node: &NodeProto, raw: &str) -> Result<&'a TensorProto> {
        self.inits.get(raw).copied().ok_or_else(|| {
            err(format!("{}: input '{raw}' must be a graph initializer", node.label()))
        })
    }

    fn push(&mut self, out: String, op: Pend, shape: Vec<usize>) -> Result<()> {
        if self.shapes.contains_key(&out) || self.inits.contains_key(out.as_str()) {
            return Err(err(format!("value '{out}' is defined more than once")));
        }
        self.index.insert(out.clone(), self.pending.len());
        self.shapes.insert(out.clone(), shape);
        self.pending.push((out, op));
        Ok(())
    }

    fn sole_output(&self, node: &NodeProto) -> Result<String> {
        let outs: Vec<&String> = node.outputs.iter().filter(|o| !o.is_empty()).collect();
        if outs.len() != 1 {
            return Err(err(format!(
                "{}: expected exactly 1 output, found {}",
                node.label(),
                outs.len()
            )));
        }
        Ok(outs[0].clone())
    }

    fn node(&mut self, node: &NodeProto) -> Result<()> {
        if node.inputs.is_empty() {
            return Err(err(format!("{}: node has no inputs", node.label())));
        }
        match node.op_type.as_str() {
            "Conv" => self.conv(node),
            "Relu" => {
                let out = self.sole_output(node)?;
                let x = self.activation(node, &node.inputs[0])?;
                let shape = self.shape_of(&x).to_vec();
                self.push(out, Pend::Relu { x }, shape)
            }
            "MaxPool" => self.maxpool(node),
            "GlobalAveragePool" => {
                let out = self.sole_output(node)?;
                let x = self.activation(node, &node.inputs[0])?;
                let s = self.shape_of(&x).to_vec();
                if s.len() != 3 {
                    return Err(err(format!(
                        "{}: GlobalAveragePool needs a rank-3 (C,H,W) activation, got {s:?}",
                        node.label()
                    )));
                }
                self.push(out, Pend::Gap { x }, vec![s[0], 1, 1])
            }
            "Add" => {
                let out = self.sole_output(node)?;
                if node.inputs.len() != 2 {
                    return Err(err(format!("{}: Add needs 2 inputs", node.label())));
                }
                let a = self.activation(node, &node.inputs[0])?;
                let b = self.activation(node, &node.inputs[1])?;
                let (sa, sb) = (self.shape_of(&a).to_vec(), self.shape_of(&b).to_vec());
                if sa != sb {
                    return Err(err(format!(
                        "{}: Add operand shapes {sa:?} vs {sb:?} differ (broadcasting is not supported)",
                        node.label()
                    )));
                }
                self.push(out, Pend::Add { a, b }, sa)
            }
            "BatchNormalization" => self.batchnorm(node),
            "Gemm" => self.gemm(node),
            "MatMul" => self.matmul(node),
            "Flatten" => self.flatten(node),
            "Reshape" => self.reshape(node),
            "Concat" => self.concat(node),
            "Softmax" => {
                let out = self.sole_output(node)?;
                let x = self.activation(node, &node.inputs[0])?;
                let s = self.shape_of(&x).to_vec();
                if s.len() != 2 {
                    return Err(err(format!(
                        "{}: Softmax needs a rank-2 activation, got {s:?}",
                        node.label()
                    )));
                }
                let axis = node.attr_i("axis", -1);
                if axis != -1 && axis != 1 {
                    return Err(err(format!(
                        "{}: Softmax axis {axis} is not the last axis of a rank-2 tensor",
                        node.label()
                    )));
                }
                self.push(out, Pend::Softmax { x }, s)
            }
            "Identity" => {
                let out = self.sole_output(node)?;
                let x = self.activation(node, &node.inputs[0])?;
                self.aliases.insert(out, x);
                Ok(())
            }
            other => Err(err(format!(
                "unsupported op '{other}' at {}; supported ops: Add, BatchNormalization, Concat, \
                 Conv, Flatten, Gemm, GlobalAveragePool, Identity, MatMul, MaxPool, Relu, \
                 Reshape, Softmax",
                node.label()
            ))),
        }
    }

    fn conv(&mut self, node: &NodeProto) -> Result<()> {
        let out = self.sole_output(node)?;
        if node.inputs.len() < 2 {
            return Err(err(format!("{}: Conv needs at least X and W inputs", node.label())));
        }
        let x = self.activation(node, &node.inputs[0])?;
        let xs = self.shape_of(&x).to_vec();
        if xs.len() != 3 {
            return Err(err(format!(
                "{}: Conv needs a rank-3 (C,H,W) activation, got {xs:?}",
                node.label()
            )));
        }
        let wt = self.initializer(node, &node.inputs[1])?;
        let wdims = wt.shape()?;
        if wdims.len() != 4 {
            return Err(err(format!(
                "{}: Conv weight '{}' must be rank-4 (F,C,KH,KW), got {wdims:?}",
                node.label(),
                wt.name
            )));
        }
        let (f, c, kh, kw) = (wdims[0], wdims[1], wdims[2], wdims[3]);
        if c != xs[0] {
            return Err(err(format!(
                "{}: Conv weight expects {c} input channels but activation has {}",
                node.label(),
                xs[0]
            )));
        }
        if let Some(s) = node.attr_s("auto_pad") {
            if !s.is_empty() && s != "NOTSET" {
                return Err(err(format!(
                    "{}: auto_pad '{s}' is not supported; export with explicit pads",
                    node.label()
                )));
            }
        }
        if node.attr_i("group", 1) != 1 {
            return Err(err(format!("{}: only group=1 convolutions are supported", node.label())));
        }
        for name in ["strides", "dilations"] {
            if let Some(v) = node.attr_ints(name) {
                if v.iter().any(|&d| d != 1) {
                    return Err(err(format!(
                        "{}: only {name} of all 1s are supported, got {v:?}",
                        node.label()
                    )));
                }
            }
        }
        if let Some(ks) = node.attr_ints("kernel_shape") {
            if ks != [kh as i64, kw as i64] {
                return Err(err(format!(
                    "{}: kernel_shape {ks:?} disagrees with weight dims ({kh},{kw})",
                    node.label()
                )));
            }
        }
        let pad = match node.attr_ints("pads") {
            None => 0,
            Some(p) => {
                if p.len() != 4 || p.iter().any(|&v| v != p[0]) || p[0] < 0 {
                    return Err(err(format!(
                        "{}: only symmetric pads [p,p,p,p] are supported, got {p:?}",
                        node.label()
                    )));
                }
                p[0] as usize
            }
        };
        let (h, wi) = (xs[1] + 2 * pad, xs[2] + 2 * pad);
        if h < kh || wi < kw {
            return Err(err(format!(
                "{}: padded input ({h}x{wi}) is smaller than the {kh}x{kw} filter",
                node.label()
            )));
        }
        let w = wt.f32_data()?;
        let b = if node.inputs.len() >= 3 && !node.inputs[2].is_empty() {
            let bt = self.initializer(node, &node.inputs[2])?;
            let b = bt.f32_data()?;
            if b.len() != f {
                return Err(err(format!(
                    "{}: Conv bias '{}' has {} values for {f} filters",
                    node.label(),
                    bt.name,
                    b.len()
                )));
            }
            b
        } else {
            vec![0.0; f]
        };
        let shape = vec![f, h - kh + 1, wi - kw + 1];
        self.push(out, Pend::Conv { x, w, f, c, kh, kw, b, pad }, shape)
    }

    fn maxpool(&mut self, node: &NodeProto) -> Result<()> {
        let out = self.sole_output(node)?;
        let x = self.activation(node, &node.inputs[0])?;
        let s = self.shape_of(&x).to_vec();
        if s.len() != 3 {
            return Err(err(format!(
                "{}: MaxPool needs a rank-3 (C,H,W) activation, got {s:?}",
                node.label()
            )));
        }
        // `maxpool2_f32` implements exactly ONNX's floor-mode 2x2/2 pooling
        // (trailing odd row/column dropped); everything else is refused.
        let constraint = |ok: bool, what: String| -> Result<()> {
            if ok {
                Ok(())
            } else {
                Err(err(format!(
                    "{}: {what}; only 2x2 stride-2 floor-mode unpadded MaxPool maps onto maxpool2",
                    node.label()
                )))
            }
        };
        let ks = node.attr_ints("kernel_shape").unwrap_or(&[]);
        constraint(ks == [2, 2], format!("kernel_shape {ks:?} != [2,2]"))?;
        let st = node.attr_ints("strides").unwrap_or(&[1, 1]);
        constraint(st == [2, 2], format!("strides {st:?} != [2,2]"))?;
        if let Some(p) = node.attr_ints("pads") {
            constraint(p.iter().all(|&v| v == 0), format!("pads {p:?} != 0"))?;
        }
        if let Some(d) = node.attr_ints("dilations") {
            constraint(d.iter().all(|&v| v == 1), format!("dilations {d:?} != 1"))?;
        }
        constraint(node.attr_i("ceil_mode", 0) == 0, "ceil_mode=1".to_string())?;
        constraint(node.attr_i("storage_order", 0) == 0, "storage_order=1".to_string())?;
        if let Some(s) = node.attr_s("auto_pad") {
            constraint(s.is_empty() || s == "NOTSET", format!("auto_pad '{s}'"))?;
        }
        constraint(s[1] >= 2 && s[2] >= 2, format!("spatial dims {s:?} below 2x2"))?;
        let shape = vec![s[0], s[1] / 2, s[2] / 2];
        self.push(out, Pend::MaxPool2 { x }, shape)
    }

    fn batchnorm(&mut self, node: &NodeProto) -> Result<()> {
        let out = self.sole_output(node)?;
        if node.inputs.len() < 5 {
            return Err(err(format!(
                "{}: BatchNormalization needs X, scale, B, mean, var inputs",
                node.label()
            )));
        }
        let raw = node.inputs[0].as_str();
        let x = self.activation(node, raw)?;
        let idx = *self.index.get(&x).ok_or_else(|| {
            err(format!(
                "{}: BatchNormalization folds into a producing Conv/Gemm/MatMul, but '{raw}' is the graph input",
                node.label()
            ))
        })?;
        let uses = self
            .consumers
            .get(raw)
            .copied()
            .unwrap_or(0)
            .max(self.consumers.get(x.as_str()).copied().unwrap_or(0));
        if uses != 1 {
            return Err(err(format!(
                "{}: cannot fold — '{raw}' has {uses} consumers; folding requires the \
                 BatchNormalization to be its producer's only consumer",
                node.label()
            )));
        }
        let ch = match &self.pending[idx].1 {
            Pend::Conv { f, .. } => *f,
            Pend::Fc { n, .. } => *n,
            _ => {
                return Err(err(format!(
                    "{}: BatchNormalization can only fold into Conv/Gemm/MatMul, but '{raw}' \
                     is produced by a different op",
                    node.label()
                )))
            }
        };
        let eps = node.attr_f("epsilon", 1e-5);
        let mut params = Vec::with_capacity(4);
        for raw_p in &node.inputs[1..5] {
            let t = self.initializer(node, raw_p)?;
            let v = t.f32_data()?;
            if v.len() != ch {
                return Err(err(format!(
                    "{}: parameter '{}' has {} values for {ch} channels",
                    node.label(),
                    t.name,
                    v.len()
                )));
            }
            params.push(v);
        }
        let (scale, beta, mean, var) = (&params[0], &params[1], &params[2], &params[3]);
        let mut k = Vec::with_capacity(ch);
        for i in 0..ch {
            let denom = var[i] + eps;
            if denom <= 0.0 {
                return Err(err(format!(
                    "{}: var[{i}] + epsilon = {denom} is not positive",
                    node.label()
                )));
            }
            k.push(scale[i] / denom.sqrt());
        }
        match &mut self.pending[idx].1 {
            Pend::Conv { w, b, c, kh, kw, .. } => {
                let row = *c * *kh * *kw;
                for fi in 0..ch {
                    for v in &mut w[fi * row..(fi + 1) * row] {
                        *v *= k[fi];
                    }
                    b[fi] = (b[fi] - mean[fi]) * k[fi] + beta[fi];
                }
            }
            Pend::Fc { w, n, b, .. } => {
                let n = *n;
                for (i, v) in w.iter_mut().enumerate() {
                    *v *= k[i % n];
                }
                for j in 0..n {
                    b[j] = (b[j] - mean[j]) * k[j] + beta[j];
                }
            }
            _ => unreachable!("checked above"),
        }
        self.aliases.insert(out, x);
        Ok(())
    }

    fn gemm(&mut self, node: &NodeProto) -> Result<()> {
        let out = self.sole_output(node)?;
        if node.inputs.len() < 2 {
            return Err(err(format!("{}: Gemm needs at least A and B inputs", node.label())));
        }
        let a = self.activation(node, &node.inputs[0])?;
        let ash = self.shape_of(&a).to_vec();
        if ash.len() != 2 {
            return Err(err(format!(
                "{}: Gemm input must be rank-2, got {ash:?}",
                node.label()
            )));
        }
        for (name, want) in [("alpha", 1.0f32), ("beta", 1.0)] {
            let v = node.attr_f(name, 1.0);
            if v != want {
                return Err(err(format!("{}: only {name}=1 is supported, got {v}", node.label())));
            }
        }
        if node.attr_i("transA", 0) != 0 {
            return Err(err(format!("{}: transA=1 is not supported", node.label())));
        }
        let wt = self.initializer(node, &node.inputs[1])?;
        let wdims = wt.shape()?;
        if wdims.len() != 2 {
            return Err(err(format!(
                "{}: Gemm weight '{}' must be rank-2, got {wdims:?}",
                node.label(),
                wt.name
            )));
        }
        let wraw = wt.f32_data()?;
        let trans_b = node.attr_i("transB", 0);
        let (k, n, w) = match trans_b {
            0 => (wdims[0], wdims[1], wraw),
            1 => {
                // Stored (N, K); our FullyConnected wants (K, N).
                let (n, k) = (wdims[0], wdims[1]);
                let mut t = vec![0.0f32; k * n];
                for j in 0..n {
                    for i in 0..k {
                        t[i * n + j] = wraw[j * k + i];
                    }
                }
                (k, n, t)
            }
            v => {
                return Err(err(format!("{}: transB={v} is not a valid flag", node.label())));
            }
        };
        if ash[1] != k {
            return Err(err(format!(
                "{}: Gemm inner dims disagree — activation {ash:?} vs weight (K={k}, N={n})",
                node.label()
            )));
        }
        let b = if node.inputs.len() >= 3 && !node.inputs[2].is_empty() {
            let bt = self.initializer(node, &node.inputs[2])?;
            let b = bt.f32_data()?;
            if b.len() != n {
                return Err(err(format!(
                    "{}: Gemm bias '{}' has {} values for N={n}",
                    node.label(),
                    bt.name,
                    b.len()
                )));
            }
            b
        } else {
            vec![0.0; n]
        };
        self.push(out, Pend::Fc { x: a, w, k, n, b }, vec![ash[0], n])
    }

    fn matmul(&mut self, node: &NodeProto) -> Result<()> {
        let out = self.sole_output(node)?;
        if node.inputs.len() != 2 {
            return Err(err(format!("{}: MatMul needs 2 inputs", node.label())));
        }
        let a = self.activation(node, &node.inputs[0])?;
        let ash = self.shape_of(&a).to_vec();
        if ash.len() != 2 {
            return Err(err(format!(
                "{}: MatMul input must be rank-2, got {ash:?}",
                node.label()
            )));
        }
        let wt = self.initializer(node, &node.inputs[1])?;
        let wdims = wt.shape()?;
        if wdims.len() != 2 || wdims[0] != ash[1] {
            return Err(err(format!(
                "{}: MatMul weight '{}' of shape {wdims:?} does not compose with {ash:?}",
                node.label(),
                wt.name
            )));
        }
        let (k, n) = (wdims[0], wdims[1]);
        let w = wt.f32_data()?;
        self.push(out, Pend::Fc { x: a, w, k, n, b: vec![0.0; n] }, vec![ash[0], n])
    }

    fn flatten(&mut self, node: &NodeProto) -> Result<()> {
        let out = self.sole_output(node)?;
        let x = self.activation(node, &node.inputs[0])?;
        let axis = node.attr_i("axis", 1);
        if axis != 1 {
            return Err(err(format!("{}: only Flatten axis=1 is supported", node.label())));
        }
        let s = self.shape_of(&x).to_vec();
        match s.len() {
            3 => {
                let k: usize = s.iter().product();
                self.push(out, Pend::Reshape { x, shape: vec![1, k] }, vec![1, k])
            }
            2 => {
                // (1, N) flattened over axis 1 is itself.
                self.aliases.insert(out, x);
                Ok(())
            }
            r => Err(err(format!("{}: cannot flatten a rank-{r} activation", node.label()))),
        }
    }

    fn reshape(&mut self, node: &NodeProto) -> Result<()> {
        let out = self.sole_output(node)?;
        if node.inputs.len() != 2 {
            return Err(err(format!("{}: Reshape needs data and shape inputs", node.label())));
        }
        let x = self.activation(node, &node.inputs[0])?;
        let st = self.initializer(node, &node.inputs[1])?;
        let target = st.i64_data()?;
        let numel: usize = self.shape_of(&x).iter().product();
        if target.len() != 2 {
            return Err(err(format!(
                "{}: only rank-2 reshape targets are supported, got {target:?}",
                node.label()
            )));
        }
        let holes = target.iter().filter(|&&d| d == -1).count();
        if holes > 1 || target.iter().any(|&d| d == 0 || d < -1) {
            return Err(err(format!(
                "{}: reshape target {target:?} is not a concrete rank-2 shape",
                node.label()
            )));
        }
        let known: usize = target.iter().filter(|&&d| d > 0).map(|&d| d as usize).product();
        let shape: Vec<usize> = if holes == 1 {
            if known == 0 || numel % known != 0 {
                return Err(err(format!(
                    "{}: cannot infer -1 in {target:?} from {numel} elements",
                    node.label()
                )));
            }
            target
                .iter()
                .map(|&d| if d == -1 { numel / known } else { d as usize })
                .collect()
        } else {
            target.iter().map(|&d| d as usize).collect()
        };
        if shape.iter().product::<usize>() != numel {
            return Err(err(format!(
                "{}: reshape target {shape:?} does not preserve {numel} elements",
                node.label()
            )));
        }
        self.push(out, Pend::Reshape { x, shape: shape.clone() }, shape)
    }

    fn concat(&mut self, node: &NodeProto) -> Result<()> {
        let out = self.sole_output(node)?;
        if node.inputs.is_empty() {
            return Err(err(format!("{}: Concat needs at least 1 input", node.label())));
        }
        let mut xs = Vec::with_capacity(node.inputs.len());
        for i in &node.inputs {
            xs.push(self.activation(node, i)?);
        }
        let first = self.shape_of(&xs[0]).to_vec();
        let rank = first.len();
        // ONNX axes count the batch dim; our rank-3 activations dropped it.
        let onnx_rank = if rank == 3 { 4 } else { rank } as i64;
        let mut axis = node
            .attr("axis")
            .map(|a| a.i)
            .ok_or_else(|| err(format!("{}: Concat requires an axis attribute", node.label())))?;
        if axis < 0 {
            axis += onnx_rank;
        }
        let our_axis = if rank == 3 {
            if axis < 1 || axis > 3 {
                return Err(err(format!(
                    "{}: Concat axis {axis} is out of range for NCHW inputs (batch concat is not supported)",
                    node.label()
                )));
            }
            (axis - 1) as usize
        } else {
            if axis != 1 {
                return Err(err(format!(
                    "{}: Concat axis {axis} must be 1 for rank-2 inputs",
                    node.label()
                )));
            }
            1
        };
        let mut shape = first.clone();
        shape[our_axis] = 0;
        for x in &xs {
            let s = self.shape_of(x);
            if s.len() != rank {
                return Err(err(format!(
                    "{}: Concat inputs have mixed ranks ({rank} vs {})",
                    node.label(),
                    s.len()
                )));
            }
            for (d, (&a, &b)) in s.iter().zip(first.iter()).enumerate() {
                if d != our_axis && a != b {
                    return Err(err(format!(
                        "{}: Concat inputs disagree on non-axis dim {d} ({a} vs {b})",
                        node.label()
                    )));
                }
            }
            shape[our_axis] += s[our_axis];
        }
        self.push(out, Pend::Concat { xs, axis: our_axis }, shape)
    }

    /// Emit the pending IR into a [`Graph`] and wrap it in a serving bundle.
    fn emit(self, model_name: &str, gp: &GraphProto) -> Result<ModelBundle> {
        if gp.outputs.len() != 1 {
            return Err(err(format!(
                "expected exactly 1 graph output, found {}",
                gp.outputs.len()
            )));
        }
        let out_name = self.resolve(&gp.outputs[0].name);
        if !self.shapes.contains_key(&out_name) {
            return Err(err(format!(
                "graph output '{}' is not produced by any node",
                gp.outputs[0].name
            )));
        }

        let mut g = Graph::new();
        let mut ids: HashMap<&str, NodeId> = HashMap::new();
        let ph = g.placeholder(self.input_name.as_str(), &self.input_ph_shape, DType::F32)?;
        if self.input_rank4 {
            let chw = self.input_ph_shape[1..].to_vec();
            let r = g.add(format!("{}/chw", self.input_name), OpKind::Reshape { shape: chw }, &[ph])?;
            ids.insert(self.input_name.as_str(), r);
        } else {
            ids.insert(self.input_name.as_str(), ph);
        }

        let lookup = |ids: &HashMap<&str, NodeId>, name: &str| -> Result<NodeId> {
            ids.get(name)
                .copied()
                .ok_or_else(|| err(format!("internal: value '{name}' emitted out of order")))
        };
        for (out, op) in &self.pending {
            let id = match op {
                Pend::Conv { x, w, f, c, kh, kw, b, pad } => {
                    let xi = lookup(&ids, x)?;
                    let wt = Tensor::from_f32(&[*f, *c, *kh, *kw], w.clone())?;
                    let bt = Tensor::from_f32(&[*f], b.clone())?;
                    let wi = g.constant(format!("{out}/w"), wt)?;
                    let bi = g.constant(format!("{out}/b"), bt)?;
                    g.add(out.as_str(), OpKind::Conv2dF32 { pad: *pad }, &[xi, wi, bi])?
                }
                Pend::Fc { x, w, k, n, b } => {
                    let xi = lookup(&ids, x)?;
                    let wt = Tensor::from_f32(&[*k, *n], w.clone())?;
                    let bt = Tensor::from_f32(&[*n], b.clone())?;
                    let wi = g.constant(format!("{out}/w"), wt)?;
                    let bi = g.constant(format!("{out}/b"), bt)?;
                    g.add(out.as_str(), OpKind::FullyConnected, &[xi, wi, bi])?
                }
                Pend::Relu { x } => g.add(out.as_str(), OpKind::Relu, &[lookup(&ids, x)?])?,
                Pend::MaxPool2 { x } => g.add(out.as_str(), OpKind::MaxPool2, &[lookup(&ids, x)?])?,
                Pend::Gap { x } => g.add(out.as_str(), OpKind::GlobalAvgPool, &[lookup(&ids, x)?])?,
                Pend::Softmax { x } => g.add(out.as_str(), OpKind::Softmax, &[lookup(&ids, x)?])?,
                Pend::Add { a, b } => {
                    let ai = lookup(&ids, a)?;
                    let bi = lookup(&ids, b)?;
                    g.add(out.as_str(), OpKind::Add, &[ai, bi])?
                }
                Pend::Concat { xs, axis } => {
                    let mut ins = Vec::with_capacity(xs.len());
                    for x in xs {
                        ins.push(lookup(&ids, x)?);
                    }
                    g.add(out.as_str(), OpKind::Concat { axis: *axis }, &ins)?
                }
                Pend::Reshape { x, shape } => {
                    g.add(out.as_str(), OpKind::Reshape { shape: shape.clone() }, &[lookup(&ids, x)?])?
                }
            };
            ids.insert(out.as_str(), id);
        }

        g.finalize()?;
        let out_id = lookup(&ids, &out_name)?;
        let out_shape = g.node(out_id).out_shape.clone();
        let signature = Signature {
            name: SERVE_SIGNATURE.to_string(),
            inputs: vec![Endpoint::new("x", self.input_name.as_str(), &self.input_ph_shape, DType::F32)],
            outputs: vec![Endpoint::new("y", out_name.as_str(), &out_shape, DType::F32)],
        };
        ModelBundle::new(model_name, g, vec![signature])
    }
}

/// Import an ONNX model from raw protobuf bytes.
pub fn import_onnx_bytes(bytes: &[u8], model_name: &str) -> Result<ModelBundle> {
    let gp = parse_model(bytes)?;
    let mut imp = Importer::new(&gp)?;
    for node in &gp.nodes {
        imp.node(node)?;
    }
    imp.emit(model_name, &gp)
}

/// Import an ONNX model from a file; the bundle is named after the file stem.
pub fn import_onnx_file(path: impl AsRef<Path>) -> Result<ModelBundle> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .map_err(|e| err(format!("cannot read {}: {e}", path.display())))?;
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("model");
    import_onnx_bytes(&bytes, name)
}

#[cfg(test)]
mod tests {
    use super::*;

    // -- a tiny protobuf *encoder*, test-only, to build ONNX bytes in-memory --

    fn pv(buf: &mut Vec<u8>, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                buf.push(b);
                break;
            }
            buf.push(b | 0x80);
        }
    }

    fn key(buf: &mut Vec<u8>, field: u64, wire: u8) {
        pv(buf, (field << 3) | u64::from(wire));
    }

    fn pb(buf: &mut Vec<u8>, field: u64, bytes: &[u8]) {
        key(buf, field, 2);
        pv(buf, bytes.len() as u64);
        buf.extend_from_slice(bytes);
    }

    fn ps(buf: &mut Vec<u8>, field: u64, s: &str) {
        pb(buf, field, s.as_bytes());
    }

    fn pi(buf: &mut Vec<u8>, field: u64, v: i64) {
        key(buf, field, 0);
        pv(buf, v as u64);
    }

    fn tensor_f32(name: &str, dims: &[i64], vals: &[f32]) -> Vec<u8> {
        let mut b = Vec::new();
        for &d in dims {
            pi(&mut b, 1, d); // unpacked dims: exercises the wire-0 path
        }
        pi(&mut b, 2, DT_FLOAT);
        let mut payload = Vec::new();
        for &v in vals {
            payload.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        pb(&mut b, 4, &payload); // packed float_data: exercises the wire-2 path
        ps(&mut b, 8, name);
        b
    }

    fn tensor_i64_raw(name: &str, dims: &[i64], vals: &[i64]) -> Vec<u8> {
        let mut b = Vec::new();
        for &d in dims {
            pi(&mut b, 1, d);
        }
        pi(&mut b, 2, DT_INT64);
        ps(&mut b, 8, name);
        let mut raw = Vec::new();
        for &v in vals {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        pb(&mut b, 9, &raw); // raw_data path
        b
    }

    fn a_int(name: &str, v: i64) -> Vec<u8> {
        let mut b = Vec::new();
        ps(&mut b, 1, name);
        pi(&mut b, 3, v);
        pi(&mut b, 20, 2); // AttributeProto.Type INT
        b
    }

    fn a_float(name: &str, v: f32) -> Vec<u8> {
        let mut b = Vec::new();
        ps(&mut b, 1, name);
        key(&mut b, 2, 5);
        b.extend_from_slice(&v.to_bits().to_le_bytes());
        pi(&mut b, 20, 1); // FLOAT
        b
    }

    fn a_ints(name: &str, vals: &[i64]) -> Vec<u8> {
        let mut b = Vec::new();
        ps(&mut b, 1, name);
        for &v in vals {
            pi(&mut b, 8, v); // unpacked repeated ints
        }
        pi(&mut b, 20, 7); // INTS
        b
    }

    fn node(op: &str, inputs: &[&str], outputs: &[&str], attrs: &[Vec<u8>]) -> Vec<u8> {
        let mut b = Vec::new();
        for i in inputs {
            ps(&mut b, 1, i);
        }
        for o in outputs {
            ps(&mut b, 2, o);
        }
        ps(&mut b, 4, op);
        for a in attrs {
            pb(&mut b, 5, a);
        }
        b
    }

    fn vinfo(name: &str, dims: &[i64]) -> Vec<u8> {
        let mut shape = Vec::new();
        for &d in dims {
            let mut dim = Vec::new();
            pi(&mut dim, 1, d);
            pb(&mut shape, 1, &dim);
        }
        let mut tt = Vec::new();
        pi(&mut tt, 1, DT_FLOAT);
        pb(&mut tt, 2, &shape);
        let mut ty = Vec::new();
        pb(&mut ty, 1, &tt);
        let mut b = Vec::new();
        ps(&mut b, 1, name);
        pb(&mut b, 2, &ty);
        b
    }

    fn model(
        nodes: &[Vec<u8>],
        inits: &[Vec<u8>],
        inputs: &[Vec<u8>],
        outputs: &[Vec<u8>],
    ) -> Vec<u8> {
        let mut g = Vec::new();
        for n in nodes {
            pb(&mut g, 1, n);
        }
        for t in inits {
            pb(&mut g, 5, t);
        }
        for i in inputs {
            pb(&mut g, 11, i);
        }
        for o in outputs {
            pb(&mut g, 12, o);
        }
        let mut m = Vec::new();
        pi(&mut m, 1, 8); // ir_version, skipped by the parser
        pb(&mut m, 7, &g);
        m
    }

    fn const_f32(bundle: &ModelBundle, name: &str) -> Vec<f32> {
        let n = bundle
            .graph
            .nodes()
            .iter()
            .find(|n| n.name == name)
            .unwrap_or_else(|| panic!("no node named {name}"));
        match &n.op {
            OpKind::Constant(t) => t.as_f32().unwrap().to_vec(),
            other => panic!("{name} is {other:?}, not a constant"),
        }
    }

    // ---------------------------------------------------------------------

    #[test]
    fn parser_reads_packed_unpacked_and_raw_payloads() {
        let t = parse_tensor(&tensor_f32("w", &[2, 2], &[1.0, -2.5, 3.0, 0.25])).unwrap();
        assert_eq!(t.name, "w");
        assert_eq!(t.dims, vec![2, 2]);
        assert_eq!(t.f32_data().unwrap(), vec![1.0, -2.5, 3.0, 0.25]);

        let t = parse_tensor(&tensor_i64_raw("shape", &[2], &[1, -1])).unwrap();
        assert_eq!(t.i64_data().unwrap(), vec![1, -1]);

        // Unknown fields must be skipped, not rejected.
        let mut b = tensor_f32("w", &[1], &[4.0]);
        pi(&mut b, 14, 99); // doc_string-ish unknown varint field
        assert_eq!(parse_tensor(&b).unwrap().f32_data().unwrap(), vec![4.0]);
    }

    #[test]
    fn varint_overlong_and_truncated_inputs_are_errors() {
        let mut r = Reader::new(&[0x80; 11]);
        assert!(r.varint().is_err());
        let mut r = Reader::new(&[0x80]);
        assert!(r.varint().is_err());
        // Group wire type (3) is unsupported.
        assert!(parse_tensor(&[0x0b]).is_err());
    }

    /// Conv(pad 1) → Relu → GlobalAveragePool → Flatten → Gemm → Softmax,
    /// the spine of every TinyML classifier.
    fn convnet_bytes() -> Vec<u8> {
        let conv_w = tensor_f32("cw", &[2, 1, 3, 3], &[0.5; 18]);
        let conv_b = tensor_f32("cb", &[2], &[0.0, 1.0]);
        let fc_w = tensor_f32("fw", &[2, 3], &[1., 2., 3., 4., 5., 6.]);
        let fc_b = tensor_f32("fb", &[3], &[0.1, 0.2, 0.3]);
        let nodes = vec![
            node(
                "Conv",
                &["x", "cw", "cb"],
                &["c1"],
                &[a_ints("pads", &[1, 1, 1, 1]), a_ints("strides", &[1, 1])],
            ),
            node("Relu", &["c1"], &["r1"], &[]),
            node("GlobalAveragePool", &["r1"], &["g1"], &[]),
            node("Flatten", &["g1"], &["f1"], &[a_int("axis", 1)]),
            node("Gemm", &["f1", "fw", "fb"], &["l"], &[a_int("transB", 0)]),
            node("Softmax", &["l"], &["y"], &[a_int("axis", -1)]),
        ];
        model(
            &nodes,
            &[conv_w, conv_b, fc_w, fc_b],
            &[vinfo("x", &[1, 1, 4, 4])],
            &[vinfo("y", &[1, 3])],
        )
    }

    #[test]
    fn imports_a_convnet_end_to_end() {
        let bundle = import_onnx_bytes(&convnet_bytes(), "tiny").unwrap();
        assert_eq!(bundle.name, "tiny");
        let g = &bundle.graph;
        // Rank-4 input → [1,C,H,W] placeholder + /chw reshape.
        let ph = g.nodes().iter().find(|n| n.name == "x").unwrap();
        assert_eq!(ph.out_shape, vec![1, 1, 4, 4]);
        assert!(g.nodes().iter().any(|n| n.name == "x/chw"));
        let conv = g.nodes().iter().find(|n| n.name == "c1").unwrap();
        assert!(matches!(conv.op, OpKind::Conv2dF32 { pad: 1 }));
        assert_eq!(conv.out_shape, vec![2, 4, 4]);
        let out = g.nodes().iter().find(|n| n.name == "y").unwrap();
        assert_eq!(out.out_shape, vec![1, 3]);
        let sig = &bundle.signatures[0];
        assert_eq!(sig.name, SERVE_SIGNATURE);
        assert_eq!(sig.input("x").unwrap().node, "x");
        assert_eq!(sig.output("y").unwrap().shape, vec![1, 3]);
    }

    #[test]
    fn batchnorm_folds_into_conv_with_exact_arithmetic() {
        // eps=0, var=4, scale=3 → k = 3/√4 = 1.5: every value is f32-exact,
        // so the fold must reproduce them bit-for-bit.
        let conv_w = tensor_f32("cw", &[1, 1, 1, 1], &[2.0]);
        let conv_b = tensor_f32("cb", &[1], &[1.0]);
        let scale = tensor_f32("s", &[1], &[3.0]);
        let beta = tensor_f32("o", &[1], &[0.5]);
        let mean = tensor_f32("m", &[1], &[2.0]);
        let var = tensor_f32("v", &[1], &[4.0]);
        let nodes = vec![
            node("Conv", &["x", "cw", "cb"], &["c"], &[]),
            node(
                "BatchNormalization",
                &["c", "s", "o", "m", "v"],
                &["bn"],
                &[a_float("epsilon", 0.0)],
            ),
            node("GlobalAveragePool", &["bn"], &["g"], &[]),
            node("Flatten", &["g"], &["y"], &[]),
        ];
        let m = model(
            &nodes,
            &[conv_w, conv_b, scale, beta, mean, var],
            &[vinfo("x", &[1, 1, 2, 2])],
            &[vinfo("y", &[1, 1])],
        );
        let bundle = import_onnx_bytes(&m, "bnfold").unwrap();
        // w' = 2·1.5 = 3;  b' = (1−2)·1.5 + 0.5 = −1.
        assert_eq!(const_f32(&bundle, "c/w"), vec![3.0]);
        assert_eq!(const_f32(&bundle, "c/b"), vec![-1.0]);
        // The BN node itself vanished: 'bn' aliases to 'c'.
        assert!(!bundle.graph.nodes().iter().any(|n| n.name == "bn"));
    }

    #[test]
    fn batchnorm_fold_refused_when_conv_has_more_consumers() {
        let conv_w = tensor_f32("cw", &[1, 1, 1, 1], &[2.0]);
        let scale = tensor_f32("s", &[1], &[1.0]);
        let beta = tensor_f32("o", &[1], &[0.0]);
        let mean = tensor_f32("m", &[1], &[0.0]);
        let var = tensor_f32("v", &[1], &[1.0]);
        let nodes = vec![
            node("Conv", &["x", "cw"], &["c"], &[]),
            node("BatchNormalization", &["c", "s", "o", "m", "v"], &["bn"], &[]),
            // Second consumer of the conv output: folding would corrupt it.
            node("Relu", &["c"], &["r"], &[]),
            node("Add", &["bn", "r"], &["y"], &[]),
        ];
        let m = model(
            &nodes,
            &[conv_w, scale, beta, mean, var],
            &[vinfo("x", &[1, 1, 2, 2])],
            &[vinfo("y", &[1, 1, 2, 2])],
        );
        let e = import_onnx_bytes(&m, "nofold").unwrap_err().to_string();
        assert!(e.contains("onnx import:"), "{e}");
        assert!(e.contains("consumers"), "{e}");
    }

    #[test]
    fn bn_fold_matches_unfolded_reference_within_one_ulp() {
        use crate::tf::session::{Session, SessionOptions};
        // All values are chosen f32-exact (integer weights/activations,
        // k = scale/√var ∈ {1.5, 3.0}) so folded and unfolded evaluation
        // orders cannot diverge by more than reassociation noise.
        let wv: Vec<f32> = (0..18).map(|i| ((i % 5) as f32) - 2.0).collect();
        let bv = [1.0f32, -2.0];
        let (scale, beta, mean, var) =
            ([3.0f32, 1.5], [0.5f32, -0.25], [2.0f32, 1.0], [4.0f32, 0.25]);
        let nodes = vec![
            node("Conv", &["x", "cw", "cb"], &["c"], &[a_ints("pads", &[1, 1, 1, 1])]),
            node(
                "BatchNormalization",
                &["c", "s", "o", "m", "v"],
                &["bn"],
                &[a_float("epsilon", 0.0)],
            ),
            node("Relu", &["bn"], &["y"], &[]),
        ];
        let m = model(
            &nodes,
            &[
                tensor_f32("cw", &[2, 1, 3, 3], &wv),
                tensor_f32("cb", &[2], &bv),
                tensor_f32("s", &[2], &scale),
                tensor_f32("o", &[2], &beta),
                tensor_f32("m", &[2], &mean),
                tensor_f32("v", &[2], &var),
            ],
            &[vinfo("x", &[1, 1, 4, 4])],
            &[vinfo("y", &[1, 2, 4, 4])],
        );
        let bundle = import_onnx_bytes(&m, "ulp").unwrap();
        let sess = Session::new(bundle.graph.clone(), SessionOptions::native_only()).unwrap();
        let xv: Vec<f32> = (0..16).map(|i| i as f32 - 8.0).collect();
        let xt = Tensor::from_f32(&[1, 1, 4, 4], xv.clone()).unwrap();
        let (got, _) = sess.run_interpreted(&[("x", xt)], &["y"]).unwrap();

        // Unfolded reference: conv, then the BN affine, then relu.
        let xr = Tensor::from_f32(&[1, 4, 4], xv).unwrap();
        let wt = Tensor::from_f32(&[2, 1, 3, 3], wv).unwrap();
        let bt = Tensor::from_f32(&[2], bv.to_vec()).unwrap();
        let conv = crate::ops::conv2d_f32(&xr, &wt, &bt, 1).unwrap();
        let mut want = conv.as_f32().unwrap().to_vec();
        for (i, v) in want.iter_mut().enumerate() {
            let f = i / 16; // 4x4 spatial per filter
            let k = scale[f] / var[f].sqrt();
            *v = ((*v - mean[f]) * k + beta[f]).max(0.0);
        }
        let got = got[0].as_f32().unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want.iter()) {
            let ulp = if a == b {
                0
            } else {
                (i64::from(a.to_bits()) - i64::from(b.to_bits())).unsigned_abs()
            };
            assert!(ulp <= 1, "folded {a} vs unfolded {b} differ by {ulp} ulp");
        }
    }

    #[test]
    fn gemm_transb_weights_are_transposed_at_import() {
        // Stored (N=2, K=3) rows [1,2,3],[4,5,6] → our (K=3, N=2) layout.
        let fc_w = tensor_f32("fw", &[2, 3], &[1., 2., 3., 4., 5., 6.]);
        let nodes = vec![node("Gemm", &["x", "fw"], &["y"], &[a_int("transB", 1)])];
        let m = model(&nodes, &[fc_w], &[vinfo("x", &[1, 3])], &[vinfo("y", &[1, 2])]);
        let bundle = import_onnx_bytes(&m, "gemm").unwrap();
        assert_eq!(const_f32(&bundle, "y/w"), vec![1., 4., 2., 5., 3., 6.]);
        assert_eq!(const_f32(&bundle, "y/b"), vec![0.0, 0.0]);
        // Rank-2 input: no /chw reshape, the placeholder is the activation.
        assert!(!bundle.graph.nodes().iter().any(|n| n.name == "x/chw"));
    }

    #[test]
    fn residual_add_concat_and_identity_map_through() {
        let conv_w = tensor_f32("cw", &[2, 2, 3, 3], &[0.1; 36]);
        let nodes = vec![
            node("Conv", &["x", "cw"], &["c"], &[a_ints("pads", &[1, 1, 1, 1])]),
            node("Identity", &["x"], &["skip"], &[]),
            node("Add", &["c", "skip"], &["sum"], &[]),
            // NCHW channel concat (onnx axis 1 → our axis 0): 2+2 channels.
            node("Concat", &["sum", "c"], &["cat"], &[a_int("axis", 1)]),
        ];
        let m = model(
            &nodes,
            &[conv_w],
            &[vinfo("x", &[1, 2, 4, 4])],
            &[vinfo("cat", &[1, 4, 4, 4])],
        );
        let bundle = import_onnx_bytes(&m, "residual").unwrap();
        let cat = bundle.graph.nodes().iter().find(|n| n.name == "cat").unwrap();
        assert!(matches!(cat.op, OpKind::Concat { axis: 0 }));
        assert_eq!(cat.out_shape, vec![4, 4, 4]);
    }

    #[test]
    fn unsupported_op_and_maxpool_mismatch_are_named_errors() {
        let nodes = vec![node("LeakyRelu", &["x"], &["y"], &[])];
        let m = model(&nodes, &[], &[vinfo("x", &[1, 4])], &[vinfo("y", &[1, 4])]);
        let e = import_onnx_bytes(&m, "bad").unwrap_err().to_string();
        assert!(e.contains("unsupported op 'LeakyRelu'"), "{e}");
        assert!(e.contains("supported ops:"), "{e}");

        // 3x3 pooling window: not maxpool2's contract, must be refused.
        let nodes = vec![node(
            "MaxPool",
            &["x"],
            &["y"],
            &[a_ints("kernel_shape", &[3, 3]), a_ints("strides", &[2, 2])],
        )];
        let m = model(&nodes, &[], &[vinfo("x", &[1, 1, 8, 8])], &[vinfo("y", &[1, 1, 3, 3])]);
        let e = import_onnx_bytes(&m, "pool").unwrap_err().to_string();
        assert!(e.contains("kernel_shape"), "{e}");

        // Ceil mode changes trailing-window semantics vs maxpool2: refused.
        let nodes = vec![node(
            "MaxPool",
            &["x"],
            &["y"],
            &[
                a_ints("kernel_shape", &[2, 2]),
                a_ints("strides", &[2, 2]),
                a_int("ceil_mode", 1),
            ],
        )];
        let m = model(&nodes, &[], &[vinfo("x", &[1, 1, 8, 8])], &[vinfo("y", &[1, 1, 4, 4])]);
        let e = import_onnx_bytes(&m, "pool2").unwrap_err().to_string();
        assert!(e.contains("ceil_mode"), "{e}");
    }

    #[test]
    fn reshape_resolves_minus_one_against_element_count() {
        let shape = tensor_i64_raw("shape", &[2], &[1, -1]);
        let nodes = vec![node("Reshape", &["x", "shape"], &["y"], &[])];
        let m = model(&nodes, &[shape], &[vinfo("x", &[1, 3, 2, 2])], &[vinfo("y", &[1, 12])]);
        let bundle = import_onnx_bytes(&m, "reshape").unwrap();
        let y = bundle.graph.nodes().iter().find(|n| n.name == "y").unwrap();
        assert_eq!(y.out_shape, vec![1, 12]);
    }

    #[test]
    fn not_an_onnx_file_is_a_clean_error() {
        let e = import_onnx_bytes(b"{\"json\": true}", "x").unwrap_err().to_string();
        assert!(e.contains("onnx import:"), "{e}");
    }
}
